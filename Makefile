# Tooling entry points (see README.md).  PYTHONPATH-based src layout: no
# install step, no new dependencies.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-full bench-groups bench-streaming

test:  ## tier-1 verify (ROADMAP.md)
	$(PY) -m pytest -x -q

test-fast:  ## skip the slow end-to-end marks
	$(PY) -m pytest -x -q -m "not slow"

bench:  ## scaled-down benchmark suite -> artifacts/bench/*.csv
	$(PY) -m benchmarks.run

bench-full:  ## paper-scale task counts
	$(PY) -m benchmarks.run --full

bench-groups:  ## exp5 only: provider-group throughput + failover overhead
	$(PY) -m benchmarks.exp5_groups

bench-streaming:  ## exp6 only: streaming vs frontier DAG dispatch (800 instances)
	$(PY) -m benchmarks.exp6_streaming --full
