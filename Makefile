# Tooling entry points (see README.md).  PYTHONPATH-based src layout: no
# install step, no new dependencies.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast lint docs-check cov bench bench-full bench-smoke bench-groups bench-streaming bench-elastic bench-staging bench-sched bench-scenario bench-tenants bench-events bench-market bench-kernels bench-check

test:  ## tier-1 verify (ROADMAP.md)
	$(PY) -m pytest -x -q

test-fast:  ## skip the slow/chaos end-to-end marks (the PR CI lane)
	$(PY) -m pytest -x -q -m "not slow and not chaos"

lint:  ## what the CI lint job runs (needs ruff: pip install ruff)
	ruff check src tests benchmarks
	ruff format --check src

docs-check:  ## docs lint: markdown links resolve, OBSERVABILITY.md <-> EVENTS in sync
	$(PY) tools/docs_check.py

cov:  ## tier-1 with the CI coverage floor (needs pytest-cov)
	$(PY) -m pytest -x -q --cov=repro.core --cov-report=term --cov-fail-under=80

bench:  ## scaled-down benchmark suite -> artifacts/bench/*.csv
	$(PY) -m benchmarks.run

bench-full:  ## paper-scale task counts
	$(PY) -m benchmarks.run --full

bench-groups:  ## exp5 only: provider-group throughput + failover overhead
	$(PY) -m benchmarks.exp5_groups

bench-smoke:  ## CI-sized subset -> artifacts/bench/BENCH_smoke.json
	$(PY) -m benchmarks.run --smoke

bench-streaming:  ## exp6 only: streaming vs frontier DAG dispatch (800 instances)
	$(PY) -m benchmarks.exp6_streaming --full

bench-elastic:  ## exp7 only: elastic weak scaling + over-provisioning cost curve
	$(PY) -m benchmarks.exp7_elastic --full

bench-staging:  ## exp8 only: data-aware staging, locality-aware vs blind placement
	$(PY) -m benchmarks.exp8_staging --full

bench-sched:  ## exp9 only: broker dispatch throughput, 100k tasks x 256 providers
	$(PY) -m benchmarks.exp9_sched --full

bench-scenario:  ## exp10 only: at-scale chaos scenario + structured report
	$(PY) -m benchmarks.exp10_scenario --report

bench-tenants:  ## exp11 only: interactive p99 under a 100k-task bulk flood
	$(PY) -m benchmarks.exp11_tenants --full

bench-events:  ## exp12 only: event-bus emit/replay throughput + dispatch tax
	$(PY) -m benchmarks.exp12_events --full

bench-market:  ## exp13 only: spot-vs-on-demand cost + checkpoint storm recovery
	$(PY) -m benchmarks.exp13_market --full

bench-kernels:  ## exp14 only: per-kernel XLA parity rows + autotuner tuned-vs-default
	$(PY) -m benchmarks.kernels_bench

bench-check:  ## smoke run + dispatch-throughput regression gate vs committed baseline
	git show HEAD:artifacts/bench/BENCH_smoke.json > /tmp/bench_baseline.json
	$(PY) -m benchmarks.run --smoke
	$(PY) -m benchmarks.check_bench /tmp/bench_baseline.json artifacts/bench/BENCH_smoke.json
