"""Market scheduler (core/market.py) + task-level checkpoint/restore
(ckpt/checkpoint.py TaskCheckpointer): hazard math, deterministic bid
schedules, spend settlement, preempt-kill resume without retry charges, and
the admission interplay (a resumed task holds its tenant queue slot exactly
once).

Everything timed runs under a VirtualClock; both strict cross-check modes
(HYDRA_EVENTS_CHECK / HYDRA_LEDGER_CHECK) are exercised implicitly through
``shutdown()`` in the end-to-end tests.
"""
from __future__ import annotations

import os
import random
import time

import pytest

from repro.core import Hydra, ProviderSpec, Task
from repro.core.admission import TenantSpec
from repro.core.autoscaler import LatencyModel, LaunchSpec, ProviderPool
from repro.core.chaos import ChaosEngine, PreemptKill
from repro.core.market import (
    HPC_WALLTIME_HAZARD,
    ON_DEMAND_HAZARD,
    SPOT_HAZARD,
    MarketPlanner,
    PreemptionHazard,
)
from repro.core.task import TaskState
from repro.runtime.clock import virtual_time

from conftest import wait_until


def spot_launch(name="spot", price=0.3, rate=6.0, latency_s=2.0, **kw):
    kw.setdefault("max_instances", 4)
    return LaunchSpec(
        template=ProviderSpec(name=name, platform="cloud", concurrency=8),
        latency=LatencyModel(distribution="fixed", mean_s=latency_s),
        price_per_slot_hour=price,
        hazard=PreemptionHazard(rate_per_hour=rate),
        **kw,
    )


def ondemand_launch(name="ond", price=1.0, latency_s=2.0, **kw):
    kw.setdefault("max_instances", 4)
    return LaunchSpec(
        template=ProviderSpec(name=name, platform="cloud", concurrency=8),
        latency=LatencyModel(distribution="fixed", mean_s=latency_s),
        price_per_slot_hour=price,
        **kw,
    )


# ---------------------------------------------------------------------------
# PreemptionHazard: the seeded revocation model
# ---------------------------------------------------------------------------


def test_hazard_tiers_ordered_and_loss_math():
    assert SPOT_HAZARD.rate_per_hour > HPC_WALLTIME_HAZARD.rate_per_hour
    assert HPC_WALLTIME_HAZARD.rate_per_hour > ON_DEMAND_HAZARD.rate_per_hour
    h = PreemptionHazard(rate_per_hour=6.0)
    # 6 kills/hr x 60s recovery = 360s lost per 3600s -> 10% loss
    assert h.expected_loss_frac(60.0) == pytest.approx(0.1)
    assert PreemptionHazard(rate_per_hour=0.0).expected_loss_frac(60.0) == 0.0
    # capped below 1: a hazardous slot is never literally worthless
    assert PreemptionHazard(rate_per_hour=1e6).expected_loss_frac(600.0) == 0.9
    assert h.survival_p(0.0) == 1.0
    assert h.survival_p(600.0) == pytest.approx(0.3678794, rel=1e-5)


def test_hazard_sample_kills_seeded_and_reproducible():
    h = PreemptionHazard(rate_per_hour=6.0)
    names = [f"spot-{i}" for i in range(20)]
    a = h.sample_kills(random.Random(5), names, window_s=600.0)
    b = h.sample_kills(random.Random(5), names, window_s=600.0)
    assert a == b
    assert set(a) <= set(names)
    # ~63% expected kill rate over 600s at 6/hr: a draw of 20 lands inside
    # wide bounds, and a zero-rate hazard kills nobody
    assert 4 <= len(a) <= 19
    assert PreemptionHazard(0.0).sample_kills(random.Random(5), names, 600.0) == []


# ---------------------------------------------------------------------------
# LaunchSpec validation (satellite bugfix: ValueError contract)
# ---------------------------------------------------------------------------


def test_launch_spec_rejects_inverted_and_negative_bounds():
    with pytest.raises(ValueError):
        LaunchSpec(
            template=ProviderSpec(name="x", platform="cloud"),
            min_instances=3,
            max_instances=1,
        )
    with pytest.raises(ValueError):
        LaunchSpec(
            template=ProviderSpec(name="x", platform="cloud"),
            min_instances=-1,
            max_instances=2,
        )
    with pytest.raises(ValueError):
        LaunchSpec(
            template=ProviderSpec(name="x", platform="cloud"),
            min_instances=0,
            max_instances=-2,
        )
    with pytest.raises(ValueError):
        LaunchSpec(
            template=ProviderSpec(name="x", platform="cloud"),
            price_per_slot_hour=-0.5,
        )


# ---------------------------------------------------------------------------
# MarketPlanner: ranking, feasibility, pricing
# ---------------------------------------------------------------------------


def test_planner_ranks_by_price_per_effective_slot_hour():
    p = MarketPlanner(recovery_cost_s=60.0)
    spot = spot_launch(price=0.3, rate=6.0)  # 0.3 / (8*0.9) = 0.0417 $/eff
    ond = ondemand_launch(price=1.0)  # platform-default hazard ~ 1.0/8
    ranked = p._rank([ond, spot])
    assert [r.template.name for r in ranked] == ["spot", "ond"]
    # a spot price spike flips the order on the next ranking
    p.set_price("spot", 2.0)
    ranked = p._rank([ond, spot])
    assert [r.template.name for r in ranked] == ["ond", "spot"]


def test_planner_hazard_discount_can_beat_nominal_price():
    p = MarketPlanner(recovery_cost_s=600.0)
    # nominally cheaper, but 50% expected loss at this recovery cost
    risky = spot_launch(name="risky", price=0.6, rate=3.0)
    stable = spot_launch(name="stable", price=0.7, rate=0.0)
    # risky: 0.6/(8*0.5)=0.15; stable: 0.7/8=0.0875
    ranked = p._rank([risky, stable])
    assert [r.template.name for r in ranked] == ["stable", "risky"]


def test_planner_slo_feasibility_excludes_slow_acquisitions():
    p = MarketPlanner(slo_target_s=30.0)
    fast = spot_launch(name="fast", latency_s=5.0)
    slow = ondemand_launch(name="hpcq", price=0.01, latency_s=300.0)
    assert p.feasible(fast) and not p.feasible(slow)
    assert [r.template.name for r in p._rank([slow, fast])] == ["fast"]
    # no target: everything is feasible, cheapest wins
    assert len(MarketPlanner()._rank([slow, fast])) == 2


def test_planner_rejects_negative_price():
    with pytest.raises(ValueError):
        MarketPlanner().set_price("spot", -1.0)


def test_default_hazard_by_platform():
    p = MarketPlanner()
    cloud = ondemand_launch(name="c")
    hpc = LaunchSpec(
        template=ProviderSpec(name="h", platform="hpc", connector="pilot"),
        latency=LatencyModel(distribution="fixed", mean_s=60.0),
        price_per_slot_hour=0.05,
    )
    assert p.hazard_of(cloud) is ON_DEMAND_HAZARD
    assert p.hazard_of(hpc) is HPC_WALLTIME_HAZARD
    explicit = spot_launch(rate=9.0)
    assert p.hazard_of(explicit).rate_per_hour == 9.0


# ---------------------------------------------------------------------------
# The bid/choose loop end to end: deterministic schedule + settled spend
# ---------------------------------------------------------------------------


def _run_market_fleet(seed: int):
    """One seeded elastic run with a planner; returns (bid_log, report)."""
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
        pool = ProviderPool(
            [spot_launch(), ondemand_launch(max_instances=2)], seed=seed
        )
        planner = MarketPlanner(slo_target_s=30.0, seed=seed)
        h.autoscale(
            pool,
            tick_s=1.0,
            warmup_ticks=2,
            cooldown_ticks=4,
            scale_out_pressure=1.2,
            planner=planner,
        )
        tasks = [Task(kind="sleep", duration=5.0) for _ in range(32)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=30.0)
        h.shutdown(wait=True)
        return list(planner.bid_log), planner.cost_report()


def test_same_seed_same_bid_schedule():
    log_a, report_a = _run_market_fleet(seed=11)
    log_b, report_b = _run_market_fleet(seed=11)
    # the bid schedule — which template won each acquisition, at what price
    # and effective throughput — is seed-deterministic.  (Raw settlement
    # node-seconds can shift by a tick with thread interleaving, like the
    # scenario harness's makespans; they are reported, not fingerprinted.)
    assert [(n, p, e) for _, n, p, e in log_a] == [
        (n, p, e) for _, n, p, e in log_b
    ]
    assert report_a["bids"] == len(log_a) > 0
    assert report_a["bids_by_template"] == report_b["bids_by_template"]
    assert report_a["dollars"] > 0
    assert report_a["settled_instances"] > 0


def test_cost_report_deterministic_closed_loop():
    """Same seed => identical cost report, bit for bit, when the planner is
    driven directly (no thread scheduling in the loop): the planner itself
    introduces no nondeterminism."""

    class _Bus:
        def emit(self, *a, **k):
            pass

    def drive(seed):
        p = MarketPlanner(slo_target_s=30.0, seed=seed)
        p._events = _Bus()
        candidates = [spot_launch(), ondemand_launch()]
        for i in range(6):
            launch = p.choose(candidates, deficit=8)
            row = {"arrived_at": 10.0 * i, "released_at": 10.0 * i + 7.5}
            p.settle(launch, f"{launch.template.name}-{i}", row)
        return p.cost_report(), [(n, pr, e) for _, n, pr, e in p.bid_log]

    report_a, log_a = drive(3)
    report_b, log_b = drive(3)
    assert report_a == report_b
    assert log_a == log_b
    assert report_a["dollars"] == pytest.approx(6 * 7.5 / 3600.0 * 0.3 * 8)


def test_spend_settles_into_event_metrics():
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
        pool = ProviderPool([spot_launch(min_instances=1)], seed=0)
        planner = MarketPlanner(seed=0)
        h.autoscale(pool, tick_s=1.0, planner=planner)
        tasks = [Task(kind="sleep", duration=2.0) for _ in range(4)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=20.0)
        h.shutdown(wait=True)  # settles the still-live min instance
        view = h.events.view
        assert view.get("hydra.cost_node_seconds") == pytest.approx(
            planner.cost_node_seconds
        )
        assert view.get("hydra.cost_dollars") == pytest.approx(
            planner.cost_dollars
        )
        assert planner.cost_dollars > 0
        # settlement is idempotent: re-settling every ledger row adds nothing
        before = planner.cost_dollars
        scaler = h.autoscaler
        for name, row in scaler.ledger.items():
            launch = pool.specs[0]
            planner.settle(launch, name, row)
        assert planner.cost_dollars == before


def test_planner_without_feasible_candidates_blocks_scale_out():
    """An SLO target nothing can meet: choose() returns None and the fleet
    must not buy capacity it knows will arrive too late."""
    p = MarketPlanner(slo_target_s=1.0)
    assert p.choose([], deficit=8) is None
    slow = ondemand_launch(latency_s=300.0)
    assert p.choose([slow], deficit=8) is None
    assert p.bid_log == []


# ---------------------------------------------------------------------------
# TaskCheckpointer: preempt-kill -> resume without charging max_retries
# ---------------------------------------------------------------------------


def _market_ckpt_fleet(n_tasks=24, duration=10.0, tenants=None):
    h = Hydra(
        streaming=True,
        pod_store="memory",
        batch_window=0.002,
        tenants=tenants,
    )
    h.enable_task_checkpoints(interval_s=2.0)
    pool = ProviderPool(
        [spot_launch(), ondemand_launch(min_instances=1, max_instances=2)],
        seed=7,
    )
    planner = MarketPlanner(slo_target_s=30.0, seed=7)
    h.autoscale(
        pool,
        tick_s=1.0,
        warmup_ticks=2,
        cooldown_ticks=4,
        scale_out_pressure=1.2,
        planner=planner,
    )
    return h, planner


def test_preempt_kill_resumes_without_charging_retries():
    with virtual_time():
        h, planner = _market_ckpt_fleet()
        tasks = [Task(kind="sleep", duration=10.0) for _ in range(24)]
        h.dispatch(tasks)
        engine = ChaosEngine(h, [PreemptKill(at_s=6.0, count=8)], seed=3)
        engine.arm()
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=30.0)
        engine.stop()
        resumed = [t for t in tasks if t.resumes > 0]
        assert len(engine.preempted_uids) > 0
        assert resumed, "the storm must actually preempt someone"
        for t in tasks:
            assert t.tstate == TaskState.DONE
            assert t.exception() is None
        for t in resumed:
            # the paper-critical contract: resumes never charge max_retries
            assert t.retries == 0
            assert t.progress_frac > 0
            assert t.ckpt_dataset in t.inputs
            assert h.staging.registry.known(t.ckpt_dataset)
            assert t.trace.last("resume_gated") is not None
        stats = h.checkpointer.stats()
        assert stats["resumes"] == len(engine.preempted_uids)
        assert stats["saves"] == stats["resumes"]
        assert h._dispatcher.resume_gated == len(resumed)
        h.shutdown(wait=True)


def test_site_death_resumes_checkpointable_orphans_mid_run():
    """The harder path: the whole instance dies under RUNNING tasks
    (_collect_orphans).  Progress captured mid-run means lost work is the
    tail since the last interval boundary — strictly less than full
    re-execution."""
    with virtual_time() as clock:
        h, planner = _market_ckpt_fleet()
        tasks = [Task(kind="sleep", duration=10.0) for _ in range(24)]
        h.dispatch(tasks)
        scaler = h.autoscaler

        def live_spot():
            return [
                n for n in scaler.pool.live_instances() if n.startswith("spot")
            ]

        assert wait_until(lambda: len(live_spot()) > 0, timeout=20.0)
        # let some work execute past an interval boundary, then kill the site
        target = live_spot()[0]
        assert wait_until(
            lambda: any(
                t.tstate == TaskState.RUNNING and t.provider == target
                for t in tasks
            ),
            timeout=20.0,
        )
        clock.sleep(3.0)
        h.remove_provider(target, drain=False, deregister=False)
        scaler.note_provider_lost(target)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=30.0)
        resumed = [t for t in tasks if t.resumes > 0]
        assert resumed
        for t in tasks:
            assert t.exception() is None
        for t in resumed:
            assert t.retries == 0
        # the dead instance leaves the binding set the moment removal
        # returns, so any re-placement lands on a survivor.  (A resumed
        # task may still FINISH attributed to the dead name: mark_done is
        # authoritative from any state, so the doomed manager's in-flight
        # sleep can win the completion race against the re-bound copy —
        # at-least-once execution, exactly-once completion.)
        assert target not in {p.name for p in h.proxy.healthy()}
        stats = h.checkpointer.stats()
        assert stats["preempted_work_s"] > 0
        # write-behind: at most one interval of work lost per resume
        assert stats["reexecuted_s"] <= 2.0 * len(resumed) + 1e-9
        h.shutdown(wait=True)


def test_noncheckpointable_kinds_still_charge_retries():
    """noop/callable tasks have no resumable progress: a preempt kill on
    them goes down the classic retry path (charged), proving eligible()
    actually gates the resume."""
    with virtual_time():
        h, planner = _market_ckpt_fleet()
        tasks = [Task(kind="noop") for _ in range(8)]
        # hold the tasks RUNNING long enough for the kill to land
        slow = [Task(kind="sleep", duration=6.0) for _ in range(8)]
        h.dispatch(tasks + slow)
        engine = ChaosEngine(h, [PreemptKill(at_s=4.0, count=16)], seed=1)
        engine.arm()
        assert wait_until(
            lambda: all(t.done() for t in tasks + slow), timeout=30.0
        )
        engine.stop()
        killed_noops = [
            t for t in tasks if t.uid in set(engine.preempted_uids)
        ]
        for t in killed_noops:
            assert t.retries > 0  # classic path: the retry was charged
            assert t.resumes == 0
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Satellite: preempt x admission — the queue slot is held exactly once
# ---------------------------------------------------------------------------


def test_preempted_resume_holds_tenant_queue_slot_exactly_once():
    """A preempted-and-resumed task must not leak admission accounting:
    its future resolves once (at final completion), so the release-at-
    resolution callback fires once, and the resume re-enters as an internal
    requeue without being re-charged."""
    with virtual_time():
        h, planner = _market_ckpt_fleet(
            tenants=[TenantSpec(name="acme", max_queued=64)]
        )
        tasks = [
            Task(kind="sleep", duration=10.0, tenant="acme") for _ in range(16)
        ]
        h.dispatch(tasks)
        assert h.admission.held("acme") == 16
        admitted_before = h.admission.admitted
        engine = ChaosEngine(h, [PreemptKill(at_s=6.0, count=6)], seed=3)
        engine.arm()
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=30.0)
        engine.stop()
        resumed = [t for t in tasks if t.resumes > 0]
        assert resumed, "the storm must actually preempt someone"
        for t in tasks:
            assert t.exception() is None
            assert t.admitted  # still marked: requeues were never re-charged
            assert not t.admission_held  # the one release fired
        # exactly one hold+release per task: nothing leaked, nothing double-
        # released (held() would go negative-clamped-to-0 either way, so
        # check the admit counter too)
        assert h.admission.held("acme") == 0
        assert h.admission.admitted == admitted_before
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Satellite: async_save + retention / LATEST round-trip
# ---------------------------------------------------------------------------


def _tree(step):
    import numpy as np

    return {"w": np.full((2, 3), float(step)), "step": np.asarray(step)}


def test_async_save_retention_and_latest_roundtrip(tmp_path):
    """The docstring's promised async save path: scheduled on the shared
    Clock, joined via the handle, retention keeps the newest ``keep``."""
    import numpy as np

    from repro.ckpt import checkpoint as ckpt

    with virtual_time() as clock:
        handles = [
            ckpt.async_save(str(tmp_path), step, _tree(step), keep=2)
            for step in (1, 2, 3)
        ]
        for step, hd in zip((1, 2, 3), handles):
            path = hd.wait(timeout=10.0)
            assert os.path.basename(path) == f"step_{step:08d}"
            assert hd.done()
        # the newest write's dir exists; older ones may be retention-pruned
        assert os.path.isdir(handles[-1].wait())
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000002", "step_00000003"]
    assert ckpt.latest_step(str(tmp_path)) == 3
    step, restored = ckpt.restore(str(tmp_path), _tree(0))
    assert step == 3
    np.testing.assert_array_equal(restored["w"], _tree(3)["w"])


def test_async_save_error_surfaces_on_wait(tmp_path):
    from repro.ckpt import checkpoint as ckpt

    blocked = tmp_path / "not_a_dir"
    blocked.write_text("a file where the checkpoint dir should go")
    with virtual_time():
        hd = ckpt.async_save(str(blocked), 1, _tree(1))
        with pytest.raises(OSError):
            hd.wait(timeout=10.0)


# ---------------------------------------------------------------------------
# Scenario-spec round trip for the new knobs
# ---------------------------------------------------------------------------


def test_scenario_spec_market_knobs_roundtrip():
    from repro.scenarios.spec import ElasticDecl, ScenarioSpec

    spec = ScenarioSpec(
        name="mkt",
        elastic=[
            ElasticDecl(
                template="spot",
                price_per_slot_hour=0.3,
                hazard_rate_per_hour=6.0,
            )
        ],
        market_slo_s=30.0,
        checkpoint_interval_s=2.0,
    )
    back = ScenarioSpec.from_dict(spec.to_dict())
    assert back == spec
    launch = back.elastic[0].to_core()
    assert launch.price_per_slot_hour == 0.3
    assert launch.hazard.rate_per_hour == 6.0
    # default: no hazard object, free template (pre-market behavior)
    plain = ElasticDecl(template="t").to_core()
    assert plain.hazard is None and plain.price_per_slot_hour == 0.0
