"""DataManager: the paper's five verbs + checkpoint staging."""
import os

import pytest

from repro.core.managers.data import DataManager


@pytest.fixture
def dm(tmp_path):
    d = DataManager(str(tmp_path))
    d.register_site("jet2")
    d.register_site("aws")
    return d


def test_put_get_copy_move_delete_list(dm):
    dm.put_bytes("jet2", "in/a.bin", b"hello")
    assert dm.get_bytes("jet2", "in/a.bin") == b"hello"
    dm.copy("jet2", "in/a.bin", "aws", "staged/a.bin")
    assert dm.get_bytes("aws", "staged/a.bin") == b"hello"
    dm.move("aws", "staged/a.bin", "shared", "final/a.bin")
    assert not dm.exists("aws", "staged/a.bin")
    assert dm.get_bytes("shared", "final/a.bin") == b"hello"
    assert dm.list("shared", "final") == ["a.bin"]
    dm.delete("shared", "final/a.bin")
    assert not dm.exists("shared", "final/a.bin")


def test_link_is_zero_copy(dm):
    dm.put_bytes("jet2", "data/x.bin", b"payload")
    p = dm.link("jet2", "data/x.bin", "jet2", "run1/x.bin")
    assert os.path.islink(p)
    assert dm.get_bytes("jet2", "run1/x.bin") == b"payload"


def test_path_escape_rejected(dm):
    with pytest.raises(ValueError):
        dm.put_bytes("jet2", "../../etc/passwd", b"nope")


def test_stage_checkpoint(dm, tmp_path):
    ckpt_dir = tmp_path / "ckpts"
    step_dir = ckpt_dir / "step_00000009"
    step_dir.mkdir(parents=True)
    (step_dir / "arrays.npz").write_bytes(b"fake")
    dst = dm.stage_checkpoint("jet2", str(ckpt_dir), 9)
    assert os.path.exists(os.path.join(dst, "arrays.npz"))
