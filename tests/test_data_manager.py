"""DataManager: the paper's five verbs + checkpoint staging."""
import os

import pytest

from repro.core.managers.data import DataManager, UnknownSiteError


@pytest.fixture
def dm(tmp_path):
    d = DataManager(str(tmp_path))
    d.register_site("jet2")
    d.register_site("aws")
    return d


def test_put_get_copy_move_delete_list(dm):
    dm.put_bytes("jet2", "in/a.bin", b"hello")
    assert dm.get_bytes("jet2", "in/a.bin") == b"hello"
    dm.copy("jet2", "in/a.bin", "aws", "staged/a.bin")
    assert dm.get_bytes("aws", "staged/a.bin") == b"hello"
    dm.move("aws", "staged/a.bin", "shared", "final/a.bin")
    assert not dm.exists("aws", "staged/a.bin")
    assert dm.get_bytes("shared", "final/a.bin") == b"hello"
    assert dm.list("shared", "final") == ["a.bin"]
    dm.delete("shared", "final/a.bin")
    assert not dm.exists("shared", "final/a.bin")


def test_link_is_zero_copy(dm):
    dm.put_bytes("jet2", "data/x.bin", b"payload")
    p = dm.link("jet2", "data/x.bin", "jet2", "run1/x.bin")
    assert os.path.islink(p)
    assert dm.get_bytes("jet2", "run1/x.bin") == b"payload"


def test_path_escape_rejected(dm):
    with pytest.raises(ValueError):
        dm.put_bytes("jet2", "../../etc/passwd", b"nope")


def test_sibling_site_with_colliding_name_prefix_rejected(tmp_path):
    """Regression: startswith-based containment let ``../ab/x`` escape site
    ``a`` into sibling site ``ab`` (shared string prefix, different dir)."""
    d = DataManager(str(tmp_path))
    d.register_site("a")
    d.register_site("ab")
    with pytest.raises(ValueError):
        d.put_bytes("a", "../ab/x.bin", b"nope")
    with pytest.raises(ValueError):
        d.list("a", "../ab")
    # legitimate paths inside each site still resolve
    d.put_bytes("ab", "x.bin", b"yes")
    assert d.get_bytes("ab", "x.bin") == b"yes"


def test_unknown_site_raises_instead_of_silently_creating(dm, tmp_path):
    """Regression: copy/move/link to a never-registered site used to mint a
    fresh site directory and strand the data there."""
    dm.put_bytes("jet2", "in/a.bin", b"hello")
    with pytest.raises(UnknownSiteError):
        dm.copy("jet2", "in/a.bin", "typo", "a.bin")
    with pytest.raises(UnknownSiteError):
        dm.move("jet2", "in/a.bin", "typo", "a.bin")
    with pytest.raises(UnknownSiteError):
        dm.link("jet2", "in/a.bin", "typo", "a.bin")
    with pytest.raises(UnknownSiteError):
        dm.copy("typo", "a.bin", "jet2", "a.bin")
    assert not os.path.exists(os.path.join(str(tmp_path), "typo"))
    assert dm.get_bytes("jet2", "in/a.bin") == b"hello"  # source untouched


def test_stage_checkpoint(dm, tmp_path):
    ckpt_dir = tmp_path / "ckpts"
    step_dir = ckpt_dir / "step_00000009"
    step_dir.mkdir(parents=True)
    (step_dir / "arrays.npz").write_bytes(b"fake")
    dst = dm.stage_checkpoint("jet2", str(ckpt_dir), 9)
    assert os.path.exists(os.path.join(dst, "arrays.npz"))
