"""Task state machine: legal transitions, idempotent completion, tracing."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.task import (
    FINAL_STATES,
    LEGAL,
    IllegalTransition,
    Resources,
    Task,
    TaskState,
)

ALL_STATES = list(TaskState)


def test_legal_path_to_done():
    t = Task(kind="noop")
    for s in (TaskState.BOUND, TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING):
        t.advance(s)
    t.mark_done(42)
    assert t.tstate == TaskState.DONE
    assert t.result() == 42


def test_illegal_transition_raises():
    t = Task(kind="noop")
    with pytest.raises(IllegalTransition):
        t.advance(TaskState.RUNNING)  # NEW -> RUNNING is illegal


def test_mark_done_is_idempotent_and_authoritative():
    t = Task(kind="noop")
    t.advance(TaskState.BOUND)
    t.mark_done("first")
    t.mark_done("second")  # duplicate/speculative completion: no-op
    assert t.result() == "first"
    assert t.tstate == TaskState.DONE


def test_mark_failed_ignored_when_not_inflight():
    t = Task(kind="noop")
    t.advance(TaskState.BOUND)
    assert t.mark_failed(RuntimeError("stale")) is False
    assert t.tstate == TaskState.BOUND


def test_retry_cycle():
    t = Task(kind="noop", max_retries=2)
    for s in (TaskState.BOUND, TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING):
        t.advance(s)
    assert t.mark_failed(RuntimeError("boom")) is True
    assert not t.done()  # retries remain: no exception surfaced yet
    t.reset_for_retry()
    assert t.tstate == TaskState.BOUND and t.retries == 1


def test_exhausted_retries_surface_exception():
    t = Task(kind="noop", max_retries=0)
    for s in (TaskState.BOUND, TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING):
        t.advance(s)
    t.mark_failed(RuntimeError("boom"))
    with pytest.raises(RuntimeError):
        t.result(timeout=0.1)


@given(st.lists(st.sampled_from(ALL_STATES), min_size=1, max_size=12))
@settings(max_examples=200, deadline=None)
def test_state_machine_never_leaves_final_states(path):
    """Property: whatever transition sequence is attempted via try_advance,
    a final-state task only changes via the explicit retry path."""
    t = Task(kind="noop")
    for target in path:
        before = t.tstate
        moved = t.try_advance(target)
        if moved:
            assert target in LEGAL[before]
        else:
            assert t.tstate == before
        if before in FINAL_STATES and before != TaskState.FAILED:
            assert t.tstate == before


def test_resources_fits():
    small = Resources(cpus=1, accels=0, memory_mb=100)
    big = Resources(cpus=8, accels=2, memory_mb=1024)
    assert small.fits(big) and not big.fits(small)


# ---------------------------------------------------------------------------
# Property suite: random legal/illegal op sequences (transitions + completion
# calls) must never corrupt the machine — final states stay final (modulo the
# explicit FAILED -> BOUND retry), done callbacks fire exactly once, and every
# trace is monotonically timestamped.
# ---------------------------------------------------------------------------

# ops: attempted transitions (legal or not) interleaved with completion calls
OPS = ALL_STATES + ["mark_done", "mark_failed", "mark_canceled", "reset_for_retry"]


def _apply(task, op):
    if isinstance(op, TaskState):
        task.try_advance(op)
    elif op == "mark_done":
        task.mark_done("r")
    elif op == "mark_failed":
        task.mark_failed(RuntimeError("boom"))
    elif op == "mark_canceled":
        task.mark_canceled()
    elif op == "reset_for_retry":
        if task.tstate == TaskState.FAILED and task.retries < task.max_retries:
            task.reset_for_retry()


@given(st.lists(st.sampled_from(OPS), min_size=1, max_size=16))
@settings(max_examples=300, deadline=None)
def test_random_ops_never_corrupt_final_states(ops):
    t = Task(kind="noop", max_retries=1)
    for op in ops:
        before = t.tstate
        _apply(t, op)
        after = t.tstate
        assert after in set(TaskState)
        if before in FINAL_STATES and before != TaskState.FAILED:
            # DONE/CANCELED are absorbing, whatever is thrown at them
            assert after == before
        if before == TaskState.FAILED:
            # FAILED may only leave via the explicit retry path
            assert after in (TaskState.FAILED, TaskState.BOUND)


@given(st.lists(st.sampled_from(OPS), min_size=1, max_size=16))
@settings(max_examples=300, deadline=None)
def test_done_callbacks_never_double_fire(ops):
    t = Task(kind="noop", max_retries=0)
    fired = []
    t.add_done_callback(lambda fut: fired.append(fut))
    for op in ops:
        _apply(t, op)
    assert len(fired) <= 1
    if t.done():  # resolved future <=> exactly one callback fire
        assert len(fired) == 1
    # duplicate completion attempts are no-ops: a resolved (or resolvable)
    # future fires exactly once; a tstate-only CANCELED (future never
    # resolved) stays silent rather than firing late
    t.mark_done("again")
    t.mark_done("again")
    assert len(fired) == (1 if t.done() else 0)


@given(st.lists(st.sampled_from(OPS), min_size=1, max_size=16))
@settings(max_examples=300, deadline=None)
def test_trace_events_monotonically_timestamped(ops):
    t = Task(kind="noop", max_retries=1)
    for op in ops:
        _apply(t, op)
    ts = [stamp for _, stamp in t.trace.events]
    assert ts == sorted(ts)
    assert t.trace.events[0][0] == "created"
