"""Task state machine: legal transitions, idempotent completion, tracing."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.task import (
    FINAL_STATES,
    LEGAL,
    IllegalTransition,
    Resources,
    Task,
    TaskState,
)

ALL_STATES = list(TaskState)


def test_legal_path_to_done():
    t = Task(kind="noop")
    for s in (TaskState.BOUND, TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING):
        t.advance(s)
    t.mark_done(42)
    assert t.tstate == TaskState.DONE
    assert t.result() == 42


def test_illegal_transition_raises():
    t = Task(kind="noop")
    with pytest.raises(IllegalTransition):
        t.advance(TaskState.RUNNING)  # NEW -> RUNNING is illegal


def test_mark_done_is_idempotent_and_authoritative():
    t = Task(kind="noop")
    t.advance(TaskState.BOUND)
    t.mark_done("first")
    t.mark_done("second")  # duplicate/speculative completion: no-op
    assert t.result() == "first"
    assert t.tstate == TaskState.DONE


def test_mark_failed_ignored_when_not_inflight():
    t = Task(kind="noop")
    t.advance(TaskState.BOUND)
    assert t.mark_failed(RuntimeError("stale")) is False
    assert t.tstate == TaskState.BOUND


def test_retry_cycle():
    t = Task(kind="noop", max_retries=2)
    for s in (TaskState.BOUND, TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING):
        t.advance(s)
    assert t.mark_failed(RuntimeError("boom")) is True
    assert not t.done()  # retries remain: no exception surfaced yet
    t.reset_for_retry()
    assert t.tstate == TaskState.BOUND and t.retries == 1


def test_exhausted_retries_surface_exception():
    t = Task(kind="noop", max_retries=0)
    for s in (TaskState.BOUND, TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING):
        t.advance(s)
    t.mark_failed(RuntimeError("boom"))
    with pytest.raises(RuntimeError):
        t.result(timeout=0.1)


@given(st.lists(st.sampled_from(ALL_STATES), min_size=1, max_size=12))
@settings(max_examples=200, deadline=None)
def test_state_machine_never_leaves_final_states(path):
    """Property: whatever transition sequence is attempted via try_advance,
    a final-state task only changes via the explicit retry path."""
    t = Task(kind="noop")
    for target in path:
        before = t.tstate
        moved = t.try_advance(target)
        if moved:
            assert target in LEGAL[before]
        else:
            assert t.tstate == before
        if before in FINAL_STATES and before != TaskState.FAILED:
            assert t.tstate == before


def test_resources_fits():
    small = Resources(cpus=1, accels=0, memory_mb=100)
    big = Resources(cpus=8, accels=2, memory_mb=1024)
    assert small.fits(big) and not big.fits(small)
