"""Data-aware staging subsystem (core/staging.py): registry/LRU semantics,
clock-driven transfer determinism, per-link queueing, gravity placement,
dispatcher stage-in/stage-out, autoscaler pressure, and chaos re-routing."""
from __future__ import annotations

import pytest

from repro.core import (
    Hydra,
    ProviderSpec,
    StagingError,
    Task,
    Workflow,
    WorkflowManager,
)
from repro.core.autoscaler import Autoscaler, LaunchSpec, ProviderPool, cloud_startup
from repro.core.policy import make_policy
from repro.core.provider import ProviderHandle
from repro.core.staging import DatasetRegistry, StagingService, TransferEngine
from repro.runtime.clock import virtual_time

from conftest import wait_until


# ---------------------------------------------------------------------------
# DatasetRegistry: replicas, capacity, LRU eviction
# ---------------------------------------------------------------------------


def test_registry_replicas_and_location():
    reg = DatasetRegistry()
    reg.register_site("a", "cloud")
    reg.register_site("b", "hpc")
    reg.add("d1", 100.0, sites=["shared", "a"])
    assert reg.locate("d1") == ["a", "shared"]
    assert reg.resident("d1", "a") and not reg.resident("d1", "b")
    assert reg.missing(["d1"], "b") == ["d1"]
    assert reg.missing_mb(["d1"], "b") == 100.0
    assert reg.resident_mb(["d1"], "a") == 100.0


def test_registry_lru_eviction_under_capacity_pressure():
    reg = DatasetRegistry()
    reg.register_site("s", "cloud", capacity_mb=120.0)
    for name in ("x", "y", "z"):
        reg.add(name, 40.0, sites=["shared"])
        reg.place_replica(name, "s")
    # x is oldest, but a touch makes it hottest -> y becomes the LRU victim
    reg.touch("x", "s")
    reg.add("w", 40.0, sites=["shared"])
    evicted = reg.place_replica("w", "s")
    assert evicted == ["y"]
    assert reg.resident("x", "s") and reg.resident("w", "s")
    assert reg.locate("y") == ["shared"]  # the shared copy survives
    assert reg.evictions == 1


def test_registry_never_evicts_last_copy_or_pinned():
    reg = DatasetRegistry()
    reg.register_site("s", "cloud", capacity_mb=100.0)
    reg.add("only_copy", 60.0)  # nowhere else: eviction would be data loss
    reg.place_replica("only_copy", "s")
    reg.add("big", 60.0, sites=["shared"])
    with pytest.raises(StagingError):
        reg.place_replica("big", "s")
    assert reg.resident("only_copy", "s")


def test_registry_oversized_dataset_rejected():
    reg = DatasetRegistry()
    reg.register_site("s", "cloud", capacity_mb=100.0)
    reg.add("huge", 200.0, sites=["shared"])
    with pytest.raises(StagingError):
        reg.place_replica("huge", "s")


# ---------------------------------------------------------------------------
# TransferEngine: clock-driven, deterministic, link-limited
# ---------------------------------------------------------------------------


def _engine(clock_sites=(("a", "cloud"), ("b", "cloud"), ("c", "hpc")), seed=0, **kw):
    reg = DatasetRegistry()
    for name, platform in clock_sites:
        reg.register_site(name, platform)
    return reg, TransferEngine(reg, seed=seed, **kw)


def test_replica_read_is_free_and_immediate():
    with virtual_time(auto_advance=False):
        reg, eng = _engine()
        reg.add("d", 100.0, sites=["a"])
        done = []
        eng.fetch("d", "a", done.append)
        assert done == [True]  # no clock advance needed: replica hit
        assert eng.cache_hits == 1 and eng.mb_moved == 0.0


def test_cold_read_completes_at_modeled_deadline():
    with virtual_time(auto_advance=False) as clock:
        reg, eng = _engine(seed=3)
        reg.add("d", 120.0, sites=["a"])
        done = []
        eng.fetch("d", "b", done.append)
        assert done == [] and eng.active_transfers() == 1
        # ~120MB over a ~120MB/s cloud link: far from done after 0.2s...
        clock.advance(0.2)
        assert done == []
        # ...and done once virtual time passes the sampled duration
        clock.advance(30.0)
        assert done == [True]
        assert reg.resident("d", "b") and eng.mb_moved == 120.0


def test_concurrent_fetches_for_same_destination_piggyback():
    with virtual_time(auto_advance=False) as clock:
        reg, eng = _engine()
        reg.add("d", 50.0, sites=["a"])
        done = []
        eng.fetch("d", "b", done.append)
        eng.fetch("d", "b", done.append)  # same (dataset, dst): no 2nd copy
        assert eng.active_transfers() == 1
        clock.advance(60.0)
        assert done == [True, True]
        assert eng.completed == 1 and eng.mb_moved == 50.0


def test_per_link_concurrency_queues_excess_transfers():
    with virtual_time(auto_advance=False) as clock:
        reg, eng = _engine(seed=1, max_per_link=2)
        for i in range(3):
            reg.add(f"d{i}", 100.0, sites=["a"])
        done = []
        for i in range(3):
            eng.fetch(f"d{i}", "b", done.append)
        assert eng.active_transfers() == 2 and eng.queued_transfers() == 1
        for _ in range(3):  # queued transfer starts only when a slot frees
            clock.advance(500.0)
        assert done == [True, True, True]
        assert eng.queue_wait_s > 0.0  # the third transfer waited for a slot


def _transfer_schedule(seed: int):
    with virtual_time(auto_advance=False) as clock:
        reg, eng = _engine(seed=seed, max_per_link=2)
        for i in range(6):
            reg.add(f"d{i}", 80.0 + 30.0 * i, sites=["shared"])
        results = []
        for i in range(6):
            eng.fetch(f"d{i}", ("a", "b", "c")[i % 3], results.append)
        for _ in range(300):
            if eng.completed == 6:
                break
            clock.advance(1.0)
        assert eng.completed == 6
        return [(r["dataset"], r["src"], r["dst"], round(r["t"], 9)) for r in eng.log]


def test_transfer_schedule_deterministic_under_virtual_clock():
    # same seed => byte-for-byte identical completion schedule; a different
    # seed draws different bandwidth samples and reorders completions
    assert _transfer_schedule(7) == _transfer_schedule(7)
    assert _transfer_schedule(7) != _transfer_schedule(8)


def test_source_site_death_reroutes_active_transfer():
    with virtual_time(auto_advance=False) as clock:
        reg, eng = _engine()
        reg.add("d", 200.0, sites=["a", "shared"])  # a is the faster source
        done = []
        eng.fetch("d", "b", done.append)
        (tr,) = [t for trs in eng._active.values() for t in trs]
        assert tr.src == "a"
        lost = eng.site_down("a")  # mid-flight: replica set shrinks to shared
        assert lost == []  # shared still holds a copy
        clock.advance(500.0)
        assert done == [True]
        assert eng.reroutes == 1 and reg.resident("d", "b")


def test_site_death_with_last_replica_fails_waiters():
    with virtual_time(auto_advance=False):
        reg, eng = _engine()
        reg.add("d", 100.0, sites=["a"])  # ONLY copy lives on a
        done = []
        eng.fetch("d", "b", done.append)
        lost = eng.site_down("a")
        assert lost == ["d"]
        assert done == [False]  # no surviving source: waiters see failure


# ---------------------------------------------------------------------------
# Data-gravity policy
# ---------------------------------------------------------------------------


def test_data_gravity_policy_prefers_replica_holding_provider():
    svc = StagingService()
    svc.register_site("a", "cloud")
    svc.register_site("b", "cloud")
    svc.registry.add("hot", 1000.0, sites=["a"])
    pol = make_policy("data_gravity")
    pol.attach_staging(svc)
    ha = ProviderHandle(spec=ProviderSpec(name="a"))
    hb = ProviderHandle(spec=ProviderSpec(name="b"))
    t = Task(kind="noop", inputs=["hot"])
    assert pol.bind(t, [ha, hb]) == "a"
    # and the cold target was charged the modeled transfer, not zero
    assert pol.data_cost_s(t, "b") > 0.0 == pol.data_cost_s(t, "a")


def test_data_gravity_ships_bytes_when_local_queue_is_long():
    svc = StagingService()
    svc.register_site("a", "cloud")
    svc.register_site("b", "cloud")
    svc.registry.add("small", 1.0, sites=["a"])
    pol = make_policy("data_gravity")
    pol.attach_staging(svc)
    pol.observe("a", 10.0)  # a is slow and
    pol.observe("b", 10.0)
    ha = ProviderHandle(spec=ProviderSpec(name="a"))
    hb = ProviderHandle(spec=ProviderSpec(name="b"))
    for _ in range(5):  # ... deeply queued
        pol.bind(Task(kind="noop"), [ha])
    t = Task(kind="noop", inputs=["small"])
    # 1MB transfer (~0.06s) beats waiting behind 5 x 10s of queue: ship it
    assert pol.bind(t, [ha, hb]) == "b"


# ---------------------------------------------------------------------------
# Dispatcher integration: stage-in gate + stage-out
# ---------------------------------------------------------------------------


def test_stage_in_before_dispatch_and_stage_out_on_completion(tmp_path):
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            policy="data_gravity",
            streaming=True,
            batch_window=0.001,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="a", platform="cloud"))
        h.register_provider(ProviderSpec(name="b", platform="hpc", connector="pilot"))
        h.staging.registry.add("in0", 256.0, sites=["shared"], pinned=True)
        wf = Workflow(name="stagewf")
        t1 = wf.add(Task(kind="noop", inputs=["in0"], outputs={"mid": 64.0}))
        t2 = wf.add(Task(kind="noop", inputs=["mid"], outputs={"out": 8.0}), deps=[t1])
        WorkflowManager(h).run([wf], timeout=120)
        assert wf.done and not wf.failed
        stats = h.staging_stats()
        # one cold pull of in0; t2 rode gravity to t1's site, replica-free
        assert stats["mb_moved"] == 256.0
        assert stats["cold_reads"] == 1 and stats["cache_hits"] >= 1
        assert stats["stage_outs"] == 2  # mid + out registered on completion
        assert "stage_in_start" in " ".join(e for e, _ in t1.trace.events)
        assert t2.provider == t1.provider  # data gravity kept the chain local
        assert h.staging.registry.resident("out", t2.provider)
        h.shutdown(wait=True)


def test_replica_blind_arm_moves_more_bytes_30pct(tmp_path):
    """The exp8 acceptance criterion at mini scale: locality-aware placement
    moves >= 30% fewer bytes than locality-blind at 4 sites."""

    def run_arm(policy: str) -> float:
        with virtual_time():
            h = Hydra(
                pod_store="memory",
                policy=policy,
                streaming=True,
                batch_window=0.001,
                workdir=str(tmp_path / policy),
            )
            for name, platform in (
                ("jet2", "cloud"),
                ("chi", "cloud"),
                ("aws", "cloud"),
                ("bridges2", "hpc"),
            ):
                h.register_provider(
                    ProviderSpec(
                        name=name,
                        platform=platform,
                        connector="pilot" if platform == "hpc" else "caas",
                        concurrency=4,
                    )
                )
            for k in range(3):
                h.staging.registry.add(
                    f"shard-{k}", 512.0, sites=["shared"], pinned=True
                )
            wfs = []
            for i in range(9):
                wf = Workflow(name=f"mini8.{i}-{policy}")
                t1 = wf.add(
                    Task(
                        kind="sleep",
                        duration=1.0,
                        inputs=[f"shard-{i % 3}"],
                        outputs={f"m{i}-{policy}/a": 256.0},
                    )
                )
                wf.add(
                    Task(
                        kind="sleep",
                        duration=1.0,
                        inputs=[f"m{i}-{policy}/a"],
                        outputs={f"m{i}-{policy}/b": 16.0},
                    ),
                    deps=[t1],
                )
                wfs.append(wf)
            WorkflowManager(h).run(wfs, timeout=600)
            assert all(w.done and not w.failed for w in wfs)
            moved = h.staging_stats()["mb_moved"]
            h.shutdown(wait=True)
        return moved

    blind = run_arm("round_robin")
    aware = run_arm("data_gravity")
    assert aware <= 0.7 * blind, f"aware={aware} blind={blind}"


def test_unknown_input_fails_task_without_dropping_batchmates(tmp_path):
    """Regression: an input name never registered used to raise out of the
    staging gate and silently drop the whole popped batch (hanging every
    batch-mate); now the bad task surfaces StagingError and the rest run."""
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.001,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="a"))
        good = Task(kind="noop")
        bad = Task(kind="noop", inputs=["never-registered"])
        h.dispatch([bad, good])
        assert wait_until(lambda: good.done() and bad.done(), timeout=10.0)
        assert good.exception() is None
        assert isinstance(bad.exception(), StagingError)
        h.shutdown(wait=True)


def test_drain_waits_for_staging_blocked_tasks(tmp_path):
    """Regression: drain() used to report idle while tasks were parked on
    stage-in (out of the ready heap but still owed a dispatch)."""
    with virtual_time(auto_advance=False) as clock:
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="a"))
        h.staging.registry.add("d", 300.0, sites=["shared"], pinned=True)
        t = Task(kind="noop", inputs=["d"])
        h.dispatch([t])
        d = h.dispatcher()
        assert wait_until(lambda: d.stalled_on_staging() == 1)
        assert not d.drain(timeout=0.2)  # parked task: NOT idle
        ok = wait_until(lambda: (clock.advance(5.0), t.done())[1], timeout=10.0)
        assert ok and t.exception() is None
        assert d.drain(timeout=5.0)
        h.shutdown(wait=True)


def test_registry_resize_keeps_capacity_accounting_consistent():
    """Regression: re-declaring a dataset at a new size left used_mb
    accounted at the old size wherever replicas already lived."""
    reg = DatasetRegistry()
    reg.register_site("s", "cloud", capacity_mb=300.0)
    reg.add("x", 100.0, sites=["shared"])
    reg.place_replica("x", "s")
    reg.add("x", 200.0)  # retry re-declares the output bigger
    assert reg.used_mb("s") == 200.0
    reg.drop_replica("x", "s")
    assert reg.used_mb("s") == 0.0


# ---------------------------------------------------------------------------
# Autoscaler: staging-stalled tasks are decayed (not zero, not full) demand
# ---------------------------------------------------------------------------


def test_autoscaler_pressure_counts_parked_tasks_as_decayed_demand(tmp_path):
    """Regression for the parked-demand blind spot: tasks parked on
    stage-in used to contribute ZERO demand, so a data-heavy burst left the
    fleet cold until the bytes landed — then every transfer completed into
    an undersized pool (the at-scale preset papered over it with a
    min_instances=2 warm floor).  Freshly parked tasks now count at ~full
    weight, decaying exponentially as they age, so long-stuck transfers
    stop buying capacity."""
    with virtual_time(auto_advance=False):
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="a", platform="cloud", concurrency=2))
        h.staging.registry.add("big", 4096.0, sites=["shared"], pinned=True)
        tasks = [Task(kind="noop", inputs=["big"]) for _ in range(8)]
        h.dispatch(tasks)
        d = h.dispatcher()
        # the clock never advances, so every task parks on its stage-in
        assert wait_until(lambda: d.stalled_on_staging() == 8)
        assert d.pending() == 0  # parked OUTSIDE the ready heap
        pool = ProviderPool(
            [LaunchSpec(template=ProviderSpec(name="elastic", platform="cloud"),
                        latency=cloud_startup())]
        )
        scaler = Autoscaler(h, pool)  # not started: we only read the signal
        # freshly parked: ~8 slots of deferred demand against 2 live slots
        fresh = scaler.pressure()
        assert 3.5 <= fresh <= 4.0, fresh
        # age the herd WITHOUT advancing the clock (that would fire the
        # frozen transfer timers and unpark everyone): backdate the park
        # stamps by 5*tau — the stuck herd decays to <1% of a slot each
        with d._lock:
            for uid in d._blocked_at:
                d._blocked_at[uid] -= 300.0
        aged = scaler.pressure()
        assert aged < fresh * 0.01, (fresh, aged)
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Chaos: provider death mid-transfer
# ---------------------------------------------------------------------------


def test_provider_death_mid_transfer_reroutes_and_no_task_fails(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="a", platform="cloud"))
        h.register_provider(ProviderSpec(name="b", platform="cloud"))
        # replica on a (fast intra-cloud source) + shared (survivor)
        h.staging.registry.add("d", 600.0, sites=["shared"], pinned=True)
        h.staging.registry.place_replica("d", "a")
        t = Task(kind="noop", inputs=["d"], provider="b")  # pin forces a pull
        h.dispatch([t])
        eng = h.staging.engine
        assert wait_until(lambda: eng.active_transfers() == 1)
        (tr,) = [x for trs in eng._active.values() for x in trs]
        assert tr.src == "a"  # the faster cloud->cloud link won the pick
        h.remove_provider("a", drain=False, deregister=True)  # dies mid-flight
        # drive virtual time until the re-routed transfer lands and the task
        # dispatches, runs, and completes — with ZERO failed tasks
        ok = wait_until(
            lambda: (clock.advance(5.0), t.done())[1], timeout=10.0
        )
        assert ok and t.exception() is None
        assert eng.reroutes == 1
        assert h.staging.registry.resident("d", "b")
        assert h.staging_stats()["transfer_failures"] == 0
        h.shutdown(wait=True)


def test_provider_death_as_source_and_reserved_target_recovers_both(tmp_path):
    """Correlated chaos regression: ONE provider dies while it is BOTH the
    source of an in-flight transfer (another task's pull) AND the reserved
    placement target of a task parked at the staging gate.  The transfer
    must re-route to a surviving replica and the parked task must re-gate
    to a surviving placement — zero failed tasks."""
    with virtual_time(auto_advance=False) as clock:
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="a", platform="cloud"))
        h.register_provider(ProviderSpec(name="b", platform="cloud"))
        # src_d: replica on a (the fast source) + shared (the survivor)
        h.staging.registry.add("src_d", 600.0, sites=["shared"], pinned=True)
        h.staging.registry.place_replica("src_d", "a")
        # gate_d: shared only; t2 pins to a, so the gate reserves a and
        # stages shared -> a
        h.staging.registry.add("gate_d", 400.0, sites=["shared"], pinned=True)
        t1 = Task(kind="noop", inputs=["src_d"], provider="b")  # a -> b pull
        t2 = Task(kind="noop", inputs=["gate_d"], provider="a")
        h.dispatch([t1, t2])
        eng = h.staging.engine
        assert wait_until(lambda: eng.active_transfers() == 2)
        assert t2.reserved_provider == "a"  # parked at the gate, target a
        h.remove_provider("a", drain=False, deregister=True)  # dies wearing both hats
        ok = wait_until(
            lambda: (clock.advance(5.0), t1.done() and t2.done())[1], timeout=20.0
        )
        assert ok
        assert t1.exception() is None and t2.exception() is None
        assert eng.reroutes >= 1  # t1's pull restarted from the shared replica
        assert t2.staging_attempts >= 1  # t2 re-entered the gate after the loss
        assert t2.provider == "b"
        assert h.staging.registry.resident("src_d", "b")
        assert h.staging.registry.resident("gate_d", "b")
        h.shutdown(wait=True)


def test_dead_reservation_is_released_and_regated(tmp_path):
    """A task that reaches the gate still carrying a reservation on a
    now-dead provider must shed it (trace-visible) and re-bind — not let
    bind_bulk silently re-choose a site its inputs never reached."""
    h = Hydra(pod_store="memory", streaming=True, batch_window=0.001, workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="b", platform="cloud"))
    h.staging.registry.add("in0", 20.0, sites=["shared"], pinned=True)
    t = Task(kind="sleep", duration=0.01, inputs=["in0"])
    t.reserved_provider = "ghost"  # reservation whose target no longer exists
    h.dispatch([t])
    assert wait_until(lambda: t.done(), timeout=10.0)
    assert t.exception() is None
    assert "regate:ghost" in [e for e, _ in t.trace.events]
    assert t.provider == "b"
    h.shutdown(wait=True)


def test_graceful_drain_evacuates_last_copy_data(tmp_path):
    """Regression: an elastic scale-in (voluntary drain) used to destroy the
    only replica of intermediate stage-out data, terminally failing queued
    downstream tasks; the drain now spills last copies to the shared store.
    A hard outage (drain=False) still loses the site's data — that is the
    chaos scenario, not this one."""
    from repro.core.managers.data import UnknownSiteError

    h = Hydra(pod_store="memory", streaming=True, workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="a"))
    h.register_provider(ProviderSpec(name="b"))
    h.staging.registry.add("solo", 50.0)
    h.staging.registry.place_replica("solo", "a")  # ONLY copy, on a
    h.remove_provider("a", drain=True, deregister=True)
    assert h.staging.registry.locate("solo") == ["shared"]
    assert h.staging_stats()["evacuated_mb"] == 50.0
    # and the physical namespace is closed: no stranding data on dead sites
    with pytest.raises(UnknownSiteError):
        h.data.put_bytes("a", "x.bin", b"nope")
    h.shutdown(wait=True)


def test_failover_rebinds_io_tasks_through_the_gate(tmp_path):
    """Regression: the broker's failover re-bind used to dispatch a task
    with declared inputs straight to the surviving provider — a site its
    inputs were never staged to.  It must re-enter through the gate."""
    h = Hydra(
        pod_store="memory",
        streaming=True,
        batch_window=0.001,
        workdir=str(tmp_path),
    )
    h.register_provider(ProviderSpec(name="a", platform="cloud"))
    h.register_provider(ProviderSpec(name="b", platform="cloud"))
    h.staging.registry.add("in0", 20.0, sites=["shared"], pinned=True)
    t = Task(kind="sleep", duration=1.0, inputs=["in0"], provider="a")
    h.dispatch([t])
    from repro.core import TaskState

    assert wait_until(lambda: t.tstate == TaskState.RUNNING, timeout=10.0)
    h.remove_provider("a", drain=False, deregister=True)  # mid-execution
    assert wait_until(lambda: t.done(), timeout=10.0)
    assert t.exception() is None
    assert t.provider == "b"
    assert "rebind_via_gate" in [e for e, _ in t.trace.events]
    assert h.staging.registry.resident("in0", "b")  # staged before re-run
    h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# DataManager <-> registry coherence
# ---------------------------------------------------------------------------


def test_physical_verbs_update_logical_replicas(tmp_path):
    from repro.core.managers.data import DataManager

    reg = DatasetRegistry()
    reg.register_site("jet2", "cloud")
    reg.register_site("aws", "cloud")
    reg.add("blob.bin", 10.0)
    dm = DataManager(str(tmp_path))
    dm.attach_registry(reg)
    dm.register_site("jet2")
    dm.register_site("aws")
    dm.put_bytes("jet2", "blob.bin", b"payload")
    assert reg.locate("blob.bin") == ["jet2"]
    dm.copy("jet2", "blob.bin", "aws", "blob.bin")
    assert reg.locate("blob.bin") == ["aws", "jet2"]
    dm.delete("jet2", "blob.bin")
    assert reg.locate("blob.bin") == ["aws"]
    dm.move("aws", "blob.bin", "shared", "blob.bin")
    assert reg.locate("blob.bin") == ["shared"]
