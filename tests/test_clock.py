"""Clock layer: wall/virtual semantics, registry scoping, trace integration."""
import threading
import time

from repro.runtime import tracing
from repro.runtime.clock import (
    VirtualClock,
    WallClock,
    get_clock,
    use_clock,
    virtual_time,
)


def test_wall_clock_tracks_real_time():
    c = WallClock()
    t0 = c.now()
    c.sleep(0.01)
    assert c.now() - t0 >= 0.009


def test_default_clock_is_wall():
    assert get_clock().name == "wall"


def test_virtual_manual_advance():
    c = VirtualClock(start=100.0, auto_advance=False)
    assert c.now() == 100.0
    c.advance(5.0)
    assert c.now() == 105.0
    c.advance_to(50.0)  # never goes backwards
    assert c.now() == 105.0
    c.close()


def test_virtual_sleep_wakes_at_exact_deadline():
    with virtual_time() as c:
        woke = []

        def sleeper():
            c.sleep(10.0)
            woke.append(c.now())

        th = threading.Thread(target=sleeper)
        th.start()
        th.join(timeout=5.0)
        assert not th.is_alive()
        assert woke == [10.0]  # exact virtual deadline, not a noisy wall time


def test_virtual_sleep_many_same_deadline_one_tick():
    # manual advance: deterministic regardless of thread start-up latency
    c = VirtualClock(auto_advance=False)
    n = 16
    done = threading.Barrier(n + 1, timeout=10.0)

    def sleeper():
        c.sleep(3.0)
        done.wait()

    for _ in range(n):
        threading.Thread(target=sleeper, daemon=True).start()
    deadline = time.time() + 10.0
    while c.pending_deadlines() < n and time.time() < deadline:
        time.sleep(0.001)
    assert c.pending_deadlines() == n
    c.advance(3.0)  # one tick wakes the whole cohort
    done.wait()
    assert c.now() == 3.0
    c.close()


def test_virtual_wait_event_timeout_and_signal():
    with virtual_time() as c:
        ev = threading.Event()
        assert c.wait_event(ev, timeout=5.0) is False  # virtual timeout elapses
        assert c.now() >= 5.0
        ev.set()
        assert c.wait_event(ev, timeout=5.0) is True


def test_close_releases_parked_sleepers():
    c = VirtualClock(auto_advance=False)
    released = threading.Event()

    def sleeper():
        c.sleep(1e9)
        released.set()

    th = threading.Thread(target=sleeper, daemon=True)
    th.start()
    time.sleep(0.02)
    c.close()
    assert released.wait(timeout=5.0)


def test_use_clock_scopes_and_restores():
    before = get_clock()
    c = VirtualClock(auto_advance=False)
    with use_clock(c):
        assert get_clock() is c
    assert get_clock() is before
    c.close()


def test_tracing_now_follows_active_clock():
    with virtual_time(start=42.0) as _:
        tr = tracing.Trace()
        tr.add("evt")
        assert tr.events[0][1] == 42.0
    assert tracing.now() > 0  # back on wall time


def test_trace_timestamps_monotonic_under_virtual_time():
    with virtual_time() as c:
        tr = tracing.Trace()
        for i in range(5):
            tr.add(f"e{i}")
            c.advance(1.0)
        ts = [t for _, t in tr.events]
        assert ts == sorted(ts) and ts == [0.0, 1.0, 2.0, 3.0, 4.0]
