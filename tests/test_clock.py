"""Clock layer: wall/virtual semantics, registry scoping, trace integration,
delayed callbacks (call_later), and the guard_wait idle valve."""
import threading
import time

from repro.runtime import tracing
from repro.runtime.clock import (
    VirtualClock,
    WallClock,
    get_clock,
    guard_wait,
    use_clock,
    virtual_time,
)


def test_wall_clock_tracks_real_time():
    c = WallClock()
    t0 = c.now()
    c.sleep(0.01)
    assert c.now() - t0 >= 0.009


def test_default_clock_is_wall():
    assert get_clock().name == "wall"


def test_virtual_manual_advance():
    c = VirtualClock(start=100.0, auto_advance=False)
    assert c.now() == 100.0
    c.advance(5.0)
    assert c.now() == 105.0
    c.advance_to(50.0)  # never goes backwards
    assert c.now() == 105.0
    c.close()


def test_virtual_sleep_wakes_at_exact_deadline():
    with virtual_time() as c:
        woke = []

        def sleeper():
            c.sleep(10.0)
            woke.append(c.now())

        th = threading.Thread(target=sleeper)
        th.start()
        th.join(timeout=5.0)
        assert not th.is_alive()
        assert woke == [10.0]  # exact virtual deadline, not a noisy wall time


def test_virtual_sleep_many_same_deadline_one_tick():
    # manual advance: deterministic regardless of thread start-up latency
    c = VirtualClock(auto_advance=False)
    n = 16
    done = threading.Barrier(n + 1, timeout=10.0)

    def sleeper():
        c.sleep(3.0)
        done.wait()

    for _ in range(n):
        threading.Thread(target=sleeper, daemon=True).start()
    deadline = time.time() + 10.0
    while c.pending_deadlines() < n and time.time() < deadline:
        time.sleep(0.001)
    assert c.pending_deadlines() == n
    c.advance(3.0)  # one tick wakes the whole cohort
    done.wait()
    assert c.now() == 3.0
    c.close()


def test_virtual_wait_event_timeout_and_signal():
    with virtual_time() as c:
        ev = threading.Event()
        assert c.wait_event(ev, timeout=5.0) is False  # virtual timeout elapses
        assert c.now() >= 5.0
        ev.set()
        assert c.wait_event(ev, timeout=5.0) is True


def test_close_releases_parked_sleepers():
    c = VirtualClock(auto_advance=False)
    released = threading.Event()

    def sleeper():
        c.sleep(1e9)
        released.set()

    th = threading.Thread(target=sleeper, daemon=True)
    th.start()
    time.sleep(0.02)
    c.close()
    assert released.wait(timeout=5.0)


def test_use_clock_scopes_and_restores():
    before = get_clock()
    c = VirtualClock(auto_advance=False)
    with use_clock(c):
        assert get_clock() is c
    assert get_clock() is before
    c.close()


def test_tracing_now_follows_active_clock():
    with virtual_time(start=42.0) as _:
        tr = tracing.Trace()
        tr.add("evt")
        assert tr.events[0][1] == 42.0
    assert tracing.now() > 0  # back on wall time


def test_call_later_wall_clock_fires():
    c = WallClock()
    fired = threading.Event()
    c.call_later(0.01, fired.set)
    assert fired.wait(timeout=5.0)


def test_call_later_virtual_manual_advance():
    c = VirtualClock(auto_advance=False)
    fired = []
    c.call_later(10.0, lambda: fired.append(c.now()))
    c.advance(9.999)
    assert fired == []
    c.advance(0.001)
    assert fired == [10.0]  # fires at the exact virtual deadline
    c.close()


def test_call_later_counts_as_pending_deadline_and_auto_advances():
    with virtual_time() as c:
        fired = threading.Event()
        c.call_later(60.0, fired.set)
        assert c.pending_deadlines() == 1
        # the auto-advancer must jump to the timer deadline on its own
        assert fired.wait(timeout=5.0)
        assert c.now() >= 60.0


def test_call_later_cancel_prevents_firing():
    c = VirtualClock(auto_advance=False)
    fired = []
    call = c.call_later(5.0, lambda: fired.append(1))
    assert call.cancel() is True
    c.advance(10.0)
    assert fired == []
    assert call.cancel() is False  # second cancel reports already-dead
    c.close()


def test_call_later_zero_delay_fires_immediately():
    c = VirtualClock(auto_advance=False)
    fired = []
    c.call_later(0.0, lambda: fired.append(c.now()))
    assert fired == [0.0]
    c.close()


def test_guard_wait_idle_virtual_clock_elapses_at_virtual_deadline():
    # Regression (Submission.wait bug): with NO tasks in flight — no
    # sleepers, no timers, frozen virtual time — a guard_wait(timeout=60)
    # used to block for 60 *real* seconds.  The idle valve must register the
    # deadline and let the auto-advancer jump to it within a grace window.
    with virtual_time() as c:
        ev = threading.Event()
        t0 = time.monotonic()
        assert guard_wait(ev, timeout=60.0) is False
        assert time.monotonic() - t0 < 5.0  # did not burn the real budget
        assert c.now() >= 60.0  # elapsed on the VIRTUAL clock


def test_guard_wait_event_still_wins_under_virtual_clock():
    with virtual_time():
        ev = threading.Event()
        threading.Timer(0.05, ev.set).start()
        assert guard_wait(ev, timeout=300.0) is True


def test_guard_wait_in_flight_keeps_idle_valve_closed():
    # Pure-CPU work never touches the clock, so the clock LOOKS idle; an
    # in_flight=True caller must keep the valve closed (real-time bound
    # applies) instead of jumping the virtual clock to the timeout and
    # reporting a phantom timeout while real work still runs.
    with virtual_time() as c:
        ev = threading.Event()
        threading.Timer(0.6, ev.set).start()  # "real work" finishing late
        t0 = time.monotonic()
        assert guard_wait(ev, timeout=1000.0, in_flight=lambda: True) is True
        assert time.monotonic() - t0 >= 0.5  # waited for the real work
        assert c.now() < 1000.0  # virtual clock was NOT jumped to the guard


def test_submission_wait_idle_virtual_clock_returns_at_virtual_deadline():
    # The user-facing shape of the same bug: a submission whose tasks can
    # never resolve (no providers ever dispatch them) must not turn
    # wait(timeout=virtual_seconds) into a real-time hang.
    from repro.core.broker import Submission
    from repro.core.task import Task

    with virtual_time():
        sub = Submission([Task(kind="noop")], broker=None)
        t0 = time.monotonic()
        assert sub.wait(timeout=45.0) is False
        assert time.monotonic() - t0 < 5.0


def test_trace_timestamps_monotonic_under_virtual_time():
    with virtual_time() as c:
        tr = tracing.Trace()
        for i in range(5):
            tr.add(f"e{i}")
            c.advance(1.0)
        ts = [t for _, t in tr.events]
        assert ts == sorted(ts) and ts == [0.0, 1.0, 2.0, 3.0, 4.0]
