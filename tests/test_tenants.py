"""Multi-tenant front door: token buckets, bounded queues, typed
backpressure, weighted-fair lane drain, and interactive SLO preemption.

Everything timed runs under a VirtualClock, so bucket refills and flood
latencies are exact and the whole file costs real seconds.
"""
import threading

import pytest

from repro.core import Hydra, ProviderSpec, Task
from repro.core.admission import (
    AdmissionController,
    AdmissionError,
    TenantSpec,
    TokenBucket,
)
from repro.core.policy import apportion_budget
from repro.runtime.clock import virtual_time

from _hypothesis_compat import given, settings, st
from conftest import wait_until


# ---------------------------------------------------------------------------
# TokenBucket
# ---------------------------------------------------------------------------


def test_token_bucket_refill_and_reject():
    with virtual_time(auto_advance=False) as clock:
        b = TokenBucket(rate=10.0, burst=5.0)
        assert b.take(5)  # drain the burst
        assert not b.take(1)  # empty: reject, no partial charge
        assert b.available() == pytest.approx(0.0)
        clock.advance(0.3)  # 10/s * 0.3s = 3 tokens back
        assert b.available() == pytest.approx(3.0)
        assert b.take(3)
        assert not b.take(1)
        clock.advance(10.0)  # refill caps at burst, not rate * elapsed
        assert b.available() == pytest.approx(5.0)


def test_token_bucket_wait_hint_and_refund():
    with virtual_time(auto_advance=False) as clock:
        b = TokenBucket(rate=2.0, burst=4.0)
        assert b.take(4)
        # 3 tokens at 2/s: ready in 1.5 virtual seconds
        assert b.wait_hint_s(3) == pytest.approx(1.5)
        b.put(2)  # rollback refund
        assert b.available() == pytest.approx(2.0)
        b.put(100)  # refund never exceeds burst
        assert b.available() == pytest.approx(4.0)
        clock.advance(1.0)
        assert b.available() == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# AdmissionController
# ---------------------------------------------------------------------------


def test_admission_rate_limit_rejects_with_typed_error():
    with virtual_time(auto_advance=False) as clock:
        ctl = AdmissionController([TenantSpec(name="t", rate=5.0, burst=5.0)])
        ctl.admit([Task(tenant="t") for _ in range(5)])
        with pytest.raises(AdmissionError) as ei:
            ctl.admit([Task(tenant="t")])
        assert ei.value.tenant == "t"
        assert ei.value.reason == "rate_limited"
        assert ei.value.retry_after_s == pytest.approx(0.2)
        clock.advance(1.0)  # 5 tokens back
        ctl.admit([Task(tenant="t") for _ in range(5)])
        assert ctl.stats()["rejected"] == {"t:rate_limited": 1}


def test_admission_queue_bound_and_release_on_resolution():
    with virtual_time(auto_advance=False):
        ctl = AdmissionController([TenantSpec(name="t", max_queued=3)])
        tasks = [Task(tenant="t") for _ in range(3)]
        ctl.admit(tasks)
        assert ctl.held("t") == 3
        with pytest.raises(AdmissionError) as ei:
            ctl.admit([Task(tenant="t")])
        assert ei.value.reason == "queue_full"
        assert ei.value.retry_after_s is None  # wait for completions, not a timer
        # resolution frees the slot, whatever the resolution path
        tasks[0].mark_done()
        tasks[1].mark_canceled()
        assert ctl.held("t") == 1
        ctl.admit([Task(tenant="t"), Task(tenant="t")])
        assert ctl.held("t") == 3
        # release is idempotent: an explicit release after the callback is a no-op
        ctl.release(tasks[0])
        assert ctl.held("t") == 3


def test_admission_is_all_or_nothing_across_tenants():
    """A rejection for one tenant's group must refund every other group the
    same call already charged — a partial admit would strand held slots (and
    tokens) on tasks that will never enter the system."""
    with virtual_time(auto_advance=False):
        ctl = AdmissionController(
            [
                TenantSpec(name="a", rate=100.0, burst=100.0, max_queued=10),
                TenantSpec(name="b", max_queued=2),
            ]
        )
        mixed = [Task(tenant="a") for _ in range(4)] + [Task(tenant="b") for _ in range(3)]
        with pytest.raises(AdmissionError) as ei:
            ctl.admit(mixed)
        assert ei.value.tenant == "b" and ei.value.reason == "queue_full"
        assert ctl.held("a") == 0 and ctl.held("b") == 0
        bucket = ctl._buckets["a"]
        assert bucket.available() == pytest.approx(100.0)  # tokens refunded
        assert all(not t.admitted for t in mixed)  # nothing committed


def test_admission_exempts_already_admitted_requeues():
    with virtual_time(auto_advance=False):
        ctl = AdmissionController([TenantSpec(name="t", rate=1.0, burst=1.0)])
        (t,) = [Task(tenant="t")]
        ctl.admit([t])
        # an internal requeue (retry / failover / staging re-gate) re-enters
        # without being re-charged: the bucket is empty and this must pass
        ctl.admit([t])
        assert ctl.held("t") == 1


def test_unconfigured_tenant_is_unlimited():
    with virtual_time(auto_advance=False):
        ctl = AdmissionController()
        ctl.admit([Task() for _ in range(10_000)])
        assert ctl.weight("anyone") == 1.0


def test_broker_dispatch_raises_typed_backpressure():
    with virtual_time(auto_advance=False):
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            tenants=[TenantSpec(name="t", max_queued=8)],
        )
        h.register_provider(ProviderSpec(name="p", concurrency=2))
        h.dispatch([Task(kind="noop", tenant="t") for _ in range(8)])
        with pytest.raises(AdmissionError):
            h.dispatch([Task(kind="noop", tenant="t")])
        assert h.tenant_stats()["rejected"] == {"t:queue_full": 1}
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# apportion_budget: weighted fairness, deficits, no starvation
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(
    st.integers(1, 64),  # budget per round
    st.lists(st.integers(0, 50), min_size=1, max_size=6),  # demands
    st.integers(0, 5),  # weight pattern selector
)
def test_apportion_never_starves_a_nonzero_weight_lane(budget, demands, wsel):
    """Property: over repeated rounds with carried deficits, every lane with
    demand > 0 and weight > 0 receives at least one grant — however skewed
    the weights — and per-round invariants hold."""
    n = len(demands)
    patterns = [
        [1.0] * n,
        [float(i + 1) for i in range(n)],
        [100.0] + [0.1] * (n - 1),
        [0.5] * n,
        [1000.0 if i == n - 1 else 1.0 for i in range(n)],
        [0.0 if i % 2 else 1.0 for i in range(n)],  # zero-weight lanes exist
    ]
    weights = patterns[wsel % len(patterns)]
    left = list(demands)
    served = [0] * n
    carry = [0.0] * n
    for _ in range(200):
        if not any(left[i] for i in range(n) if weights[i] > 0):
            break
        grants, carry = apportion_budget(budget, left, weights, carry)
        assert sum(grants) <= budget
        for i, g in enumerate(grants):
            assert 0 <= g <= left[i]
            left[i] -= g
            served[i] += g
    for i in range(n):
        if demands[i] > 0 and weights[i] > 0:
            assert served[i] > 0, (budget, demands, weights, served)
            assert left[i] == 0  # bounded demand fully drains, never wedges


def test_apportion_weight_ratio_shapes_the_split():
    grants, _ = apportion_budget(30, [100, 100], [2.0, 1.0], None)
    assert sum(grants) == 30
    assert grants[0] == 20 and grants[1] == 10


def test_apportion_weightless_lanes_round_robin():
    # all weights zero: plain round-robin rather than a division by zero
    grants, carry = apportion_budget(5, [10, 10], [0.0, 0.0], None)
    assert sum(grants) == 5 and min(grants) >= 2
    assert carry == [0.0, 0.0]


# ---------------------------------------------------------------------------
# Dispatcher drain order: SLO-class preemption + weighted fairness
# ---------------------------------------------------------------------------


def _virtual_finish_times(tasks):
    return [t.trace.last("exec_done") for t in tasks]


def test_interactive_preempts_queued_batch_backfill():
    """Late-arriving interactive tasks overtake thousands of already-queued
    batch tasks: queued (never running) backfill is preempted."""
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            tenants=[TenantSpec(name="serve", weight=1.0)],
        )
        h.register_provider(ProviderSpec(name="p", concurrency=4))
        flood = [
            Task(kind="sleep", duration=0.1, tenant="bulk", slo_class="batch")
            for _ in range(2000)
        ]
        h.dispatch(flood)
        # the flood is queued; now the interactive requests arrive LATE
        serve = [
            Task(kind="sleep", duration=0.1, tenant="serve", slo_class="interactive")
            for _ in range(20)
        ]
        h.dispatch(serve)
        for t in flood + serve:
            assert t.result(timeout=120) is None
        makespan = max(_virtual_finish_times(flood))
        serve_done = max(_virtual_finish_times(serve))
        # 2020 * 0.1s over 4 slots ~ 50s of virtual makespan; the 20
        # interactive tasks (0.5s of work) must clear almost immediately
        assert makespan > 20.0
        assert serve_done < 5.0, (serve_done, makespan)
        h.shutdown(wait=True)


def test_weighted_fair_split_between_batch_tenants():
    """Two batch tenants at 3:1 weight: early completions skew ~3:1 while
    both lanes stay live (no starvation of the light tenant)."""
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            tenants=[
                TenantSpec(name="heavy", weight=3.0),
                TenantSpec(name="light", weight=1.0),
            ],
        )
        h.register_provider(ProviderSpec(name="p", concurrency=8))
        heavy = [Task(kind="sleep", duration=0.2, tenant="heavy") for _ in range(400)]
        light = [Task(kind="sleep", duration=0.2, tenant="light") for _ in range(400)]
        h.dispatch(heavy)
        h.dispatch(light)
        for t in heavy + light:
            assert t.result(timeout=120) is None
        cutoff = max(max(_virtual_finish_times(heavy)), max(_virtual_finish_times(light))) / 2
        h_early = sum(1 for ts in _virtual_finish_times(heavy) if ts <= cutoff)
        l_early = sum(1 for ts in _virtual_finish_times(light) if ts <= cutoff)
        assert l_early > 0  # the light lane is never starved
        assert h_early > l_early * 1.5, (h_early, l_early)
        h.shutdown(wait=True)


def test_interactive_p99_bounded_under_10k_flood():
    """The front-door acceptance shape at test scale: a 10k-task batch flood
    must not blow up interactive p99 — the same steady trickle of requests
    finishes in near-unloaded time because the interactive lane drains
    first every round."""
    with virtual_time():
        def run(flood_n: int) -> float:
            h = Hydra(
                pod_store="memory",
                streaming=True,
                batch_window=0.0,
                max_batch=64,
                tenants=[TenantSpec(name="serve", weight=1.0)],
            )
            h.register_provider(ProviderSpec(name="p", concurrency=16))
            if flood_n:
                h.dispatch(
                    [
                        Task(kind="sleep", duration=0.1, tenant="bulk")
                        for _ in range(flood_n)
                    ]
                )
            lat = []
            clock_tasks = []
            for _ in range(50):
                t = Task(
                    kind="sleep", duration=0.2, tenant="serve", slo_class="interactive"
                )
                from repro.runtime.clock import get_clock

                t0 = get_clock().now()
                h.dispatch([t])
                t.add_done_callback(lambda _f, t=t, t0=t0: lat.append(
                    (t.trace.last("exec_done") or t0) - t0
                ))
                clock_tasks.append(t)
            for t in clock_tasks:
                assert t.result(timeout=600) is None
            assert h.dispatcher().drain(timeout=600)
            h.shutdown(wait=True)
            assert len(lat) == 50
            lat.sort()
            return lat[int(0.99 * len(lat)) - 1]

        unloaded = run(0)
        flooded = run(10_000)
        assert flooded <= max(3.0 * unloaded, unloaded + 1.0), (unloaded, flooded)
