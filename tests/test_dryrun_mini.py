"""Mini dry-run: lower+compile reduced configs on an 8-device host mesh in a
subprocess (the full 512-device sweep runs via launch/dryrun.py)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.compat import compat_cost_analysis, compat_make_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch, get_shape, token_batch_spec
    from repro.models.model import Model
    from repro.optim import adamw
    from repro.parallel.sharding import STRATEGIES
    from repro.train import step as step_lib

    mesh = compat_make_mesh((2, 4), ("data", "model"))

    for arch_name in ("llama3-8b", "falcon-mamba-7b", "grok-1-314b"):
        arch = get_arch(arch_name).reduced().replace(
            d_model=128, d_ff=256, n_heads=8, head_dim=16, vocab_size=512)
        model = Model(arch)
        strategy = STRATEGIES["tp"]
        if arch.family == "moe":
            strategy = strategy.with_overrides(experts=None)
        named = lambda t: jax.tree.map(lambda ps: NamedSharding(mesh, ps), t)
        import jax.numpy as jnp
        batch_specs = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        }
        sh = step_lib.make_shardings(model, strategy, mesh, batch_specs)
        fn = step_lib.make_train_step(model, strategy, mesh, adamw.AdamWConfig())
        params, opt = step_lib.abstract_train_state(model)
        metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                  step_lib.metrics_struct(model))
        metrics_sh["grad_norm"] = NamedSharding(mesh, P())
        metrics_sh["lr"] = NamedSharding(mesh, P())
        jfn = jax.jit(fn,
            in_shardings=(named(sh.params), named(sh.opt), named(sh.batch)),
            out_shardings=(named(sh.params), named(sh.opt), metrics_sh),
            donate_argnums=(0, 1))
        compiled = jfn.lower(params, opt, batch_specs).compile()
        mem = compiled.memory_analysis()
        cost = compat_cost_analysis(compiled)
        assert cost["flops"] > 0
        print("MINI_DRYRUN_OK", arch_name, int(cost["flops"]))
""")


@pytest.mark.slow
def test_mini_dryrun_8dev_compiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.stdout.count("MINI_DRYRUN_OK") == 3, out.stdout + out.stderr
