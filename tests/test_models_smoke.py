"""Per-arch smoke tests: REDUCED config, one forward/train step on CPU,
asserting output shapes + finite values (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models.model import Model


def _batch(cfg, B=2, L=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32),
    }
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_len_train, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_reduced_train_step(arch_name):
    cfg = get_arch(arch_name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch_name
    assert float(loss) > 0
    # one gradient step moves the loss
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch_name


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_reduced_prefill_decode_shapes(arch_name):
    cfg = get_arch(arch_name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, L = 2, 16
    batch = _batch(cfg, B, L)
    logits, cache = model.prefill(params, batch, cache_len=L + 4)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), L, jnp.int32)
    lg, cache2 = model.decode_step(params, cache, tok, pos)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch_name", sorted(ARCHS))
def test_full_config_param_count_matches_spec_tree(arch_name):
    """The analytic param_count used for MODEL_FLOPS must track the real
    spec tree (within 1% - analytic skips a few tiny norm/gate tensors)."""
    cfg = get_arch(arch_name)
    model = Model(cfg)
    analytic = cfg.param_count()
    actual = model.param_count()
    assert abs(analytic - actual) / actual < 0.01, (arch_name, analytic, actual)
