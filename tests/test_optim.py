"""Optimizer: AdamW convergence, schedule shape, ZeRO-1 pspec derivation."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        return adamw.apply_updates(cfg, params, grads, state)

    for _ in range(150):
        params, state, metrics = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1
    assert int(state["step"]) == 150


def test_grad_clipping():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.apply_updates(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    lrs = [float(adamw.lr_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 60, 110, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6  # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6  # peak
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-3  # decayed to min
    assert lrs[5] <= lrs[4] + 1e-6


def test_zero1_pspec_shards_largest_divisible_dim():
    ps = adamw.zero1_pspec(P(None, "model"), (1024, 512), data_size=16)
    assert ps == P("data", "model")
    # non-divisible dims are skipped
    ps = adamw.zero1_pspec(P(None, "model"), (49155, 512), data_size=16)
    assert ps == P(None, "model")
    # scalars untouched
    assert adamw.zero1_pspec(P(), (), data_size=16) == P()
    # already data-sharded params untouched
    ps = adamw.zero1_pspec(P("data", "model"), (1024, 512), data_size=16)
    assert ps == P("data", "model")


def test_bf16_params_fp32_state():
    cfg = adamw.AdamWConfig(peak_lr=0.01)
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = adamw.init_state(params)
    assert state["m"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones(8, jnp.bfloat16)}
    new_params, state, _ = adamw.apply_updates(cfg, params, grads, state)
    assert new_params["w"].dtype == jnp.bfloat16
