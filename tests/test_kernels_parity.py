"""Registry-driven Pallas parity (kernels/registry.py).

Every registered kernel must match its pure-jnp oracle at tight fp32
tolerance — across its ENTIRE block sweep space at the task-payload (tiny)
shape, at the CI-bench (smoke) shape under defaults, and across the
attention variants (causal / non-causal / windowed / GQA / MQA) the bench
rows don't sweep.  Interpret mode on CPU; the same calls lower to Mosaic on
a real TPU.

This is the test-side twin of the check_bench HARD allclose gate (1e-3):
the gate catches drift in CI artifacts, this suite pins the much tighter
tolerance the kernels actually achieve, so a config point that silently
degrades (a masked-out block, an off-by-one window) fails here first."""
from __future__ import annotations

import numpy as np
import pytest

from repro.kernels import registry as kreg

# fp32 interpret-mode kernels track the jnp oracle to ~1e-6; 2e-5 leaves
# headroom for accumulation-order differences without hiding real bugs
TOL = 2e-5

INTERPRET = kreg.interpret_default()


def _parity_err(name: str, shape: dict, config: dict, seed: int = 0) -> float:
    kdef = kreg.get_kernel(name)
    args = kdef.make_args(shape, "float32", seed)
    return kreg.max_abs_err(
        kdef.call(shape, args, config, INTERPRET), kdef.ref(shape, args)
    )


@pytest.mark.parametrize("name", sorted(kreg.KERNELS))
@pytest.mark.parametrize("tier", ["tiny", "smoke"])
def test_parity_at_payload_and_bench_shapes(name, tier):
    """Defaults config at the two shapes the system actually dispatches:
    tiny (the kind="kernel" payload default) and smoke (BENCH_smoke rows)."""
    kdef = kreg.get_kernel(name)
    shape = dict(kdef.tiny_shape if tier == "tiny" else kdef.smoke_shape)
    assert _parity_err(name, shape, kdef.defaults(shape)) <= TOL


@pytest.mark.parametrize("name", sorted(kreg.KERNELS))
def test_parity_across_entire_sweep_space(name):
    """Every config the autotuner could ever pick computes the same answer:
    the sweep space at the tiny shape is small enough to cover exhaustively
    (a pruned-away config is still a *legal* config)."""
    kdef = kreg.get_kernel(name)
    shape = dict(kdef.tiny_shape)
    space = kdef.space(shape)
    assert len(space) >= 2, "sweep space degenerate: the autotuner has no choice"
    for config in space:
        err = _parity_err(name, shape, config)
        assert err <= TOL, f"{name} diverges at {kreg.config_sig(config)}: {err:g}"


# ---------------------------------------------------------------------------
# attention variants: masking interacts with the block grid, so causal,
# windowed, and grouped-KV paths each get their own parity point
# ---------------------------------------------------------------------------

_VARIANTS = {
    "mha_causal": {"H": 4, "KV": 4, "causal": True, "window": None},
    "mha_full": {"H": 4, "KV": 4, "causal": False, "window": None},
    "gqa_causal": {"H": 4, "KV": 2, "causal": True, "window": None},
    "mqa_causal": {"H": 4, "KV": 1, "causal": True, "window": None},
    "windowed": {"H": 4, "KV": 4, "causal": True, "window": 32},
    "gqa_windowed": {"H": 4, "KV": 2, "causal": True, "window": 64},
}


@pytest.mark.parametrize("variant", sorted(_VARIANTS))
def test_flash_attention_variants(variant):
    shape = {"B": 1, "L": 128, "hd": 32, **_VARIANTS[variant]}
    kdef = kreg.get_kernel("flash_attention")
    for config in ({"block_q": 32, "block_k": 32}, {"block_q": 64, "block_k": 32}):
        err = _parity_err("flash_attention", shape, config)
        assert err <= TOL, f"{variant} @ {kreg.config_sig(config)}: {err:g}"


def test_make_args_is_seed_deterministic():
    """Same (shape, dtype, seed) => bit-identical operands on every host —
    the property the autotuner's byte-identical payload cache rests on."""
    for name, kdef in kreg.KERNELS.items():
        shape = dict(kdef.tiny_shape)
        a = kdef.make_args(shape, "float32", 3)
        b = kdef.make_args(shape, "float32", 3)
        c = kdef.make_args(shape, "float32", 4)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert any(
            not np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, c)
        ), f"{name}: seed does not reach the operands"
