"""Partitioning invariants (hypothesis): every task in exactly one pod,
capacity respected, SCPP/MCPP pod counts correct."""
from _hypothesis_compat import given, settings, st

from repro.core.partition import partition
from repro.core.task import Resources, Task


def _tasks(n, cpus=None):
    return [
        Task(kind="noop", resources=Resources(cpus=(cpus[i] if cpus else 1)))
        for i in range(n)
    ]


@given(st.integers(1, 300), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_mcpp_every_task_exactly_once(n, tpp):
    tasks = _tasks(n)
    pods = partition(tasks, "p", model="mcpp", tasks_per_pod=tpp)
    seen = [t.uid for p in pods for t in p.tasks]
    assert sorted(seen) == sorted(t.uid for t in tasks)
    assert len(seen) == len(set(seen))
    assert all(p.size <= tpp for p in pods)
    assert len(pods) == -(-n // tpp)


@given(st.integers(1, 200))
@settings(max_examples=30, deadline=None)
def test_scpp_one_task_per_pod(n):
    tasks = _tasks(n)
    pods = partition(tasks, "p", model="scpp")
    assert len(pods) == n
    assert all(p.size == 1 for p in pods)


@given(st.lists(st.integers(1, 8), min_size=1, max_size=120))
@settings(max_examples=50, deadline=None)
def test_binpack_capacity_respected(cpu_list):
    cap = Resources(cpus=16, accels=8, memory_mb=1 << 20)
    tasks = _tasks(len(cpu_list), cpus=cpu_list)
    pods = partition(tasks, "p", model="binpack", pod_capacity=cap)
    seen = [t.uid for p in pods for t in p.tasks]
    assert sorted(seen) == sorted(t.uid for t in tasks)
    for p in pods:
        assert sum(t.resources.cpus for t in p.tasks) <= cap.cpus


def test_binpack_rejects_oversized_task():
    import pytest

    cap = Resources(cpus=2, accels=0, memory_mb=128)
    t = Task(kind="noop", resources=Resources(cpus=4))
    with pytest.raises(ValueError):
        partition([t], "p", model="binpack", pod_capacity=cap)
