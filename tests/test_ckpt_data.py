"""Checkpointing (sync/async, retention, restart) + data pipeline."""
import os

import jax
from repro.compat import compat_make_mesh
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, Prefetcher, batch_at


def _state():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "opt": {"m": jnp.zeros((2, 3)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    state = _state()
    ckpt.save(str(tmp_path), 7, state)
    step, restored = ckpt.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    state = _state()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_async_checkpointer(tmp_path):
    state = _state()
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(3, state)
    ac.wait()
    step, restored = ckpt.restore(str(tmp_path), state)
    assert step == 3


def test_restore_validates_shapes(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((3, 3))})


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), _state())


def test_batches_deterministic_and_step_indexed():
    dc = DataConfig(vocab_size=100, seq_len=8, global_batch=4, seed=1)
    b1, b2 = batch_at(dc, 5), batch_at(dc, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at(dc, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted from the same stream
    assert b1["tokens"].shape == b1["labels"].shape == (4, 8)


def test_prefetcher_yields_in_order():
    dc = DataConfig(vocab_size=100, seq_len=8, global_batch=2, seed=0)
    pf = Prefetcher(dc, start_step=3, depth=2)
    try:
        steps = [next(pf)[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
        ref = batch_at(dc, 3)
        pf2 = Prefetcher(dc, start_step=3, depth=1)
        np.testing.assert_array_equal(next(pf2)[1]["tokens"], ref["tokens"])
        pf2.close()
    finally:
        pf.close()


def test_train_restart_equivalence(tmp_path):
    """Train 4 steps == train 2, checkpoint, restore, train 2 more."""
    from repro.configs import get_arch
    from repro.models.model import Model
    from repro.optim import adamw
    from repro.train import step as step_lib
    from repro.parallel.sharding import STRATEGIES

    cfg = get_arch("llama3-8b").reduced()
    model = Model(cfg)
    ocfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    mesh = compat_make_mesh((1,), ("data",))
    ts = jax.jit(step_lib.make_train_step(model, STRATEGIES["tp"], mesh, ocfg))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)

    params, opt = step_lib.init_train_state(model, jax.random.key(0))
    for i in range(4):
        params, opt, _ = ts(params, opt, batch_at(dc, i))
    ref = jax.tree.leaves(params)

    params2, opt2 = step_lib.init_train_state(model, jax.random.key(0))
    for i in range(2):
        params2, opt2, _ = ts(params2, opt2, batch_at(dc, i))
    ckpt.save(str(tmp_path), 2, {"params": params2, "opt": opt2})
    _, restored = ckpt.restore(str(tmp_path), {"params": params2, "opt": opt2})
    params3, opt3 = restored["params"], restored["opt"]
    for i in range(2, 4):
        params3, opt3, _ = ts(params3, opt3, batch_at(dc, i))
    for a, b in zip(ref, jax.tree.leaves(params3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
