"""Distributed flash-decode == single-device decode (multi-device subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import compat_make_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_arch
    from repro.models.model import Model
    from repro.parallel import sharding as sh
    from repro.train import step as step_lib

    for arch_name, kv in (("llama3-8b", 1), ("recurrentgemma-2b", 1)):
        cfg = get_arch(arch_name).reduced().replace(n_kv_heads=kv)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        B, L = 4, 16
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L + 1)), jnp.int32)
        _, cache = model.prefill(params, {"tokens": toks[:, :L]}, cache_len=L + 1)
        lg_ref, _ = model.decode_step(params, cache, toks[:, L:L+1], jnp.full((B,), L, jnp.int32))
        ref = np.asarray(lg_ref[:, 0])

        strat = dataclasses.replace(sh.STRATEGIES["tp"], name="tp_fd", flash_decode=True)
        fn = step_lib.make_decode_step(model, strat, mesh)
        shardings = step_lib.make_shardings(
            model, strat, mesh,
            {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
             "pos": jax.ShapeDtypeStruct((B,), jnp.int32)},
            model.cache_specs(B, L + 1))
        named = lambda t: jax.tree.map(lambda ps: NamedSharding(mesh, ps), t)
        jfn = jax.jit(fn, in_shardings=(named(shardings.params), named(shardings.cache), named(shardings.batch)))
        cache_sh = jax.tree.map(lambda x, s: jax.device_put(x, s), cache, named(shardings.cache))
        lg, _ = jfn(params, cache_sh, {"tokens": toks[:, L:L+1], "pos": jnp.full((B,), L, jnp.int32)})
        err = np.max(np.abs(ref - np.asarray(lg[:, 0]))) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 2e-3, (arch_name, err)
        print("FLASH_DECODE_OK", arch_name, float(err))
""")


@pytest.mark.slow
def test_flash_decode_matches_reference_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=600
    )
    assert out.stdout.count("FLASH_DECODE_OK") == 2, out.stdout + out.stderr
