"""Binding policies: eligibility, balance, adaptivity."""
from repro.core.policy import AdaptivePolicy, CapabilityPolicy, LoadAwarePolicy, RoundRobinPolicy
from repro.core.provider import ProviderProxy, ProviderSpec
from repro.core.task import Resources, Task


def _providers(*specs):
    proxy = ProviderProxy()
    return [proxy.register(s) for s in specs]


def test_round_robin_balances():
    hs = _providers(ProviderSpec(name="a"), ProviderSpec(name="b"))
    pol = RoundRobinPolicy()
    picks = [pol.bind(Task(kind="noop"), hs) for _ in range(10)]
    assert picks.count("a") == picks.count("b") == 5


def test_pinned_provider_wins():
    hs = _providers(ProviderSpec(name="a"), ProviderSpec(name="b"))
    pol = RoundRobinPolicy()
    t = Task(kind="noop", provider="b")
    assert all(pol.bind(t, hs) == "b" for _ in range(3))


def test_capability_routes_accel_tasks():
    hs = _providers(
        ProviderSpec(name="cpu_pool", node_capacity=Resources(cpus=64, accels=0, memory_mb=1 << 20)),
        ProviderSpec(name="tpu_pool", node_capacity=Resources(cpus=16, accels=8, memory_mb=1 << 20)),
    )
    pol = CapabilityPolicy()
    accel_task = Task(kind="noop", resources=Resources(cpus=1, accels=4))
    cpu_task = Task(kind="noop", resources=Resources(cpus=8))
    assert pol.bind(accel_task, hs) == "tpu_pool"
    assert pol.bind(cpu_task, hs) == "cpu_pool"


def test_load_aware_prefers_idle():
    hs = _providers(ProviderSpec(name="a"), ProviderSpec(name="b"))
    pol = LoadAwarePolicy()
    first = pol.bind(Task(kind="noop"), hs)
    second = pol.bind(Task(kind="noop"), hs)
    assert {first, second} == {"a", "b"}


def test_adaptive_prefers_faster_provider():
    hs = _providers(ProviderSpec(name="fast"), ProviderSpec(name="slow"))
    pol = AdaptivePolicy()
    for _ in range(20):
        pol.observe("fast", 0.01)
        pol.observe("slow", 1.0)
    picks = []
    for _ in range(10):
        p = pol.bind(Task(kind="noop"), hs)
        picks.append(p)
        pol.observe(p, 0.01 if p == "fast" else 1.0)
    assert picks.count("fast") > picks.count("slow")


def test_no_eligible_provider_raises():
    import pytest

    hs = _providers(ProviderSpec(name="tiny", node_capacity=Resources(cpus=1, accels=0, memory_mb=64)))
    pol = RoundRobinPolicy()
    with pytest.raises(RuntimeError):
        pol.bind(Task(kind="noop", resources=Resources(cpus=128)), hs)
