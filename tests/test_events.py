"""Event-sourced control plane (core/events.py): the tier-1 contract.

Four layers of coverage, matching the ISSUE's acceptance criteria:

  * bus mechanics — emit/fold is O(1) append + reduce, the dump carries a
    self-verifying snapshot header, bounded buffers mark themselves partial;
  * replay determinism — a serialized stream folds back into every derived
    metric BIT-FOR-BIT, a deterministic workload produces a byte-identical
    canonical stream on a same-seed rerun, and any mutation or truncation
    of the JSONL is detected by ``verify_replay``;
  * migration — across a full chaos scenario (searise_smoke: groups,
    tenants, staging, autoscaler, four fault kinds) every legacy stats
    accumulator equals its log-derived view, key by key;
  * the CLI (``python -m repro.core.events``) exit-code contract.

The whole suite already runs with ``HYDRA_EVENTS_CHECK=1`` (conftest), so
every other test doubles as a strict cross-check; this file pins the parts
strict mode alone cannot see (serialization, replay, canonical ordering).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from types import SimpleNamespace

import pytest

from repro.core import Hydra, ProviderSpec, Task
from repro.core.chaos import ChaosEngine
from repro.core.events import (
    _REDUCERS,
    EVENTS,
    EventBus,
    MetricsView,
    replay_jsonl,
    verify_replay,
)
from repro.core.managers.workflow import WorkflowManager
from repro.runtime.clock import virtual_time
from repro.scenarios import ScenarioSpec, presets
from repro.scenarios.runner import build_broker, run_scenario
from repro.scenarios.spec import ProviderDecl, TrafficSpec
from repro.scenarios.traffic import build_traffic

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Bus mechanics
# ---------------------------------------------------------------------------


def test_taxonomy_is_closed_and_documented():
    """Every event kind has a reducer, and every spec is fully described —
    the docs-lint (tools/docs_check.py) leans on these names being final."""
    assert set(_REDUCERS) == set(EVENTS)
    assert len(EVENTS) >= 35
    for name, spec in EVENTS.items():
        assert spec.name == name
        assert spec.site and spec.doc
        assert spec.metrics, f"{name} derives no metrics"


def test_emit_folds_and_dump_roundtrips(tmp_path):
    bus = EventBus(strict=False)
    bus.emit("dispatch.batch", n=3)
    bus.emit("dispatch.batch", n=2)
    bus.emit("task.complete", provider="a", failed=False)
    bus.emit("task.complete", provider="a", failed=True)
    bus.emit("admission.reject", tenant="t0", reason="rate")
    assert len(bus) == 5
    v = bus.view
    assert v.get("hydra.dispatch.batches") == 2
    assert v.get("hydra.dispatch.tasks") == 5
    assert v.get("hydra.tasks.completed") == 1
    assert v.get("hydra.tasks.failed") == 1
    assert v.keyed_get("hydra.admission.rejected") == {"t0:rate": 1}

    path = tmp_path / "bus.jsonl"
    header = bus.dump_jsonl(str(path))
    with open(path, encoding="utf-8") as fh:
        view, rheader = replay_jsonl(fh)
    assert rheader == header
    assert view.snapshot() == bus.snapshot() == header["snapshot"]
    ok, _, _ = verify_replay(str(path))
    assert ok


def test_unknown_event_is_counted_not_raised():
    v = MetricsView()
    v.apply("no.such.event", {})
    assert v.unknown == 1
    assert v.snapshot() == {"counters": {}, "keyed": {}}


def test_bounded_buffer_marks_dump_partial(tmp_path):
    bus = EventBus(strict=False, buffer=2)
    for _ in range(5):
        bus.emit("dispatch.retry")
    assert len(bus) == 5  # logical length: every emit counted
    assert bus.dropped == 3
    assert bus.view.get("hydra.dispatch.retry_backoffs") == 5  # views stay exact
    path = tmp_path / "partial.jsonl"
    bus.dump_jsonl(str(path))
    ok, _, header = verify_replay(str(path))
    assert not ok and header["dropped"] == 3


# ---------------------------------------------------------------------------
# Replay determinism
# ---------------------------------------------------------------------------


def _serial_run(tmp_path, tag: str) -> tuple[str, dict]:
    """A fully serialized deterministic workload: one provider, one slot,
    each task waited on before the next is submitted, all under a fresh
    VirtualClock — two invocations must tell byte-identical stories."""
    with virtual_time():
        h = Hydra(
            pod_store="memory",
            streaming=True,
            batch_window=0.0,
            workdir=str(tmp_path / tag),
        )
        h.register_provider(ProviderSpec(name="solo", platform="cloud", concurrency=1))
        for i in range(4):
            t = Task(kind="sleep", duration=0.25 * (i + 1))
            h.dispatch([t])
            t.result(timeout=60)
        canon = h.events.canonical_jsonl()
        snap = h.events.snapshot()
        h.shutdown(wait=True)
    return canon, snap


def test_same_workload_same_canonical_stream(tmp_path):
    canon_a, snap_a = _serial_run(tmp_path, "a")
    canon_b, snap_b = _serial_run(tmp_path, "b")
    assert canon_a == canon_b  # byte-identical canonical event stream
    assert snap_a == snap_b  # identical derived metrics
    # and the stream is non-trivial: it carries the run's actual story
    names = {json.loads(line)["name"] for line in canon_a.splitlines()}
    assert {"provider.register", "dispatch.batch", "task.complete"} <= names


def test_runner_records_replayable_log(tmp_path):
    """run_scenario(record_events=...) dumps a log replay can self-verify."""
    spec = ScenarioSpec(
        name="rec-mini",
        seed=5,
        providers=[ProviderDecl(name="p0", concurrency=4)],
        traffic=TrafficSpec(serve_waves=1, serve_tasks_per_wave=4, serve_task_s=0.2),
        batch_window=0.0,
        timeout_s=120.0,
    )
    path = tmp_path / "mini.jsonl"
    report = run_scenario(spec, chaos=False, record_events=str(path))
    assert report.failed_tasks == 0 and report.events_error is None
    assert report.events_path == str(path)
    assert report.n_bus_events > 0
    ok, replayed, header = verify_replay(str(path))
    assert ok and replayed == header["snapshot"]
    assert report.to_dict()["n_bus_events"] == report.n_bus_events


# ---------------------------------------------------------------------------
# Full chaos scenario: record once, share across replay/migration/CLI tests
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_run(tmp_path_factory):
    """One searise_smoke chaos run (groups + tenants + staging + autoscaler
    + all four fault kinds), with the live broker's legacy accumulators and
    log-derived views captured side by side before shutdown."""
    path = str(tmp_path_factory.mktemp("events") / "smoke.jsonl")
    spec = presets.searise_smoke(seed=3)
    with virtual_time():
        h = build_broker(spec)
        wfs = build_traffic(h.staging.registry, spec.traffic, prefix=spec.name)
        engine = ChaosEngine(h, [c.to_core() for c in spec.chaos], seed=spec.seed).arm()
        WorkflowManager(h).run(wfs, wait=True, timeout=spec.timeout_s)
        engine.stop()
        h.events.check()  # strict cross-check on the quiesced broker
        legacy = h._events_recompute()
        derived = h.events.view.flat()
        chaos_stats = engine.stats()
        legacy_injected = dict(engine.injected)
        header = h.events.dump_jsonl(path)
        h.shutdown(wait=True)
    return SimpleNamespace(
        path=path,
        header=header,
        legacy=legacy,
        derived=derived,
        chaos_stats=chaos_stats,
        legacy_injected=legacy_injected,
    )


def test_chaos_scenario_replays_bit_identical(smoke_run):
    """The tier-1 round-trip acceptance check: dump -> replay reconstructs
    every derived metric (ints AND float accumulators) bit-for-bit."""
    ok, replayed, header = verify_replay(smoke_run.path)
    assert ok
    assert replayed == header["snapshot"] == smoke_run.header["snapshot"]
    # float metrics (staged MB, queue-wait seconds) survive the round trip
    counters = replayed["counters"]
    assert counters.get("hydra.staging.mb_moved", 0) > 0
    assert sum(replayed["keyed"].get("hydra.chaos.injected", {}).values()) >= 4


def test_migration_legacy_accumulators_equal_views(smoke_run):
    """Every legacy stats accumulator == its log-derived view, key by key —
    the migration contract that lets the dict-shaped accessors become thin
    adapters without moving a single number."""
    assert smoke_run.legacy, "recompute returned nothing — wiring regressed"
    mismatches = {
        k: (want, smoke_run.derived.get(k, 0))
        for k, want in smoke_run.legacy.items()
        if smoke_run.derived.get(k, 0) != want
    }
    assert not mismatches
    # chaos is external to the broker's recompute: check its view explicitly
    assert smoke_run.chaos_stats["injected"] == {
        k: int(v) for k, v in smoke_run.legacy_injected.items()
    }


def test_mutated_stream_is_detected(smoke_run, tmp_path):
    with open(smoke_run.path, encoding="utf-8") as fh:
        lines = fh.readlines()
    # (1) tamper with one record's payload
    idx = next(i for i, ln in enumerate(lines) if '"dispatch.batch"' in ln)
    rec = json.loads(lines[idx])
    rec["attrs"]["n"] = rec["attrs"].get("n", 0) + 1
    tampered = tmp_path / "tampered.jsonl"
    tampered.write_text(
        "".join(lines[:idx])
        + json.dumps(rec, sort_keys=True, separators=(",", ":"))
        + "\n"
        + "".join(lines[idx + 1 :])
    )
    ok, _, _ = verify_replay(str(tampered))
    assert not ok
    # (2) drop a record entirely
    truncated = tmp_path / "truncated.jsonl"
    truncated.write_text("".join(lines[:idx] + lines[idx + 1 :]))
    ok, _, _ = verify_replay(str(truncated))
    assert not ok


def test_replay_cli_contract(smoke_run, tmp_path):
    env = {**os.environ, "PYTHONPATH": SRC}

    def run(*args):
        return subprocess.run(
            [sys.executable, "-m", "repro.core.events", *args],
            capture_output=True,
            text=True,
            env=env,
            timeout=120,
        )

    out_json = tmp_path / "replayed.json"
    r = run("replay", smoke_run.path, "--json", str(out_json))
    assert r.returncode == 0, r.stderr
    assert json.loads(out_json.read_text()) == smoke_run.header["snapshot"]

    # identical logs diff clean; exit 1 when they diverge
    r = run("diff", smoke_run.path, smoke_run.path)
    assert r.returncode == 0, r.stderr

    r = run("taxonomy")
    assert r.returncode == 0 and len(r.stdout.splitlines()) == len(EVENTS)

    bad = tmp_path / "bad.jsonl"
    with open(smoke_run.path, encoding="utf-8") as fh:
        lines = fh.readlines()
    bad.write_text("".join(lines[:-1]))  # drop the last record
    r = run("replay", str(bad))
    assert r.returncode == 1
    r = run("diff", smoke_run.path, str(bad))
    assert r.returncode == 1
