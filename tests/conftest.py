import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)


def wait_until(pred, timeout=15.0, poll=0.02):
    """Poll a predicate in REAL time (thread progress, not clock time) —
    shared by the autoscaler and staging suites."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(poll)
    return pred()
