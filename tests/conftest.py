import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

# run the WHOLE tier-1 suite with the CapacityLedger honesty harness on:
# every O(1) counter read cross-checks against a from-scratch recompute and
# raises LedgerDivergence on a persistent mismatch (core/ledger.py)
os.environ.setdefault("HYDRA_LEDGER_CHECK", "1")

# same harness for the event-sourced control plane (core/events.py): every
# stats accessor — and every broker shutdown — cross-checks the log-derived
# metric views against the legacy accumulators and raises EventsDivergence
# on a persistent mismatch
os.environ.setdefault("HYDRA_EVENTS_CHECK", "1")


def wait_until(pred, timeout=15.0, poll=0.02):
    """Poll a predicate in REAL time (thread progress, not clock time) —
    shared by the autoscaler and staging suites."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(poll)
    return pred()
