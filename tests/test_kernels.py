"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU; the same calls lower to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,L,hd,block",
    [
        (1, 4, 4, 128, 64, 64),   # MHA
        (2, 8, 2, 256, 64, 128),  # GQA 4:1
        (1, 4, 1, 128, 32, 32),   # MQA
        (1, 2, 2, 192, 64, 64),   # non-pow2 seq (divisible blocks)
    ],
)
def test_flash_attention_sweep(dtype, B, H, KV, L, hd, block):
    q = jnp.asarray(RNG.normal(size=(B, H, L, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, KV, L, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, KV, L, hd)), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_windowed(window):
    B, H, KV, L, hd = 1, 2, 1, 256, 64
    q = jnp.asarray(RNG.normal(size=(B, H, L, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, KV, L, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, KV, L, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_non_causal():
    B, H, KV, L, hd = 1, 2, 2, 128, 64
    q = jnp.asarray(RNG.normal(size=(B, H, L, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, KV, L, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, KV, L, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,ck,di,N,block_d", [(1, 16, 64, 4, 32), (2, 32, 128, 16, 64), (2, 64, 256, 16, 256)])
def test_selective_scan_sweep(B, ck, di, N, block_d):
    x = jnp.asarray(RNG.normal(size=(B, ck, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, ck, di)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, ck, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, ck, N)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, N)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, di, N)), jnp.float32)
    y1, h1 = ops.selective_scan_chunk(x, dt, bm, cm, a, h0, block_d=block_d)
    y2, h2 = ref.selective_scan_chunk_ref(x, dt, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)


def test_selective_scan_chains_chunks():
    """Two chunks chained via h0 == one double-length chunk."""
    B, ck, di, N = 1, 16, 64, 8
    x = jnp.asarray(RNG.normal(size=(B, 2 * ck, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, 2 * ck, di)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, 2 * ck, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, 2 * ck, N)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, N)), jnp.float32)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    y_full, h_full = ops.selective_scan_chunk(x, dt, bm, cm, a, h0, block_d=32)
    y1, h1 = ops.selective_scan_chunk(x[:, :ck], dt[:, :ck], bm[:, :ck], cm[:, :ck], a, h0, block_d=32)
    y2, h2 = ops.selective_scan_chunk(x[:, ck:], dt[:, ck:], bm[:, ck:], cm[:, ck:], a, h1, block_d=32)
    np.testing.assert_allclose(np.asarray(y_full[:, ck:]), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,L,dr,block_d", [(1, 32, 128, 64), (2, 64, 256, 128), (2, 128, 512, 512)])
def test_rglru_sweep(B, L, dr, block_d):
    la = -jnp.asarray(RNG.uniform(0.01, 1.0, (B, L, dr)), jnp.float32)
    gx = jnp.asarray(RNG.normal(size=(B, L, dr)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, dr)), jnp.float32)
    y1, h1 = ops.rglru_scan(la, gx, h0, block_d=block_d)
    y2, h2 = ref.rglru_ref(la, gx, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(2, 32, 64, 128), (4, 64, 128, 256), (8, 128, 256, 128)])
def test_moe_gmm_sweep(dtype, E, C, D, F):
    x = jnp.asarray(RNG.normal(size=(E, C, D)), dtype)
    w = jnp.asarray(RNG.normal(size=(E, D, F)) * 0.1, dtype)
    got = ops.moe_gmm(x, w, block_c=32, block_f=64, block_d=64)
    want = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_model_path_with_pallas_matches_xla():
    """mamba block computed via the Pallas kernel == the XLA path."""
    from repro.configs import get_arch
    from repro.models import ssm
    from repro.models.model import Model

    cfg = get_arch("falcon-mamba-7b").reduced().replace(ssm_chunk=8)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    block = jax.tree.map(lambda p: p[0], params["blocks"])
    y_xla = ssm.mamba_block(cfg, x, block, use_pallas=False)
    y_pallas = ssm.mamba_block(cfg, x, block, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_pallas), rtol=2e-4, atol=2e-4)
