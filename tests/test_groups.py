"""Provider groups: balancing strategies, circuit breaker state machine,
transparent failover, half-open recovery, registration validation."""
import time

import pytest

from repro.core import (
    BreakerState,
    CircuitBreaker,
    GroupExhausted,
    Hydra,
    ProviderSpec,
    Task,
)
from repro.core.group import ProviderGroup, make_strategy
from repro.core.provider import ValidationError


def specs(*names, **kw):
    return [ProviderSpec(name=n, concurrency=4, **kw) for n in names]


@pytest.fixture
def broker(tmp_path):
    h = Hydra(pod_store="memory", workdir=str(tmp_path), tasks_per_pod=8)
    yield h
    h.shutdown(wait=False)


# ---------------------------------------------------------------------------
# CircuitBreaker unit behaviour
# ---------------------------------------------------------------------------


def test_breaker_opens_after_consecutive_failures():
    b = CircuitBreaker(failure_threshold=3, reset_timeout_s=60.0)
    for _ in range(2):
        b.record_failure()
    assert b.state == BreakerState.CLOSED and b.allow()
    b.record_failure()
    assert b.state == BreakerState.OPEN and not b.allow()


def test_breaker_success_resets_failure_streak():
    b = CircuitBreaker(failure_threshold=2)
    b.record_failure()
    b.record_success()
    b.record_failure()
    assert b.state == BreakerState.CLOSED  # streak broken: still closed


def test_breaker_trip_opens_immediately():
    b = CircuitBreaker(failure_threshold=99)
    b.trip()
    assert b.state == BreakerState.OPEN and not b.allow()


def test_breaker_half_open_probe_single_flight_then_close():
    b = CircuitBreaker(failure_threshold=1, reset_timeout_s=0.02)
    b.record_failure()
    assert not b.allow()
    time.sleep(0.03)
    assert b.allow()  # the timed probe
    assert b.state == BreakerState.HALF_OPEN
    assert not b.allow()  # only one probe in flight
    b.record_success()
    assert b.state == BreakerState.CLOSED and b.allow()


def test_breaker_release_probe_returns_ticket():
    b = CircuitBreaker(failure_threshold=1, reset_timeout_s=0.02)
    b.record_failure()
    time.sleep(0.03)
    assert b.allow()  # probe dispatched
    b.release_probe()  # probe task finished elsewhere: it never ran
    assert b.allow()  # ticket returned: next caller may probe
    b.record_success()
    assert b.state == BreakerState.CLOSED


def test_breaker_half_open_failure_reopens():
    b = CircuitBreaker(failure_threshold=1, reset_timeout_s=0.02)
    b.record_failure()
    time.sleep(0.03)
    assert b.allow()
    b.record_failure()
    assert b.state == BreakerState.OPEN and not b.allow()


# ---------------------------------------------------------------------------
# Group construction + strategies
# ---------------------------------------------------------------------------


def test_group_registration_and_bind_targets(broker):
    broker.register_group("pool", specs("g1", "g2", "g3"))
    assert broker.proxy.is_group("pool")
    names = {t.name for t in broker.proxy.bind_targets()}
    assert names == {"pool"}  # members leave the direct-binding pool
    assert broker.proxy.get("g1").group == "pool"


def test_group_rejects_mixed_platforms(broker):
    broker.register_provider(ProviderSpec(name="c1"))
    broker.register_provider(ProviderSpec(name="h1", platform="hpc", connector="pilot"))
    with pytest.raises(ValidationError):
        ProviderGroup("bad", [broker.proxy.get("c1"), broker.proxy.get("h1")])


def test_member_cannot_join_two_groups(broker):
    broker.register_group("pool_a", specs("m1", "m2"))
    with pytest.raises(ValidationError):
        broker.register_group("pool_b", ["m1"])


def test_group_name_collision_rejected(broker):
    broker.register_provider(ProviderSpec(name="solo"))
    with pytest.raises(ValidationError):
        broker.register_group("solo", specs("x1", "x2"))


def test_unknown_strategy_rejected():
    with pytest.raises(ValidationError):
        make_strategy("fastest_first")


def test_failed_registration_rolls_back_members(broker):
    """A failed register_group must not leak on-the-fly members into the
    direct-binding pool."""
    with pytest.raises(ValidationError):
        broker.register_group("bad", specs("r1", "r2"), strategy="nope")
    assert broker.proxy.bind_targets() == []
    with pytest.raises(KeyError):
        broker.proxy.get("r1")
    broker.register_group("good", specs("r1", "r2"))  # names reusable now


def test_round_robin_strategy_balances(broker):
    group = broker.register_group("pool", specs("r1", "r2", "r3"))
    picks = [group.select() for _ in range(9)]
    assert {picks.count(m) for m in ("r1", "r2", "r3")} == {3}


def test_weighted_strategy_prefers_capacity(broker):
    big = ProviderSpec(name="big", concurrency=4, n_nodes=4)
    small = ProviderSpec(name="small", concurrency=4, n_nodes=1)
    group = broker.register_group("pool", [big, small], strategy="weighted")
    picks = []
    for _ in range(10):
        m = group.select()
        group.note_dispatch(m, 1)
        picks.append(m)
    assert picks.count("big") > picks.count("small")


def test_least_loaded_strategy_fills_idle_member(broker):
    group = broker.register_group("pool", specs("l1", "l2"), strategy="least_loaded")
    group.note_dispatch("l1", 5)
    assert group.select() == "l2"


def test_select_excludes_failed_member_and_exhausts(broker):
    group = broker.register_group("pool", specs("e1", "e2"))
    group.mark_down("e1")
    assert group.select() == "e2"  # e1's breaker is open
    with pytest.raises(GroupExhausted):
        group.select(exclude="e2")  # e1 down + e2 excluded -> nothing left
    group.mark_down("e2")
    with pytest.raises(GroupExhausted):
        group.select()


# ---------------------------------------------------------------------------
# End-to-end: dispatch, failover, recovery
# ---------------------------------------------------------------------------


def test_group_workload_completes_and_balances(broker):
    broker.register_group("pool", specs("b1", "b2"))
    tasks = [Task(kind="noop") for _ in range(64)]
    sub = broker.submit(tasks)
    assert sub.wait(timeout=60)
    assert sub.states == {"DONE": 64}
    assert all(t.group == "pool" and t.provider in ("b1", "b2") for t in tasks)
    rows = {r["member"]: r for r in broker.group_rows()}
    assert rows["b1"]["dispatched"] > 0 and rows["b2"]["dispatched"] > 0
    assert rows["b1"]["completed"] + rows["b2"]["completed"] == 64


def test_group_failover_survives_member_death(broker):
    """ISSUE acceptance: a 3-member group where one member dies mid-run must
    finish ALL tasks with the breaker open on the dead member."""
    group = broker.register_group("pool", specs("f1", "f2", "f3"))
    tasks = [Task(kind="sleep", duration=0.005) for _ in range(120)]
    sub = broker.submit(tasks)
    broker.manager("f2").fail()  # ProviderDown mid-run
    assert sub.wait(timeout=120)
    assert sub.states == {"DONE": 120}
    assert group.breaker_state("f2") == BreakerState.OPEN
    # survivors absorbed the failed-over work
    assert all(t.provider in ("f1", "f3") or t.tstate.value == "DONE" for t in tasks)
    row = {r["member"]: r for r in broker.group_rows()}["f2"]
    assert row["breaker"] == "OPEN" and row["trips"] >= 1


def test_failover_is_transparent_to_policy(tmp_path):
    """The binding policy only ever sees the logical group name."""
    seen = []

    h = Hydra(pod_store="memory", workdir=str(tmp_path), policy="load_aware")
    orig_observe = h.policy.observe

    def spy(provider, runtime_s):
        seen.append(provider)
        orig_observe(provider, runtime_s)

    h.policy.observe = spy
    h.register_group("pool", specs("p1", "p2"))
    tasks = [Task(kind="sleep", duration=0.002) for _ in range(40)]
    sub = h.submit(tasks)
    h.manager("p1").fail()
    assert sub.wait(timeout=60)
    assert sub.states == {"DONE": 40}
    assert set(seen) == {"pool"}  # member names never leak into the policy
    h.shutdown(wait=False)


def test_half_open_probe_recovers_member(broker):
    # least_loaded is the strategy most sensitive to stale load counts on a
    # downed member: recovery must not be starved by leftover `outstanding`
    group = broker.register_group(
        "pool", specs("h1", "h2"), strategy="least_loaded", reset_timeout_s=0.05
    )
    sub = broker.submit([Task(kind="noop") for _ in range(16)])
    assert sub.wait(timeout=30)
    broker.manager("h1").fail()
    group.mark_down("h1")
    assert group.breaker_state("h1") == BreakerState.OPEN
    broker.manager("h1").recover()
    time.sleep(0.06)  # reset window elapses -> next dispatch is the probe
    sub2 = broker.submit([Task(kind="noop") for _ in range(16)])
    assert sub2.wait(timeout=30)
    assert sub2.states == {"DONE": 16}
    deadline = time.time() + 5
    while group.breaker_state("h1") != BreakerState.CLOSED and time.time() < deadline:
        broker.submit([Task(kind="noop")]).wait(timeout=10)
    assert group.breaker_state("h1") == BreakerState.CLOSED


def test_group_exhausted_falls_back_to_standalone_provider(broker):
    broker.register_group("pool", specs("x1", "x2"))
    broker.register_provider(ProviderSpec(name="backup", concurrency=4))
    tasks = [Task(kind="sleep", duration=0.005) for _ in range(60)]
    sub = broker.submit(tasks)
    broker.manager("x1").fail()
    broker.manager("x2").fail()
    assert sub.wait(timeout=120)
    assert sub.states == {"DONE": 60}


def test_elastic_remove_grouped_member(broker):
    """remove_provider on a group member = permanent failover: the member
    leaves the group for good (no half-open probes to a dead slot)."""
    group = broker.register_group("pool", specs("d1", "d2", "d3"))
    tasks = [Task(kind="sleep", duration=0.004) for _ in range(90)]
    sub = broker.submit(tasks)
    broker.remove_provider("d2")
    assert sub.wait(timeout=120)
    assert sub.states == {"DONE": 90}
    assert "d2" not in group and group.member_names == ["d1", "d3"]


def test_pilot_members_group(broker):
    """Groups work over the HPC (pilot) connector too."""
    members = [
        ProviderSpec(name=n, platform="hpc", connector="pilot", concurrency=4)
        for n in ("hpc1", "hpc2")
    ]
    broker.register_group("hpc_pool", members)
    tasks = [Task(kind="noop") for _ in range(32)]
    sub = broker.submit(tasks)
    assert sub.wait(timeout=60)
    assert sub.states == {"DONE": 32}


def test_groups_and_standalone_mix(broker):
    broker.register_group("pool", specs("mx1", "mx2"))
    broker.register_provider(ProviderSpec(name="lone", concurrency=4))
    tasks = [Task(kind="noop") for _ in range(48)]
    sub = broker.submit(tasks)
    assert sub.wait(timeout=60)
    assert sub.states == {"DONE": 48}
    bound = {t.group or t.provider for t in tasks}
    assert bound <= {"pool", "lone"} and len(bound) == 2
