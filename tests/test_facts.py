"""FACTS science sanity + workflow integration through the broker."""
import numpy as np

from repro.facts import model as facts


def test_preprocess_deterministic():
    a = facts.preprocess(3, seed=1)
    b = facts.preprocess(3, seed=1)
    np.testing.assert_array_equal(a["gsat"], b["gsat"])
    c = facts.preprocess(4, seed=1)
    assert not np.array_equal(a["gsat"], c["gsat"])


def test_fit_recovers_positive_sensitivity():
    pre = facts.preprocess(0, seed=0)
    fitted = facts.fit(pre)
    a, b = fitted["theta"]
    assert a > 0  # warming raises sea level
    assert fitted["sigma2"] > 0


def test_projection_quantiles_ordered():
    pre = facts.preprocess(1, seed=0)
    fitted = facts.fit(pre)
    proj = facts.project(pre, fitted, n_samples=500, seed=0)
    out = facts.postprocess(proj)
    q = out["quantiles"]
    assert q["p5"] < q["p17"] < q["p50"] < q["p83"] < q["p95"]
    assert 0 < q["p50"] < 3000  # plausible mm range for 2100


def test_more_samples_tighter_median():
    pre = facts.preprocess(2, seed=0)
    fitted = facts.fit(pre)
    meds = [
        facts.postprocess(facts.project(pre, fitted, n_samples=n, seed=s))["quantiles"]["p50"]
        for n, s in ((2000, 1), (2000, 2))
    ]
    assert abs(meds[0] - meds[1]) / max(abs(meds[0]), 1) < 0.2


def test_full_workflow_through_broker(tmp_path):
    from repro.core import Hydra, ProviderSpec, WorkflowManager
    from repro.facts.workflow import make_workflow, result_of

    h = Hydra(pod_store="memory", workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="jet2", concurrency=4))
    wfm = WorkflowManager(h)
    wfs = [make_workflow(h.data, i, n_samples=100) for i in range(3)]
    wfm.run(wfs)
    assert all(w.done and not w.failed for w in wfs)
    r = result_of(h.data, 1)
    assert "p50" in r["quantiles"]
    h.shutdown(wait=False)


def test_workflow_with_declared_data_footprints(tmp_path):
    """With a registry the FACTS stages declare real data dependencies: the
    shared forcing archive feeds every preprocess, and the staging layer
    moves + registers the chain's modeled artifacts (core/staging.py)."""
    from repro.core import Hydra, ProviderSpec, WorkflowManager
    from repro.facts.workflow import FORCING_DATASET, make_workflow
    from repro.runtime.clock import virtual_time

    with virtual_time():
        h = Hydra(
            pod_store="memory",
            policy="data_gravity",
            streaming=True,
            batch_window=0.001,
            workdir=str(tmp_path),
        )
        h.register_provider(ProviderSpec(name="jet2", concurrency=4))
        h.register_provider(ProviderSpec(name="bridges2", platform="hpc",
                                         connector="pilot", concurrency=4))
        wfs = [
            make_workflow(h.data, i, n_samples=50, registry=h.staging.registry)
            for i in range(2)
        ]
        assert all(t.inputs for wf in wfs for t in wf.tasks)
        WorkflowManager(h).run(wfs, timeout=300)
        assert all(w.done and not w.failed for w in wfs)
        stats = h.staging_stats()
        assert stats["mb_moved"] >= 2048.0  # at least one forcing pull
        assert stats["stage_outs"] == 8  # pre/fit/proj/result x 2 instances
        assert h.staging.registry.locate(FORCING_DATASET)  # still pinned
        h.shutdown(wait=True)
