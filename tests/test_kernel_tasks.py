"""kind="kernel" tasks: real Pallas compute on the wire.

Covers the whole payload path — the KernelRuntime's rep-granular resume
contract (managers/compute.py), the checkpointer's kernel branch (progress
IS the checkpoint: lost_s == 0), a live broker executing one task per
registered kernel with ``kernel.exec`` accounting reconciling under
HYDRA_EVENTS_CHECK=1, tuned-config consultation under HYDRA_AUTOTUNE=1,
and the acceptance scenario: a searise run whose serve lane dispatches
kernel payloads completes with ZERO failed tasks under the PR-6 correlated
fault schedule."""
from __future__ import annotations

import pytest

from repro.core import Hydra, ProviderSpec, Task, TaskState
from repro.core.events import EventBus
from repro.core.managers.compute import KERNEL_RUNTIME
from repro.core.staging import DatasetRegistry
from repro.ckpt.checkpoint import TaskCheckpointer
from repro.kernels import registry as kreg
from repro.scenarios import presets
from repro.scenarios.runner import check_invariants, run_scenario

from conftest import wait_until


# ---------------------------------------------------------------------------
# KernelRuntime: rep-granular execution + resume
# ---------------------------------------------------------------------------


def test_kernel_runtime_executes_and_advances_progress():
    task = Task(kind="kernel", payload={"kernel": "moe_gmm", "reps": 2, "seed": 1})
    result = KERNEL_RUNTIME.run(task)
    assert result["kernel"] == "moe_gmm"
    assert result["reps"] == 2 and result["skipped_reps"] == 0
    assert result["kernel_s"] > 0
    assert task.progress_frac == 1.0
    assert task.kernel_stats["reps"] == 2
    assert task.kernel_stats["config"] == kreg.config_sig(
        kreg.get_kernel("moe_gmm").defaults(kreg.get_kernel("moe_gmm").tiny_shape)
    )


def test_kernel_runtime_resume_skips_completed_reps():
    """A resumed task re-enters with the progress_frac the checkpointer
    captured: only the unfinished reps run again."""
    task = Task(kind="kernel", payload={"kernel": "rglru_scan", "reps": 4})
    task.progress_frac = 0.5  # two of four reps completed before the kill
    task.kernel_done_s = 0.125
    result = KERNEL_RUNTIME.run(task)
    assert result["skipped_reps"] == 2
    assert result["reps"] == 4
    assert task.progress_frac == 1.0
    # lifetime totals: kernel_s includes the pre-kill work, so broker
    # reps/seconds accounting reconciles across preempt/resume cycles
    assert result["kernel_s"] > 0.125
    assert task.kernel_stats["kernel_s"] == result["kernel_s"]


def test_kernel_runtime_honors_explicit_payload_config():
    shape = {"B": 1, "L": 64, "dr": 128}
    task = Task(
        kind="kernel",
        payload={
            "kernel": "rglru_scan",
            "shape": shape,
            "config": {"block_d": 32},
        },
    )
    result = KERNEL_RUNTIME.run(task)
    assert result["config"] == "block_d=32"
    assert result["sig"] == kreg.shape_sig(shape, "float32")


# ---------------------------------------------------------------------------
# checkpointer kernel branch: completed reps ARE the checkpoint
# ---------------------------------------------------------------------------


def test_checkpointer_kernel_branch_loses_nothing():
    ck = TaskCheckpointer(DatasetRegistry(), EventBus(strict=False), interval_s=2.0)
    kernel = Task(kind="kernel", payload={"kernel": "rglru_scan", "reps": 4})
    assert ck.eligible(kernel)  # resumable from rep 0: never charge a retry
    assert not ck.eligible(Task(kind="noop"))
    kernel.progress_frac = 0.75
    kernel.kernel_done_s = 1.5
    ck.on_preempt(kernel)
    # the runtime's per-rep advance IS the durable boundary: unlike the
    # sleep path there is no interval rounding and no re-executed tail
    assert kernel.progress_frac == 0.75
    assert kernel.resumes == 1 and kernel.retries == 0
    assert kernel.ckpt_dataset == f"ckpt:{kernel.uid}"
    assert kernel.ckpt_dataset in kernel.inputs
    assert ck.registry.known(kernel.ckpt_dataset)
    stats = ck.stats()
    assert stats["preempted_work_s"] == pytest.approx(1.5)
    assert stats["reexecuted_s"] == 0.0


# ---------------------------------------------------------------------------
# broker execution + kernel.exec accounting (HYDRA_EVENTS_CHECK strict)
# ---------------------------------------------------------------------------


def _kernel_broker(tmp_path) -> Hydra:
    h = Hydra(pod_store="memory", streaming=True, batch_window=0.0, workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="a", concurrency=2))
    return h


def test_broker_executes_one_task_per_registered_kernel(tmp_path):
    h = _kernel_broker(tmp_path)
    tasks = [
        Task(kind="kernel", payload={"kernel": name, "reps": 1, "seed": i})
        for i, name in enumerate(sorted(kreg.KERNELS))
    ]
    h.dispatch(tasks)
    assert wait_until(lambda: all(t.done() for t in tasks), timeout=120.0)
    for t in tasks:
        assert t.tstate == TaskState.DONE and t.exception() is None
        assert t.result()["skipped_reps"] == 0
    # one kernel.exec per completed task, keyed metrics reconcile with the
    # legacy accumulators (the shutdown below re-runs the strict cross-check)
    assert h.kernel_execs == len(tasks)
    assert h.kernel_execs_by == {name: 1 for name in kreg.KERNELS}
    assert h.kernel_reps == len(tasks)
    assert h.kernel_seconds > 0
    view = h.events.view
    assert view.get("hydra.kernel.execs") == len(tasks)
    assert view.keyed_get("hydra.kernel.execs") == {name: 1 for name in kreg.KERNELS}
    exec_events = [e for e in h.events.events() if e.name == "kernel.exec"]
    assert len(exec_events) == len(tasks)
    h.shutdown(wait=True)


def test_broker_kernel_tasks_consult_tuned_cache_under_gate(tmp_path, monkeypatch):
    h = _kernel_broker(tmp_path)
    tuner = h.enable_kernel_autotune(timer="model")
    kdef = kreg.get_kernel("rglru_scan")
    tuned = tuner.tune("rglru_scan", dict(kdef.tiny_shape), "float32")
    default_sig = kreg.config_sig(kdef.defaults(kdef.tiny_shape))
    assert kreg.config_sig(tuned.config) != default_sig  # a real contrast

    monkeypatch.setenv("HYDRA_AUTOTUNE", "1")
    gated = Task(kind="kernel", payload={"kernel": "rglru_scan"})
    h.dispatch([gated])
    assert wait_until(gated.done, timeout=60.0)
    assert gated.result()["config"] == kreg.config_sig(tuned.config)

    monkeypatch.delenv("HYDRA_AUTOTUNE")
    ungated = Task(kind="kernel", payload={"kernel": "rglru_scan"})
    h.dispatch([ungated])
    assert wait_until(ungated.done, timeout=60.0)
    assert ungated.result()["config"] == default_sig

    assert len([e for e in h.events.events() if e.name == "kernel.tune"]) == 1
    assert h.events.view.get("hydra.kernel.tunes") == 1
    h.shutdown(wait=True)
    # shutdown released the process-global tuner installation
    from repro.kernels import autotune

    assert autotune._GLOBAL is not tuner


def test_enable_kernel_autotune_refuses_double_attach(tmp_path):
    h = _kernel_broker(tmp_path)
    h.enable_kernel_autotune(timer="model")
    with pytest.raises(RuntimeError):
        h.enable_kernel_autotune(timer="model")
    h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# the acceptance scenario: kernel payloads under correlated chaos
# ---------------------------------------------------------------------------


def _shrunken_kernels_spec(seed: int = 0):
    """searise_kernels at tier-1 size: same fleet, same four-event fault
    schedule, one serve wave of four single-rep kernel tasks (one per
    registered kernel) so real compute stays a few wall seconds."""
    spec = presets.searise_kernels(seed)
    spec.traffic.facts_members = 6
    spec.traffic.train_jobs = 1
    spec.traffic.serve_waves = 1
    spec.traffic.serve_tasks_per_wave = 4
    spec.traffic.serve_kernel_reps = 1
    return spec


def test_kernel_scenario_zero_failed_under_chaos():
    spec = _shrunken_kernels_spec()
    chaos = run_scenario(spec, chaos=True)
    base = run_scenario(spec, chaos=False)
    assert check_invariants(chaos, base, spec) == []
    assert chaos.failed_tasks == 0 and base.failed_tasks == 0
    for report in (chaos, base):
        k = report.kernel
        # at-least-once execution, exactly-once completion: a speculative
        # duplicate may add an exec, never lose one
        assert k["execs"] >= spec.traffic.serve_tasks_per_wave
        assert set(k["execs_by"]) == set(spec.traffic.serve_kernels)
        assert k["reps"] >= spec.traffic.serve_tasks_per_wave
        assert k["seconds"] > 0
        assert k["tunes"] == len(spec.traffic.serve_kernels)  # pre-tuned once each


@pytest.mark.chaos
def test_kernel_preset_full_smoke_scale_preempts_and_recovers():
    """The unshrunken preset (nightly): enough serve waves that the
    preempt-kill wave actually lands on kernel work mid-flight."""
    spec = presets.searise_kernels()
    chaos = run_scenario(spec, chaos=True)
    base = run_scenario(spec, chaos=False)
    assert check_invariants(chaos, base, spec) == []
    assert chaos.failed_tasks == 0
    assert chaos.preempted_tasks > 0
    want = spec.traffic.serve_waves * spec.traffic.serve_tasks_per_wave
    assert chaos.kernel["execs"] >= want
