"""Deterministic fallback for ``hypothesis`` (not installed in this container).

The real library is used when available.  Otherwise ``given`` degrades to a
small deterministic example sweep per strategy (boundary values + a few
interior points), so the property tests still run as smoke tests instead of
failing at collection.  Do NOT ``pip install hypothesis`` here — the image
is frozen (see ROADMAP.md constraints).
"""
from __future__ import annotations

import itertools

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, examples):
            self._examples = list(examples)

        def examples(self):
            return self._examples

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            mid = (lo + hi) // 2
            vals = []
            for v in (lo, lo + 1, mid, hi - 1, hi):
                if lo <= v <= hi and v not in vals:
                    vals.append(v)
            return _Strategy(vals)

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            pool = elem.examples()
            reps = -(-max_size // max(len(pool), 1))
            cycle = (pool * reps)[:max_size]
            out, seen = [], set()
            for size in {min_size, min(min_size + 1, max_size), (min_size + max_size) // 2, max_size}:
                if min_size <= size <= max_size:
                    for rot in range(min(len(pool), 3)):
                        ex = (cycle[rot:] + cycle[:rot])[:size]
                        key = tuple(ex)
                        if key not in seen:
                            seen.add(key)
                            out.append(list(ex))
            return _Strategy(out)

        @staticmethod
        def sampled_from(seq):
            return _Strategy(list(seq))

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                combos = itertools.product(*(s.examples() for s in strategies))
                for i, combo in enumerate(combos):
                    if i >= 30:  # cap the deterministic sweep
                        break
                    fn(*args, *combo, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco
