"""Roofline autotuner (kernels/autotune.py): pruning, caching, determinism,
and the HYDRA_AUTOTUNE consultation gate in kernels/ops.py.

The determinism contract is the load-bearing one: under ``timer="model"``
the whole tune is a pure function of (kernel, shape, dtype, seed), the
cached dataset payload is canonical JSON of the *choice* (never timings),
and identically-seeded runs must produce byte-identical payloads — that is
what lets tuned configs replicate through staging like any other dataset."""
from __future__ import annotations

import pytest

from repro.core.events import EventBus
from repro.core.staging import SHARED_SITE, DatasetRegistry
from repro.kernels import ops
from repro.kernels import registry as kreg
from repro.kernels.autotune import (
    Autotuner,
    autotune_enabled,
    set_autotuner,
    tuned_config,
    unset_autotuner,
)

# the exp14 demo problem: small batch x full-width feature dim, where the
# pruner collapses the frontier to the single largest admissible block
DEMO = ("rglru_scan", {"B": 1, "L": 64, "dr": 1024})


def _model_tuner(**kw) -> Autotuner:
    return Autotuner(timer="model", **kw)


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------


def test_prune_survivors_are_a_real_cut_of_the_space():
    tuner = _model_tuner()
    for name, kdef in kreg.KERNELS.items():
        shape = dict(kdef.smoke_shape)
        survivors, exhaustive = tuner.prune(name, shape, "float32")
        space_sigs = {kreg.config_sig(c) for c in kdef.space(shape)}
        assert exhaustive == len(space_sigs)
        assert 1 <= len(survivors) <= exhaustive
        assert {kreg.config_sig(c) for c in survivors} <= space_sigs


def test_prune_cuts_demo_sweep_at_least_2x_and_tune_picks_full_width():
    """The check_bench HARD floor (sweep_cut >= 2) must hold structurally,
    not just on one lucky run: rglru traffic is config-independent, so the
    Pareto frontier is exactly the largest admissible block."""
    name, shape = DEMO
    tuner = _model_tuner()
    result = tuner.tune(name, shape)
    assert result.sweep_cut >= 2.0
    assert result.exhaustive == result.swept + result.pruned
    assert result.config == {"block_d": 1024}
    assert kreg.config_sig(result.config) in result.timings


def test_vmem_budget_filters_and_degenerate_budget_falls_back_to_defaults():
    name, shape = DEMO
    kdef = kreg.get_kernel(name)
    # a budget no candidate fits: prune must yield the committed defaults
    # rather than an empty sweep, and tune must still return a usable config
    tiny = _model_tuner(vmem_budget=1)
    survivors, exhaustive = tiny.prune(name, shape)
    assert survivors == [kdef.defaults(shape)]
    assert exhaustive == len(kdef.space(shape))
    assert tiny.tune(name, shape).config == kdef.defaults(shape)
    # a budget that only admits the smallest block: the winner shrinks
    smallest = kdef.cost(shape, {"block_d": 32}, "float32").vmem_bytes
    capped = _model_tuner(vmem_budget=int(smallest))
    assert capped.tune(name, shape).config == {"block_d": 32}


# ---------------------------------------------------------------------------
# cache + events
# ---------------------------------------------------------------------------


def test_cache_hit_skips_retiming_and_emits_no_second_tune_event():
    name, shape = DEMO
    bus = EventBus(strict=False)
    tuner = _model_tuner(events=bus)
    first = tuner.tune(name, shape)
    second = tuner.tune(name, shape)
    assert not first.cached and second.cached
    assert second.config == first.config
    tune_events = [e for e in bus.events() if e.name == "kernel.tune"]
    assert len(tune_events) == 1  # the hit re-timed nothing, so no event
    assert tuner.stats() == {"tunes": 1, "swept_configs": first.swept}
    assert tune_events[0].attrs["swept"] == first.swept
    # a different shape is a different key: a genuine second sweep
    tuner.tune(name, {"B": 1, "L": 64, "dr": 128})
    assert len([e for e in bus.events() if e.name == "kernel.tune"]) == 2


def test_same_seed_runs_produce_byte_identical_payloads():
    name, shape = DEMO
    results, payloads = [], []
    for _ in range(2):
        tuner = _model_tuner(seed=7)
        r = tuner.tune(name, shape)
        results.append(r)
        payloads.append(tuner.payload(r.key))
    assert results[0].config == results[1].config
    assert isinstance(payloads[0], bytes)
    assert payloads[0] == payloads[1]
    # the payload is the choice, never the timings (timings are wall-noisy
    # under timer="wall"; keeping them out is what makes bytes comparable)
    assert b"timings" not in payloads[0]
    assert b'"seed":7' in payloads[0]


def test_winner_registers_as_pinned_shared_dataset():
    name, shape = DEMO
    registry = DatasetRegistry()
    tuner = _model_tuner(registry=registry)
    result = tuner.tune(name, shape)
    assert result.key.startswith(f"tune:{name}:")
    assert result.key.endswith(kreg.shape_sig(shape, "float32"))
    assert registry.known(result.key)
    assert registry.get(result.key).pinned
    assert SHARED_SITE in registry.locate(result.key)


# ---------------------------------------------------------------------------
# the HYDRA_AUTOTUNE gate (ops.py consultation path)
# ---------------------------------------------------------------------------


@pytest.fixture
def global_tuner():
    tuner = _model_tuner()
    set_autotuner(tuner)
    yield tuner
    unset_autotuner(tuner)


def test_tuned_config_is_env_gated(monkeypatch, global_tuner):
    name, shape = DEMO
    global_tuner.tune(name, shape)
    monkeypatch.delenv("HYDRA_AUTOTUNE", raising=False)
    assert not autotune_enabled()
    assert tuned_config(name, shape) is None  # gate off: defaults path
    monkeypatch.setenv("HYDRA_AUTOTUNE", "0")
    assert tuned_config(name, shape) is None
    monkeypatch.setenv("HYDRA_AUTOTUNE", "1")
    assert tuned_config(name, shape) == {"block_d": 1024}
    # never-tuned problems fall back to None even with the gate on
    assert tuned_config(name, {"B": 2, "L": 64, "dr": 256}) is None


def test_ops_resolution_order_explicit_beats_tuned_beats_default(
    monkeypatch, global_tuner
):
    name, shape = DEMO
    global_tuner.tune(name, shape)
    monkeypatch.setenv("HYDRA_AUTOTUNE", "1")
    import jax.numpy as jnp

    defaults = {"block_d": 512}
    assert ops._resolve(name, shape, jnp.float32, defaults, {"block_d": 64}) == {
        "block_d": 64
    }
    assert ops._resolve(name, shape, jnp.float32, defaults, {"block_d": None}) == {
        "block_d": 1024
    }
    monkeypatch.delenv("HYDRA_AUTOTUNE")
    assert ops._resolve(name, shape, jnp.float32, defaults, {"block_d": None}) == {
        "block_d": 512
    }


def test_unset_autotuner_only_clears_its_own_installation():
    a, b = _model_tuner(), _model_tuner()
    set_autotuner(a)
    unset_autotuner(b)  # a stale shutdown must not clobber the live tuner
    name, shape = DEMO
    a.tune(name, shape)
    try:
        import os

        os.environ["HYDRA_AUTOTUNE"] = "1"
        assert tuned_config(name, shape) is not None
    finally:
        os.environ.pop("HYDRA_AUTOTUNE", None)
        unset_autotuner(a)
    assert tuned_config(name, shape) is None
