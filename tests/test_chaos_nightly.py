"""Nightly chaos suite (``-m chaos``): the full-scale standing scenario and
a seed sweep of the acceptance shape.

PR CI deselects these (``-m "not slow and not chaos"``); the nightly lane
runs them to keep the zero-failed-under-adversity contract verified at a
scale and seed diversity a PR run cannot afford."""
from __future__ import annotations

import pytest

from repro.scenarios import presets
from repro.scenarios.runner import (
    check_invariants,
    makespan_inflation,
    run_scenario,
)

pytestmark = pytest.mark.chaos


def test_searise_full_holds_invariants():
    """2048-member ensemble, six fault events (incl. an intra-cloud
    degradation window and a second preempt wave)."""
    spec = presets.searise_full()
    chaos = run_scenario(spec, chaos=True)
    base = run_scenario(spec, chaos=False)
    assert check_invariants(chaos, base, spec) == []
    assert chaos.preempted_tasks > 0 and chaos.recovered_tasks > 0
    injected = chaos.chaos_stats["injected"]
    assert injected["link_window"] == 2  # partition AND degradation fired
    assert injected["preempt_kill"] == 2


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_at_scale_seed_sweep(seed):
    """The acceptance invariants are not a property of one lucky seed."""
    spec = presets.searise_at_scale(seed=seed)
    chaos = run_scenario(spec, chaos=True)
    base = run_scenario(spec, chaos=False)
    assert check_invariants(chaos, base, spec) == []
    assert makespan_inflation(chaos, base) <= spec.max_makespan_inflation


def test_smoke_determinism_across_seeds():
    """Each seed is internally reproducible; different seeds are allowed to
    (and for the preempt draw, do) differ."""
    fps = {}
    for seed in (0, 5):
        spec = presets.searise_smoke(seed=seed)
        a = run_scenario(spec, chaos=True)
        b = run_scenario(spec, chaos=True)
        assert a.fingerprint() == b.fingerprint()
        assert a.event_schedule == b.event_schedule
        fps[seed] = a.fingerprint()
    assert fps[0] != fps[5]  # the seed is part of the identity
