"""Prefill+decode must reproduce the teacher-forced forward exactly
(validates KV caches, ring buffers, recurrent states) for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model

FAMILIES = [
    "llama3-8b",  # dense GQA
    "falcon-mamba-7b",  # ssm
    "grok-1-314b",  # moe
    "arctic-480b",  # moe + dense residual
    "recurrentgemma-2b",  # hybrid rg-lru + local attn
    "seamless-m4t-medium",  # enc-dec
    "llama-3.2-vision-11b",  # vlm cross-attn
]


@pytest.mark.parametrize("arch_name", FAMILIES)
def test_decode_matches_teacher_forcing(arch_name):
    cfg = get_arch(arch_name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(hash(arch_name) % 2**31)
    B, L = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L + 1)), jnp.int32)
    bf = {"tokens": toks}
    bp = {"tokens": toks[:, :L]}
    if cfg.family == "audio":
        fr = jnp.asarray(rng.normal(size=(B, cfg.enc_len_train, cfg.d_model)), jnp.float32)
        bf["enc_frames"] = fr
        bp["enc_frames"] = fr
    if cfg.family == "vlm":
        im = jnp.asarray(rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), jnp.float32)
        bf["img_embeds"] = im
        bp["img_embeds"] = im

    ref = np.asarray(model.logits(params, bf)[:, L, :])
    _, cache = model.prefill(params, bp, cache_len=L + 1)
    lg, _ = model.decode_step(params, cache, toks[:, L : L + 1], jnp.full((B,), L, jnp.int32))
    got = np.asarray(lg[:, 0, :])
    err = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
    assert err < 2e-3, (arch_name, err)


@pytest.mark.parametrize("arch_name", ["llama3-8b", "falcon-mamba-7b", "recurrentgemma-2b"])
def test_multistep_decode(arch_name):
    """Decode 4 tokens autoregressively == teacher-forced logits at each pos."""
    cfg = get_arch(arch_name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(0)
    B, L, n_steps = 2, 12, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L + n_steps)), jnp.int32)

    full = np.asarray(model.logits(params, {"tokens": toks}))
    _, cache = model.prefill(params, {"tokens": toks[:, :L]}, cache_len=L + n_steps)
    decode = jax.jit(model.decode_step)
    for i in range(n_steps):
        pos = jnp.full((B,), L + i, jnp.int32)
        lg, cache = decode(params, cache, toks[:, L + i : L + i + 1], pos)
        ref = full[:, L + i, :]
        err = np.max(np.abs(ref - np.asarray(lg[:, 0, :]))) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 2e-3, (arch_name, i, err)
