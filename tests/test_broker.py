"""Broker integration: submission lifecycle, metrics, fault tolerance,
elastic scaling, straggler mitigation, workflows."""
import time

import pytest

from repro.core import Hydra, ProviderSpec, Task, TaskState, Workflow, WorkflowManager


@pytest.fixture
def broker(tmp_path):
    h = Hydra(pod_store="memory", workdir=str(tmp_path), tasks_per_pod=16)
    h.register_provider(ProviderSpec(name="jet2", concurrency=4))
    h.register_provider(ProviderSpec(name="aws", concurrency=4))
    h.register_provider(ProviderSpec(name="bridges2", platform="hpc", connector="pilot", concurrency=4))
    yield h
    h.shutdown(wait=False)


def test_noop_workload_completes(broker):
    tasks = [Task(kind="noop") for _ in range(200)]
    sub = broker.submit(tasks)
    assert sub.wait(timeout=60)
    assert sub.states == {"DONE": 200}
    m = sub.metrics()
    assert m.ovh > 0 and m.th > 0 and m.n_pods > 0


def test_scpp_vs_mcpp_pod_counts(broker):
    t1 = [Task(kind="noop") for _ in range(64)]
    sub1 = broker.submit(t1, partitioning="scpp")
    sub1.wait(timeout=60)
    assert sub1.metrics().n_pods == 64
    t2 = [Task(kind="noop") for _ in range(64)]
    sub2 = broker.submit(t2, partitioning="mcpp", tasks_per_pod=16)
    sub2.wait(timeout=60)
    assert sub2.metrics().n_pods <= 12  # 64/16 per bound provider group


def test_callable_task_result(broker):
    t = Task(kind="callable", fn=lambda: 7 * 6)
    broker.submit([t]).wait(timeout=30)
    assert t.result(timeout=5) == 42


def test_failing_task_retries_then_succeeds(broker):
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    t = Task(kind="callable", fn=flaky, max_retries=3)
    broker.submit([t]).wait(timeout=60)
    assert t.result(timeout=10) == "ok"
    assert calls["n"] == 3


def test_exhausted_retries_fail_task(broker):
    t = Task(kind="callable", fn=lambda: 1 / 0, max_retries=1)
    broker.submit([t]).wait(timeout=60)
    # give the retry path a moment to finish
    deadline = time.time() + 10
    while not t.done() and time.time() < deadline:
        time.sleep(0.05)
    with pytest.raises(ZeroDivisionError):
        t.result(timeout=1)


def test_provider_failure_rebinds_all_tasks(broker):
    tasks = [Task(kind="sleep", duration=0.005) for _ in range(120)]
    sub = broker.submit(tasks)
    broker.manager("aws").fail()
    assert sub.wait(timeout=120)
    assert sub.states == {"DONE": 120}
    assert not broker.proxy.get("aws").healthy


def test_elastic_add_remove(broker):
    tasks = [Task(kind="sleep", duration=0.004) for _ in range(150)]
    sub = broker.submit(tasks)
    broker.register_provider(ProviderSpec(name="azure", concurrency=8))
    broker.remove_provider("jet2")
    assert sub.wait(timeout=120)
    assert sub.states == {"DONE": 150}
    assert "jet2" not in broker.providers()
    assert "azure" in broker.providers()


def test_straggler_speculation(tmp_path):
    h = Hydra(
        pod_store="memory", workdir=str(tmp_path),
        enable_straggler_mitigation=True, straggler_factor=3.0,
    )
    h.register_provider(ProviderSpec(name="fast", concurrency=8))
    h.register_provider(ProviderSpec(name="slow", concurrency=2))
    tasks = [Task(kind="sleep", duration=0.01) for _ in range(30)]
    straggler = Task(kind="sleep", duration=8.0)
    tasks.append(straggler)
    t0 = time.perf_counter()
    sub = h.submit(tasks)
    assert sub.wait(timeout=30)
    assert time.perf_counter() - t0 < 6.0  # beat the 8s straggler
    h.shutdown(wait=False)


def test_workflow_dag_ordering(broker):
    order = []
    wf = Workflow()
    a = wf.add(Task(kind="callable", fn=lambda: order.append("a")))
    b = wf.add(Task(kind="callable", fn=lambda: order.append("b")), deps=[a])
    c = wf.add(Task(kind="callable", fn=lambda: order.append("c")), deps=[a])
    d = wf.add(Task(kind="callable", fn=lambda: order.append("d")), deps=[b, c])
    WorkflowManager(broker).run([wf])
    assert wf.done and not wf.failed
    assert order[0] == "a" and order[-1] == "d"


def test_workflow_failure_cancels_downstream(broker):
    wf = Workflow()
    a = wf.add(Task(kind="callable", fn=lambda: 1 / 0, max_retries=0))
    b = wf.add(Task(kind="noop"), deps=[a])
    WorkflowManager(broker).run([wf])
    assert wf.failed
    assert b.tstate == TaskState.CANCELED


def test_graceful_shutdown_idempotent(tmp_path):
    h = Hydra(pod_store="memory", workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="a"))
    h.submit([Task(kind="noop") for _ in range(10)]).wait(timeout=30)
    h.shutdown()
    h.shutdown(wait=False)  # second call must not raise
