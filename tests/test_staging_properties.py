"""Property tests: the batched placement-pricing path must be a pure
refactor of the per-site path.

``StagingService.transfer_cost_many`` exists only as a performance device
(one registry pass prices a whole bind batch, §Perf exp9); if it ever
disagrees with per-site ``transfer_cost_s``, the gravity policy silently
places against different costs inside a ``bind_bulk`` than outside one.
Swept here over randomized (inputs, targets) sets — including unknown and
replica-less datasets — both directly and through ``Policy.data_costs``
inside and outside ``bulk_scope()``.

Uses the deterministic hypothesis shim (tests/_hypothesis_compat.py): the
real library drives the sweep when installed, a bounded example product
otherwise."""
from __future__ import annotations

from repro.core.policy import make_policy
from repro.core.staging import StagingService
from repro.core.task import Task

from _hypothesis_compat import given, settings, st

SITES = ("jet2", "chi", "bridges2", "frontier")
DATASETS = (
    "forcing",  # replicated: shared + one cloud site
    "pre",  # single cloud replica
    "fit",  # single hpc replica
    "proj",  # shared only
    "lost",  # known but replica-less: inf cost, must be skipped
    "undeclared",  # unknown to the registry: charges nothing
)


def _service() -> StagingService:
    svc = StagingService(seed=0)
    for name, platform in (
        ("jet2", "cloud"),
        ("chi", "cloud"),
        ("bridges2", "hpc"),
        ("frontier", "hpc"),
    ):
        svc.register_site(name, platform)
    svc.registry.add("forcing", 2048.0, sites=["shared", "jet2"], pinned=True)
    svc.registry.add("pre", 512.0, sites=["chi"])
    svc.registry.add("fit", 64.0, sites=["bridges2"])
    svc.registry.add("proj", 1024.0, sites=["shared"])
    svc.registry.add("lost", 128.0, sites=[])
    return svc


class _Target:
    """The slice of a bind target Policy.data_costs relies on."""

    def __init__(self, name: str):
        self.name = name


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.sampled_from(DATASETS), min_size=0, max_size=4),
    st.lists(st.sampled_from(SITES), min_size=1, max_size=4),
)
def test_transfer_cost_many_matches_per_site(names, sites):
    svc = _service()
    batched = svc.transfer_cost_many(names, sites)
    assert set(batched) == set(sites)
    for site in sites:
        assert batched[site] == svc.transfer_cost_s(names, site)
        assert batched[site] >= 0.0
        assert batched[site] != float("inf")  # lost datasets are skipped


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.sampled_from(DATASETS), min_size=1, max_size=4),
    st.lists(st.sampled_from(SITES), min_size=1, max_size=4),
)
def test_data_costs_agree_inside_and_outside_bulk_scope(names, sites):
    svc = _service()
    policy = make_policy("data_gravity")
    policy.attach_staging(svc)
    task = Task(kind="noop", inputs=list(names))
    targets = [_Target(s) for s in sites]
    outside = policy.data_costs(task, targets)
    with policy.bulk_scope():
        first = policy.data_costs(task, targets)
        again = policy.data_costs(task, targets)
        assert again is first  # the batch cache actually served the repeat
    assert outside == first
    for site in sites:
        assert first[site] == svc.transfer_cost_s(task.inputs, site)


def test_resident_inputs_price_zero_everywhere_they_live():
    svc = _service()
    costs = svc.transfer_cost_many(["pre"], SITES)
    assert costs["chi"] == 0.0  # replica hit
    assert costs["jet2"] > 0.0  # same platform, different site: still a pull
