"""CapacityLedger invariants (core/ledger.py).

The ledger's whole value is that its O(1) counters NEVER drift from what a
from-scratch scan would compute.  Two attack angles:

  * property test: drive a random interleaving of the real broker events —
    bind/dispatch, completion, provider registration/removal/blacklist,
    group member churn, breaker trips/recoveries, acquisition begin/
    complete/abort — through the REAL broker API and assert, after every
    settled step, that the ledger equals ``Hydra._ledger_recompute()``;
  * concurrency regression: ``queue_pressure()`` read under concurrent
    enqueue/dispatch/completion traffic stays finite, non-negative, and the
    ledger still reconciles when the dust settles.

The whole tier-1 suite additionally runs with HYDRA_LEDGER_CHECK=1
(conftest.py), so every broker test doubles as a ledger cross-check; these
tests target the event sources end-on.
"""
import random
import threading

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Hydra, ProviderSpec, Task
from repro.core.ledger import CapacityLedger, LedgerDivergence
from repro.runtime.clock import virtual_time


def reconciled(h: Hydra, tries: int = 200) -> dict:
    """Assert the ledger matches the recompute once in-flight events land."""
    h.ledger.check(retries=tries, retry_sleep_s=0.005)
    return h.ledger.snapshot()


# ---------------------------------------------------------------------------
# unit-level: the counter algebra
# ---------------------------------------------------------------------------


def test_ledger_counter_algebra():
    led = CapacityLedger()
    led.upsert_direct("a", 4)
    led.upsert_direct("b", 2)
    assert led.total_slots() == 6 and led.idle_slots() == 6
    led.load_delta("a", 3)
    assert led.idle_slots() == 3
    led.load_delta("a", 2)  # over capacity: idle clamps at 0, not negative
    assert led.idle_slots() == 2 and led.total_slots() == 6
    led.load_delta("a", -5)
    assert led.idle_slots() == 6
    led.deactivate("a")
    assert led.total_slots() == 2 and led.idle_slots() == 2
    led.set_counted("a", True)
    assert led.total_slots() == 6
    led.remove("a")
    led.remove("a")  # idempotent
    assert led.total_slots() == 2
    led.begin_incoming("x", 4)
    led.begin_incoming("x", 4)  # re-begin replaces, not accumulates
    assert led.incoming_slots() == 4
    led.end_incoming("x")
    led.end_incoming("x")
    assert led.incoming_slots() == 0
    led.task_entered(5)
    led.task_resolved(2)
    assert led.backlog() == 3


def test_ledger_capacity_gain_callback_fires_outside_lock():
    led = CapacityLedger()
    gains = []

    def on_gain():
        gains.append(led.idle_slots())  # re-entering a read must not deadlock

    led.attach(on_capacity_gain=on_gain)
    led.upsert_direct("a", 2)
    led.load_delta("a", 2)
    led.load_delta("a", -1)  # idle 0 -> 1: a gain
    assert gains and gains[-1] == 1


def test_strict_divergence_raises():
    led = CapacityLedger(strict=True)
    led.attach(recompute=lambda: {"idle_slots": 99, "total_slots": 99, "incoming_slots": 0, "backlog": 0})
    with pytest.raises(LedgerDivergence):
        led.check(retries=2, retry_sleep_s=0.0)
    assert led.divergences == 1


# ---------------------------------------------------------------------------
# property test: random REAL broker event sequences
# ---------------------------------------------------------------------------


@given(st.integers(0, 9))
@settings(max_examples=10, deadline=None)
def test_random_event_sequences_never_diverge(seed):
    rng = random.Random(seed)
    with virtual_time():
        h = Hydra(pod_store="memory", streaming=True, batch_window=0.0, max_batch=64)
        # a standing fleet plus a group whose members we can churn
        for i in range(3):
            h.register_provider(ProviderSpec(name=f"s{seed}p{i}", concurrency=2))
        group = h.register_group(
            f"s{seed}g",
            [ProviderSpec(name=f"s{seed}m{i}", concurrency=2) for i in range(2)],
            failure_threshold=1,
            reset_timeout_s=0.01,
        )
        alive = [f"s{seed}p{i}" for i in range(3)]
        elastic_n = 0
        outstanding_tasks: list[Task] = []

        for step in range(30):
            op = rng.randrange(7)
            if op in (0, 1):  # dispatch a burst
                burst = [Task(kind="noop") for _ in range(rng.randint(1, 8))]
                outstanding_tasks.extend(burst)
                h.dispatch(burst)
            elif op == 2 and alive:  # blacklist-style outage
                victim = rng.choice(alive)
                alive.remove(victim)
                h.manager(victim).fail()
                h._handle_provider_down(victim)
            elif op == 3:  # scale-out: register a fresh provider
                elastic_n += 1
                name = f"s{seed}e{elastic_n}"
                h.register_provider(ProviderSpec(name=name, concurrency=2))
                alive.append(name)
            elif op == 4 and len(alive) > 1:  # scale-in: drain + deregister
                victim = alive.pop()
                h.remove_provider(victim, drain=True, deregister=True)
            elif op == 5:  # breaker trip on a group member
                member = rng.choice(group.member_names)
                group.mark_down(member)
            else:  # acquisition lifecycle
                elastic_n += 1
                spec = ProviderSpec(name=f"s{seed}a{elastic_n}", concurrency=2)
                h.begin_acquisition(spec, eta_s=100.0)
                if rng.random() < 0.5:
                    h.abort_acquisition(spec.name)
                else:
                    h.complete_acquisition(spec)
                    alive.append(spec.name)
            reconciled(h)

        # let the work finish and re-check the settled state
        h._dispatcher.drain(timeout=30)
        snap = reconciled(h)
        assert snap["idle_slots"] >= 0 and snap["total_slots"] >= 0
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# regression: queue_pressure under concurrent enqueue/dispatch
# ---------------------------------------------------------------------------


def test_queue_pressure_consistent_under_concurrent_traffic():
    with virtual_time():
        h = Hydra(pod_store="memory", streaming=True, batch_window=0.0, max_batch=64)
        for i in range(4):
            h.register_provider(ProviderSpec(name=f"qp{i}", concurrency=4))
        d = h.dispatcher()
        stop = threading.Event()
        bad: list = []

        def reader():
            while not stop.is_set():
                p = d.queue_pressure()
                if not (0.0 <= p < 1e9):
                    bad.append(p)

        readers = [threading.Thread(target=reader, daemon=True) for _ in range(3)]
        for r in readers:
            r.start()
        all_tasks = []
        for _ in range(20):
            burst = [Task(kind="noop") for _ in range(25)]
            all_tasks.extend(burst)
            h.dispatch(burst)
        assert d.drain(timeout=30)
        stop.set()
        for r in readers:
            r.join(timeout=5)
        assert not bad, f"queue_pressure out of range: {bad[:5]}"
        for t in all_tasks:
            assert t.result(timeout=10) is None
        snap = reconciled(h)
        assert snap["backlog"] == 0  # every resolved task left the backlog
        assert snap["idle_slots"] == snap["total_slots"] == 16
        h.shutdown(wait=True)


def test_tripped_fleet_at_pool_max_recovers_via_probe():
    """Livelock regression: with an autoscaler attached (throttled budget)
    and EVERY slot behind an OPEN breaker, the event-driven ledger reads 0
    idle forever — the OPEN -> HALF_OPEN transition only happens inside a
    dispatch.  The stall path must fall back to the time-aware probe peek
    (broker.probe_slots) once the reset windows elapse, or a fully-tripped
    fleet at pool max never receives the probe that recovers it.  Wall
    clock: breaker windows must elapse by real time while no task moves the
    virtual clock."""
    from repro.core.autoscaler import LaunchSpec, ProviderPool, cloud_startup

    h = Hydra(pod_store="memory", streaming=True, batch_window=0.0)
    h.register_group(
        "pg",
        [ProviderSpec(name=f"pm{i}", concurrency=2) for i in range(2)],
        failure_threshold=1,
        reset_timeout_s=0.15,
    )
    pool = ProviderPool(
        [
            LaunchSpec(
                template=ProviderSpec(name="nope", platform="cloud"),
                min_instances=0,
                max_instances=0,  # pool exhausted: no replacement capacity
                latency=cloud_startup(1.0),
            )
        ]
    )
    h.autoscale(pool, tick_s=0.05)
    group = h.group("pg")
    group.mark_down("pm0")
    group.mark_down("pm1")
    assert h.idle_slots() == 0 and h.total_slots() == 0
    tasks = [Task(kind="noop") for _ in range(8)]
    h.dispatch(tasks)
    for t in tasks:
        assert t.result(timeout=20) is None  # recovered via half-open probe
    reconciled(h)
    h.shutdown(wait=True)


def test_backlog_counts_distinct_unresolved_submitted_tasks():
    with virtual_time():
        h = Hydra(pod_store="memory", streaming=True, batch_window=0.0)
        h.register_provider(ProviderSpec(name="bl0", concurrency=4))
        tasks = [Task(kind="noop") for _ in range(10)]
        h.dispatch(tasks)
        for t in tasks:
            t.result(timeout=10)
        snap = reconciled(h)
        assert snap["backlog"] == 0
        h.shutdown(wait=True)


def test_prune_retires_metrics_and_bounds_submissions():
    with virtual_time():
        h = Hydra(pod_store="memory", streaming=True, batch_window=0.0, max_batch=16)
        h.register_provider(ProviderSpec(name="pr0", concurrency=4))
        tasks = [Task(kind="noop") for _ in range(400)]
        h.dispatch(tasks)
        for t in tasks:
            t.result(timeout=30)
        h._dispatcher.drain(timeout=10)
        h._prune_finished_submissions()
        with h._lock:
            live = len(h._submissions)
        assert live == 0  # everything resolved: nothing retained
        totals = h.phase_totals()  # retired totals survive the prune
        assert totals.get("bind", 0) >= 0 and "submit" in totals
        with h._lock:
            assert h._retired["n_tasks"] == 400
        h.shutdown(wait=True)
