"""Int8 error-feedback gradient compression (multi-device via subprocess)."""
import os
import subprocess
import sys
import textwrap

import numpy as np

import jax
from repro.compat import compat_make_mesh
import jax.numpy as jnp

from repro.optim import compression as C


def test_quantize_dequantize_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(33, 17)), jnp.float32)
    q, scale = C._quant(C._to_blocks(x, 1))
    deq = C._dequant(q, scale).reshape(-1)[: x.size].reshape(x.shape)
    # int8 block quantization: error < scale/2 per element
    per_block_bound = np.repeat(np.asarray(scale), C.BLOCK)[: x.size].reshape(x.shape)
    assert np.all(np.abs(np.asarray(deq - x)) <= per_block_bound * 0.51 + 1e-7)


def test_compression_state_shapes():
    st = C.compression_state(jax.ShapeDtypeStruct((37, 53), jnp.float32), 8)
    assert st["worker_err"].shape == (37, 53)
    assert st["owner_err"].shape[1] == C.BLOCK


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import compat_make_mesh, compat_shard_map
    from repro.optim import compression as C

    mesh = compat_make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    shape = (37, 53)
    xs = rng.normal(size=(8,) + shape).astype(np.float32)
    true_mean = xs.mean(0)
    state = C.compression_state(jax.ShapeDtypeStruct(shape, jnp.float32), 8)

    def f(x_local, st):
        return C.compressed_mean(x_local[0], st, "data")

    fm = compat_shard_map(f, mesh=mesh, in_specs=(P("data"), P()), out_specs=P())
    got, st = jax.jit(fm)(jnp.asarray(xs), state)
    one_shot = float(np.max(np.abs(np.asarray(got) - true_mean)) / np.max(np.abs(true_mean)))
    assert one_shot < 0.05, one_shot

    accum = np.zeros(shape); errs = []
    for i in range(20):
        got, st = jax.jit(fm)(jnp.asarray(xs), st)
        accum += np.asarray(got)
        errs.append(np.max(np.abs(accum / (i + 1) - true_mean)))
    assert errs[-1] < errs[0] / 5, (errs[0], errs[-1])  # EF kills the bias
    print("COMPRESSION_OK", one_shot, errs[-1])
""")


def test_compressed_allreduce_with_error_feedback_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], env=env, capture_output=True, text=True, timeout=300
    )
    assert "COMPRESSION_OK" in out.stdout, out.stdout + out.stderr
