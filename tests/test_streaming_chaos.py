"""Chaos: random ProviderDown injections against a 2-member group while 50
DAG instances stream through the dispatcher.  Zero tasks may end FAILED and
every workflow must complete (extends tests/test_groups.py failover patterns
to the streaming dispatcher)."""
import random
import threading
import time

import pytest

from repro.core import (
    BreakerState,
    Hydra,
    ProviderSpec,
    Task,
    TaskState,
    Workflow,
    WorkflowManager,
)

pytestmark = pytest.mark.slow  # deselectable on PR CI runs (-m "not slow")


def chain_workflows(n_instances: int, stages: int, duration: float) -> list[Workflow]:
    wfs = []
    for i in range(n_instances):
        wf = Workflow(name=f"chaos.{i:05d}")
        prev = None
        for _ in range(stages):
            t = Task(kind="sleep", duration=duration, max_retries=4)
            prev = wf.add(t, deps=[prev] if prev else None)
        wfs.append(wf)
    return wfs


def test_chaos_streaming_failover_zero_failed_tasks(tmp_path):
    rng = random.Random(0xC0FFEE)
    h = Hydra(
        pod_store="memory",
        workdir=str(tmp_path),
        streaming=True,
        batch_window=0.002,
        max_batch=64,
    )
    group = h.register_group(
        "pool",
        [ProviderSpec(name=n, concurrency=8) for n in ("cm1", "cm2")],
        reset_timeout_s=0.05,
    )
    wfm = WorkflowManager(h)
    wfs = chain_workflows(50, stages=4, duration=0.004)
    done = threading.Event()

    def runner():
        wfm.run(wfs, timeout=180)
        done.set()

    th = threading.Thread(target=runner, daemon=True)
    th.start()

    # inject outages mid-stream: one member at a time, always letting the
    # breaker close again before the next strike (a 2-member pool with both
    # members down has, by design, nowhere to fail over to)
    injections = 0
    while not done.is_set() and injections < 5:
        time.sleep(rng.uniform(0.05, 0.15))
        if done.is_set():
            break
        victim = rng.choice(group.member_names)
        h.manager(victim).fail()
        injections += 1
        time.sleep(rng.uniform(0.02, 0.06))  # stay down mid-stream
        h.manager(victim).recover()
        deadline = time.time() + 10.0
        while (
            not done.is_set()
            and group.breaker_state(victim) != BreakerState.CLOSED
            and time.time() < deadline
        ):
            time.sleep(0.01)

    assert done.wait(timeout=180), "workflows did not finish under chaos"
    th.join(timeout=10)
    assert injections >= 1  # chaos actually happened

    all_tasks = [t for wf in wfs for t in wf.tasks]
    states = {}
    for t in all_tasks:
        states[t.tstate.value] = states.get(t.tstate.value, 0) + 1
    assert states == {"DONE": 200}, f"non-DONE tasks under chaos: {states}"
    assert all(wf.done and not wf.failed for wf in wfs)
    assert not any(t.tstate == TaskState.FAILED for t in all_tasks)
    # failover left its audit trail: some task was re-routed or a breaker
    # tripped on at least one member
    trips = sum(r["trips"] for r in h.group_rows())
    assert trips >= 1
    h.shutdown(wait=True)


def test_chaos_elastic_member_removal_mid_stream(tmp_path):
    """Permanent member loss (remove_provider) during streaming dispatch:
    survivors absorb everything, still zero failed tasks."""
    h = Hydra(
        pod_store="memory",
        workdir=str(tmp_path),
        streaming=True,
        batch_window=0.002,
    )
    group = h.register_group(
        "pool", [ProviderSpec(name=n, concurrency=8) for n in ("em1", "em2", "em3")]
    )
    wfm = WorkflowManager(h)
    wfs = chain_workflows(20, stages=4, duration=0.004)
    done = threading.Event()

    def runner():
        wfm.run(wfs, timeout=120)
        done.set()

    threading.Thread(target=runner, daemon=True).start()
    time.sleep(0.05)
    h.remove_provider("em2")
    assert done.wait(timeout=120)
    assert all(wf.done and not wf.failed for wf in wfs)
    assert "em2" not in group
    assert all(t.tstate == TaskState.DONE for wf in wfs for t in wf.tasks)
    h.shutdown(wait=True)
