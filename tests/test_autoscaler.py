"""Elastic autoscaler: latency models, pool bookkeeping, the pressure ->
hysteresis -> acquire/drain control loop, dynamic group membership, and the
chaos case (member dies during scale-in drain => zero failed tasks).

Everything timed runs under a VirtualClock: acquisition latencies of tens of
virtual seconds (cloud) to minutes (HPC) cost real milliseconds, and the
seeded ProviderPool RNG makes every latency draw reproducible.
"""
import random
import threading
import time

import pytest

from repro.core import Hydra, ProviderSpec, Task
from repro.core.autoscaler import (
    Autoscaler,
    LatencyModel,
    LaunchSpec,
    ProviderPool,
    cloud_startup,
    hpc_queue_wait,
)
from repro.core.provider import ValidationError
from repro.core.task import TaskState
from repro.runtime.clock import get_clock, virtual_time


from conftest import wait_until


def cloud_template(name="pool", concurrency=4, **kw):
    return ProviderSpec(name=name, platform="cloud", connector="caas", concurrency=concurrency, **kw)


def assert_zero_failures(tasks):
    for t in tasks:
        assert t.tstate == TaskState.DONE, f"{t.uid}: {t.tstate}"
        assert t.exception() is None


# ---------------------------------------------------------------------------
# Latency models + pool bookkeeping
# ---------------------------------------------------------------------------


def test_latency_models_deterministic_and_platform_ordered():
    a, b = random.Random(42), random.Random(42)
    model = cloud_startup()
    assert [model.sample(a) for _ in range(10)] == [model.sample(b) for _ in range(10)]
    # cloud startup is seconds, HPC queue wait is minutes
    assert cloud_startup().expected_s < hpc_queue_wait().expected_s
    # lognormal sample mean tracks the configured mean (loose bound)
    rng = random.Random(0)
    mean = sum(cloud_startup(mean_s=45.0).sample(rng) for _ in range(500)) / 500
    assert 35.0 < mean < 55.0


def test_latency_model_fixed_and_uniform():
    rng = random.Random(1)
    assert LatencyModel(distribution="fixed", mean_s=7.5).sample(rng) == 7.5
    u = LatencyModel(distribution="uniform", lo_s=2.0, hi_s=4.0)
    for _ in range(20):
        assert 2.0 <= u.sample(rng) <= 4.0
    with pytest.raises(ValidationError):
        LatencyModel(distribution="bogus").sample(rng)


def test_launch_spec_validation():
    with pytest.raises(ValidationError):
        LaunchSpec(template=cloud_template(), min_instances=3, max_instances=1)
    with pytest.raises(ValidationError):
        ProviderPool([])
    with pytest.raises(ValidationError):
        ProviderPool([LaunchSpec(template=cloud_template("x")), LaunchSpec(template=cloud_template("x"))])
    # platform default latency models are attached automatically
    assert LaunchSpec(template=cloud_template()).latency.mean_s == cloud_startup().mean_s


def test_pool_instance_names_never_recycled():
    pool = ProviderPool([LaunchSpec(template=cloud_template("jet2"), max_instances=8)])
    launch = pool.specs[0]
    s1 = pool.request_instance(launch)
    s2 = pool.request_instance(launch)
    assert (s1.name, s2.name) == ("jet2-1", "jet2-2")
    pool.note_gone(launch, s1.name)
    assert pool.request_instance(launch).name == "jet2-3"


def test_pool_candidates_fastest_first():
    fast = LaunchSpec(template=cloud_template("cloudy"), latency=cloud_startup(mean_s=30))
    slow = LaunchSpec(
        template=ProviderSpec(name="hpc", platform="hpc", connector="pilot"),
        latency=hpc_queue_wait(mean_s=600),
    )
    pool = ProviderPool([slow, fast])
    assert [s.template.name for s in pool.candidates()] == ["cloudy", "hpc"]


# ---------------------------------------------------------------------------
# Acquisition state on the broker
# ---------------------------------------------------------------------------


def test_pending_acquisition_visible_in_scale_stats():
    h = Hydra(pod_store="memory")
    try:
        h.register_provider(cloud_template("seed", concurrency=2))
        spec = cloud_template("elastic-1", concurrency=4)
        h.begin_acquisition(spec, eta_s=30.0)
        stats = h.scale_stats()
        assert stats["incoming_slots"] == 4
        assert [p["name"] for p in stats["pending_acquisitions"]] == ["elastic-1"]
        assert h.abort_acquisition("elastic-1") is True
        assert h.incoming_slots() == 0
        # completing an aborted acquisition must NOT register a zombie
        assert h.complete_acquisition(spec) is None
        assert h.providers() == ["seed"]
    finally:
        h.shutdown(wait=False)


def test_complete_acquisition_joins_live_group():
    h = Hydra(pod_store="memory")
    try:
        h.register_group("g", [cloud_template("m1", concurrency=2)])
        spec = cloud_template("m2", concurrency=4)
        h.begin_acquisition(spec, eta_s=5.0, group="g")
        handle = h.complete_acquisition(spec)
        group = h.group("g")
        assert handle is not None and handle.group == "g"
        assert set(group.member_names) == {"m1", "m2"}
        # the joined member is reachable through group metrics and enlarges
        # the synthetic capacity only element-wise upward
        assert any(r["member"] == "m2" for r in h.group_rows())
        # and it is NOT a direct bind target (grouped members never are)
        assert all(t.name != "m2" for t in h.proxy.bind_targets())
    finally:
        h.shutdown(wait=False)


def test_remove_provider_deregister_frees_name_and_policy_state():
    h = Hydra(pod_store="memory", policy="adaptive")
    try:
        h.register_provider(cloud_template("seed", concurrency=2))
        h.register_provider(cloud_template("tmp", concurrency=2))
        h.policy.observe("tmp", 3.0)
        h.remove_provider("tmp", drain=True, deregister=True)
        assert "tmp" not in h.policy.ewma and "tmp" not in h.policy.outstanding
        h.register_provider(cloud_template("tmp", concurrency=2))  # name recycles
    finally:
        h.shutdown(wait=False)


# ---------------------------------------------------------------------------
# The control loop
# ---------------------------------------------------------------------------


def elastic_broker(min_instances=0, max_instances=4, seed_concurrency=2, **scaler_kw):
    h = Hydra(streaming=True, pod_store="memory", batch_window=0.002, max_batch=64)
    h.register_provider(cloud_template("seed", concurrency=seed_concurrency))
    pool = ProviderPool(
        [
            LaunchSpec(
                template=cloud_template("jet2", concurrency=4),
                min_instances=min_instances,
                max_instances=max_instances,
                latency=cloud_startup(mean_s=20.0),
            )
        ],
        seed=7,
    )
    kw = dict(tick_s=1.0, warmup_ticks=2, cooldown_ticks=3)
    kw.update(scaler_kw)
    scaler = h.autoscale(pool, **kw)
    return h, scaler


def test_scale_out_under_sustained_pressure_then_drain():
    with virtual_time():
        h, scaler = elastic_broker(max_instances=4)
        tasks = [Task(kind="sleep", duration=4.0) for _ in range(48)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=20.0)
        assert_zero_failures(tasks)
        # sustained pressure demanded extra capacity and it arrived
        assert scaler.arrivals >= 2
        assert wait_until(lambda: scaler.pressure() <= 0.05, timeout=10.0)  # drained
        # the elastic instances actually executed work (not just the seed)
        elastic = {t.provider for t in tasks if t.provider and t.provider != "seed"}
        assert elastic
        h.shutdown(wait=True)


def test_no_scale_out_on_brief_pressure_blip():
    with virtual_time():
        h, scaler = elastic_broker(warmup_ticks=30)
        tasks = [Task(kind="sleep", duration=1.0) for _ in range(6)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=15.0)
        assert_zero_failures(tasks)
        # hysteresis: pressure subsided before the warmup elapsed
        assert scaler.acquisitions == 0
        h.shutdown(wait=True)


def test_max_bound_respected_under_heavy_pressure():
    with virtual_time():
        h, scaler = elastic_broker(max_instances=2, max_concurrent_acquisitions=8)
        tasks = [Task(kind="sleep", duration=3.0) for _ in range(96)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=25.0)
        assert_zero_failures(tasks)
        assert scaler.acquisitions <= 2
        assert len(h.providers()) <= 3  # seed + at most max_instances
        h.shutdown(wait=True)


def test_min_bound_prewarmed_and_never_released():
    with virtual_time():
        h, scaler = elastic_broker(min_instances=2, max_instances=4)
        # min instances are requested at start, before any pressure exists
        assert scaler.acquisitions >= 2
        assert wait_until(lambda: scaler.arrivals >= 2, timeout=15.0)
        # a long idle stretch may release down TO the min, never below
        assert wait_until(lambda: scaler.ticks >= 30, timeout=15.0)
        counts = scaler.pool.counts()["jet2"]
        assert counts["live"] + counts["pending"] >= 2
        assert len(h.providers()) >= 3
        h.shutdown(wait=True)


def test_scale_in_drains_and_deregisters_after_idle():
    with virtual_time():
        h, scaler = elastic_broker(max_instances=3, cooldown_ticks=2)
        tasks = [Task(kind="sleep", duration=4.0) for _ in range(48)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=20.0)
        assert wait_until(lambda: scaler.releases >= 1, timeout=15.0)
        assert_zero_failures(tasks)
        # released instances are deregistered: the proxy no longer knows them
        gone = [
            n for n in scaler.ledger
            if scaler.ledger[n]["released_at"] is not None
        ]
        assert gone
        for name in gone:
            with pytest.raises(KeyError):
                h.proxy.get(name)
        h.shutdown(wait=True)


def test_scale_in_aborts_pending_acquisition_first():
    with virtual_time():
        # enormous acquisition latency: instances never arrive, so once the
        # small workload finishes on the seed, scale-in must WITHDRAW the
        # pending acquisitions instead of draining live ones
        h = Hydra(streaming=True, pod_store="memory")
        h.register_provider(cloud_template("seed", concurrency=2))
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=cloud_template("slow", concurrency=4),
                    latency=LatencyModel(distribution="fixed", mean_s=10_000.0),
                )
            ],
            seed=3,
        )
        scaler = h.autoscale(pool, tick_s=1.0, warmup_ticks=2, cooldown_ticks=2)
        tasks = [Task(kind="sleep", duration=6.0) for _ in range(40)]
        h.dispatch(tasks)
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=20.0)
        assert_zero_failures(tasks)
        assert wait_until(lambda: scaler.aborts >= 1, timeout=15.0)
        assert scaler.releases == 0  # nothing live was ever drained
        assert wait_until(lambda: h.incoming_slots() == 0, timeout=15.0)
        h.shutdown(wait=True)


def test_chaos_member_dies_during_scale_in_drain():
    """The chaos case: while an elastic instance is draining out (scale-in),
    another provider dies hard.  Both orphan sets must re-bind onto the
    survivors with ZERO failed tasks."""
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory", batch_window=0.002)
        h.register_provider(cloud_template("seed", concurrency=4))
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=cloud_template("jet2", concurrency=4),
                    max_instances=2,
                    latency=LatencyModel(distribution="fixed", mean_s=5.0),
                )
            ],
            seed=11,
        )
        # warmup_ticks huge: the test drives acquisition/release by hand so
        # the control loop cannot race the choreography
        scaler = h.autoscale(pool, tick_s=1.0, warmup_ticks=10_000, cooldown_ticks=10_000)
        launch = pool.specs[0]
        n1 = scaler._acquire(launch)
        n2 = scaler._acquire(launch)
        assert wait_until(lambda: scaler.arrivals == 2, timeout=15.0)
        tasks = [Task(kind="sleep", duration=8.0, max_retries=4) for _ in range(36)]
        h.dispatch(tasks)
        assert wait_until(
            lambda: any(t.tstate == TaskState.RUNNING for t in tasks), timeout=15.0
        )
        # scale-in drain of one elastic member while ANOTHER provider dies
        release = threading.Thread(target=scaler._release, args=(launch, n2))
        release.start()
        h.manager("seed").fail()
        release.join(timeout=15.0)
        assert not release.is_alive()
        assert wait_until(lambda: all(t.done() for t in tasks), timeout=25.0)
        assert_zero_failures(tasks)  # zero failed tasks, the acceptance bar
        assert n1 in h.providers() and n2 not in h.providers()
        h.shutdown(wait=True)


def test_dispatcher_defers_unplaceable_task_while_capacity_incoming():
    with virtual_time():
        from repro.core.task import Resources

        h = Hydra(streaming=True, pod_store="memory")
        h.register_provider(cloud_template("small", concurrency=2))  # 16 cpus
        big_spec = ProviderSpec(
            name="big-1",
            platform="cloud",
            connector="caas",
            node_capacity=Resources(cpus=64, accels=0, memory_mb=1 << 20),
            concurrency=4,
        )
        h.begin_acquisition(big_spec, eta_s=30.0)
        big_task = Task(kind="noop", resources=Resources(cpus=48, memory_mb=1 << 17))
        h.dispatch([big_task])
        # unplaceable NOW, but capacity is incoming: must stay queued
        time.sleep(0.4)
        assert not big_task.done()
        assert big_task.tstate != TaskState.CANCELED
        h.complete_acquisition(big_spec)
        assert wait_until(lambda: big_task.done(), timeout=15.0)
        assert big_task.exception() is None
        assert big_task.provider == "big-1"
        h.shutdown(wait=True)


def test_unplaceable_task_still_fails_without_incoming_capacity():
    with virtual_time():
        from repro.core.task import Resources

        h = Hydra(streaming=True, pod_store="memory")
        h.register_provider(cloud_template("small", concurrency=2))
        big_task = Task(kind="noop", resources=Resources(cpus=4096))
        h.dispatch([big_task])
        assert wait_until(lambda: big_task.done(), timeout=15.0)
        assert big_task.exception() is not None
        h.shutdown(wait=True)


def test_failed_group_join_rolls_back_registration():
    # cloud spec arriving into an hpc group: add_member raises AFTER
    # register_provider succeeded — the registration must be fully undone,
    # not leaked into the direct-binding pool as a zombie
    h = Hydra(pod_store="memory")
    try:
        h.register_group(
            "hpcpool",
            [ProviderSpec(name="b2", platform="hpc", connector="pilot", concurrency=2)],
        )
        spec = cloud_template("zombie-1", concurrency=4)
        h.begin_acquisition(spec, eta_s=1.0, group="hpcpool")
        with pytest.raises(ValidationError):
            h.complete_acquisition(spec)
        with pytest.raises(KeyError):
            h.proxy.get("zombie-1")
        assert all(t.name != "zombie-1" for t in h.proxy.bind_targets())
    finally:
        h.shutdown(wait=False)


def test_autoscale_rejects_misconfigured_group_target():
    h = Hydra(streaming=True, pod_store="memory")
    try:
        h.register_group(
            "hpcpool",
            [ProviderSpec(name="b2", platform="hpc", connector="pilot", concurrency=2)],
        )
        pool = ProviderPool(
            [LaunchSpec(template=cloud_template("jet"), group="hpcpool")]
        )
        with pytest.raises(ValidationError):
            h.autoscale(pool)
        assert h.autoscaler is None or not h.autoscaler.arrivals
    finally:
        h.autoscaler = None  # failed attach leaves nothing running
        h.shutdown(wait=False)


def test_pool_quarantines_spec_after_consecutive_failures():
    pool = ProviderPool([LaunchSpec(template=cloud_template("bad"), min_instances=1)])
    launch = pool.specs[0]
    for _ in range(ProviderPool.MAX_CONSECUTIVE_FAILURES):
        spec = pool.request_instance(launch)
        pool.note_failed(launch, spec.name)
    # a spec that keeps failing leaves both the min-fill and candidate sets:
    # one broken template cannot buy providers in an unbounded loop
    assert pool.below_min() == []
    assert pool.candidates() == []
    # a successful arrival resets the quarantine counter
    spec = pool.request_instance(launch)
    pool.note_live(launch, spec.name)
    assert pool.candidates() == [launch]


def test_lost_instance_frees_pool_headroom_for_replacement():
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory")
        h.register_provider(cloud_template("seed", concurrency=2))
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=cloud_template("jet2", concurrency=4),
                    max_instances=1,
                    latency=LatencyModel(distribution="fixed", mean_s=2.0),
                )
            ]
        )
        scaler = h.autoscale(pool, tick_s=1.0, warmup_ticks=10_000, cooldown_ticks=10_000)
        launch = pool.specs[0]
        name = scaler._acquire(launch)
        assert wait_until(lambda: scaler.arrivals == 1, timeout=15.0)
        assert pool.counts()["jet2"]["live"] == 1
        assert pool.candidates() == []  # at max
        # hard outage: the broker blacklists the instance
        h._handle_provider_down(name)
        assert pool.counts()["jet2"]["live"] == 0
        assert pool.candidates() == [launch]  # headroom freed: replaceable
        assert scaler.ledger[name]["released_at"] is not None
        h.shutdown(wait=True)


def test_releasable_never_counts_pending_toward_min():
    pool = ProviderPool(
        [LaunchSpec(template=cloud_template("jet2"), min_instances=1, max_instances=4)]
    )
    launch = pool.specs[0]
    live = pool.request_instance(launch)
    pool.note_live(launch, live.name)
    pool.request_instance(launch)  # stays pending
    # live(1) + pending(1) > min(1), but draining the only LIVE instance
    # would break the standing-capacity promise while the pending one can
    # still fail or be withdrawn
    assert pool.releasable() is None


def test_unplaceable_task_fails_fast_when_incoming_cannot_fit_it():
    with virtual_time():
        from repro.core.task import Resources

        h = Hydra(streaming=True, pod_store="memory")
        h.register_provider(cloud_template("small", concurrency=2))
        # incoming capacity exists, but is far too small for the task:
        # deferring would stall the error until every acquisition landed
        h.begin_acquisition(cloud_template("tiny-1", concurrency=2), eta_s=1000.0)
        big_task = Task(kind="noop", resources=Resources(cpus=4096))
        h.dispatch([big_task])
        assert wait_until(lambda: big_task.done(), timeout=15.0)
        assert big_task.exception() is not None
        h.shutdown(wait=True)


def test_autoscaler_stop_withdraws_inflight_acquisitions():
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory")
        h.register_provider(cloud_template("seed", concurrency=2))
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=cloud_template("never", concurrency=4),
                    latency=LatencyModel(distribution="fixed", mean_s=100_000.0),
                )
            ]
        )
        scaler = h.autoscale(pool, tick_s=1.0, warmup_ticks=1)
        h.dispatch([Task(kind="sleep", duration=5.0) for _ in range(32)])
        assert wait_until(lambda: scaler.acquisitions >= 1, timeout=15.0)
        scaler.stop(wait=True)
        assert h.incoming_slots() == 0  # no orphaned pending records
        assert pool.counts()["never"]["pending"] == 0
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Zero-supply pressure semantics + deferred parked demand
# ---------------------------------------------------------------------------


def test_zero_supply_pressure_is_inf_and_still_buys_capacity():
    """Regression for the supply==0 degeneration: ``demand / max(supply, 1)``
    read a 100k-task queue against a dead fleet as 'pressure 100000' — a
    number that merely scaled with backlog.  The sentinel is now +inf, the
    scale-out gate trips on it, and stats() stays JSON-safe (null)."""
    import json

    with virtual_time():
        from repro.core.admission import TenantSpec

        # no providers registered at all: supply is truly zero; the front
        # door keeps the dispatch budget idle-gated (work waits in lanes)
        h = Hydra(streaming=True, pod_store="memory", tenants=[TenantSpec(name="t")])
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=cloud_template("burst", concurrency=4),
                    latency=LatencyModel(distribution="fixed", mean_s=100_000.0),
                    max_instances=2,
                )
            ]
        )
        scaler = Autoscaler(h, pool, warmup_ticks=1)  # not started: manual ticks
        assert scaler.pressure() == 0.0  # no demand: 0.0 whatever the supply
        h.dispatch([Task(kind="noop", tenant="t") for _ in range(500)])
        assert wait_until(lambda: h.queue_depth() == 500)
        assert scaler.pressure() == float("inf")
        scaler._tick()  # warmup_ticks=1: the inf reading trips scale-out NOW
        assert scaler.acquisitions >= 1
        assert pool.counts()["burst"]["pending"] >= 1
        stats = scaler.stats()
        assert stats["last_pressure"] is None  # inf is not JSON: emitted as null
        json.dumps(stats)
        scaler.stop(wait=True)
        h.shutdown(wait=True)


def test_tripped_group_fleet_reads_as_infinite_pressure():
    """A fleet whose every group member is breaker-OPEN has zero live slots:
    queue_pressure must read +inf (the MOST pressured state), not the raw
    pending count, and not a saturated-but-live finite value."""
    with virtual_time():
        h = Hydra(streaming=True, pod_store="memory")
        group = h.register_group(
            "g", [cloud_template("m1", concurrency=2), cloud_template("m2", concurrency=2)]
        )
        d = h.dispatcher()
        assert d.queue_pressure() == 0.0
        for m in group.member_names:
            group.mark_down(m)
        assert h.total_slots() == 0
        h.dispatch([Task(kind="noop") for _ in range(64)])
        assert wait_until(lambda: d.pending() > 0)
        assert d.queue_pressure() == float("inf")
        assert d.stats()["queue_pressure"] is None  # JSON-safe sentinel
        h.shutdown(wait=True)


def test_saturated_but_live_fleet_reads_finite_pressure():
    with virtual_time(auto_advance=False) as clock:
        from repro.core.admission import TenantSpec

        # a front door keeps queued work in the dispatcher's lanes (budget
        # gated on idle slots), so pending() is observable while saturated
        h = Hydra(
            streaming=True,
            pod_store="memory",
            batch_window=0.0,
            tenants=[TenantSpec(name="t")],
        )
        h.register_provider(cloud_template("p", concurrency=2))
        d = h.dispatcher()
        # freeze the clock: the two sleeps occupy both slots until advanced
        sleeps = [Task(kind="sleep", duration=60.0, tenant="t") for _ in range(2)]
        h.dispatch(sleeps)
        assert wait_until(lambda: h.idle_slots() == 0, timeout=10.0)
        backlog = [Task(kind="noop", tenant="t") for _ in range(40)]
        h.dispatch(backlog)
        assert wait_until(lambda: d.pending() == 40)
        p = d.queue_pressure()
        assert p == 40.0  # finite raw pending: in-flight work frees slots
        import math

        assert math.isfinite(p)
        # unfreeze: EVERYTHING (sleeps included) drains before shutdown —
        # an executor thread still inside clock.sleep would wedge it
        assert wait_until(
            lambda: (
                clock.advance(30.0),
                all(t.done() for t in sleeps + backlog),
            )[1],
            timeout=30.0,
        )
        h.shutdown(wait=True)


def test_interactive_pressure_gate_opens_scale_out():
    """With ``interactive_scale_out_pressure`` set, interactive-lane depth
    alone trips the scale-out path even when aggregate pressure is tame."""
    with virtual_time(auto_advance=False):
        from repro.core.admission import TenantSpec

        h = Hydra(
            streaming=True,
            pod_store="memory",
            batch_window=0.0,
            tenants=[TenantSpec(name="serve")],
        )
        h.register_provider(cloud_template("p", concurrency=8))
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=cloud_template("burst", concurrency=4),
                    latency=LatencyModel(distribution="fixed", mean_s=100_000.0),
                )
            ]
        )
        scaler = Autoscaler(
            h,
            pool,
            warmup_ticks=1,
            scale_out_pressure=100.0,  # aggregate gate unreachable
            interactive_scale_out_pressure=0.5,
        )
        # saturate the 8 slots with frozen sleeps, then queue interactive work
        sleeps = [Task(kind="sleep", duration=60.0) for _ in range(8)]
        h.dispatch(sleeps)
        assert wait_until(lambda: h.idle_slots() == 0, timeout=10.0)
        serve = [
            Task(kind="noop", tenant="serve", slo_class="interactive")
            for _ in range(16)
        ]
        h.dispatch(serve)
        assert wait_until(
            lambda: h.queue_depth_by_class().get("interactive", 0) >= 16
        )
        assert scaler.pressure() < 100.0
        assert scaler.interactive_pressure() >= 0.5
        scaler._tick()
        assert scaler.acquisitions >= 1
        scaler.stop(wait=True)
        # unfreeze so the frozen sleeps and queued work drain before shutdown
        clock = get_clock()
        assert wait_until(
            lambda: (
                clock.advance(30.0),
                all(t.done() for t in sleeps + serve),
            )[1],
            timeout=30.0,
        )
        h.shutdown(wait=True)
