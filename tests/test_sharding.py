"""Sharding rule resolution: strategies, divisibility drops, spill targets."""
import jax
from repro.compat import compat_make_mesh
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.models.model import Model
from repro.parallel import sharding as sh

SIZES = {"data": 16, "model": 16}
AXES = ("data", "model")


def test_tp_rules_basic():
    rules = sh.STRATEGIES["tp"].param_rules
    assert sh.resolve_axes(("embed", "mlp"), rules, AXES) == P(None, "model")
    assert sh.resolve_axes(("vocab", "embed"), rules, AXES) == P("model", None)


def test_duplicate_mesh_axis_dropped():
    rules = sh.STRATEGIES["tp"].param_rules
    # experts takes 'model'; mlp cannot reuse it
    ps = sh.resolve_axes(("experts", "embed", "mlp"), rules, AXES)
    assert ps == P("model", None, None)


def test_divisibility_drop_and_spill_to_embed():
    rules = sh.STRATEGIES["tp"].param_rules
    # 56 heads cannot shard 16 ways; spills onto embed (7168 divides)
    ps = sh.resolve_axes(("embed", "heads", None), rules, AXES, (7168, 56, 128), SIZES)
    assert ps == P("model", None, None)
    # divisible heads shard normally
    ps = sh.resolve_axes(("embed", "heads", None), rules, AXES, (4096, 32, 128), SIZES)
    assert ps == P(None, "model", None)


def test_cache_seq_spill():
    rules = sh.STRATEGIES["tp"].act_rules
    # 8 KV heads cannot shard 16 ways -> cache becomes sequence-sharded
    ps = sh.resolve_axes(
        ("layers", "batch", "cache_seq", "kv_heads_act", None),
        rules, AXES, (32, 128, 32768, 8, 128), SIZES,
    )
    assert ps == P(None, "data", "model", None, None)


def test_fsdp_tp_shards_embed_over_data():
    rules = sh.STRATEGIES["fsdp_tp"].param_rules
    ps = sh.resolve_axes(("embed", "mlp"), rules, AXES, (16384, 53248), SIZES)
    assert ps == P("data", "model")


def test_default_strategy_by_size():
    assert sh.default_strategy(get_arch("llama3-8b")).name == "tp"
    assert sh.default_strategy(get_arch("llama3-405b")).name == "fsdp_tp"
    grok = sh.default_strategy(get_arch("grok-1-314b"))
    assert grok.param_rules["experts"] is None  # 8 experts can't shard 16-way


def test_param_pspec_tree_covers_every_leaf():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    for name in ("llama3-8b", "arctic-480b", "falcon-mamba-7b", "recurrentgemma-2b"):
        arch = get_arch(name)
        model = Model(arch)
        specs = model.specs()
        pspecs = sh.param_pspec_tree(specs, sh.default_strategy(arch), mesh)
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "axes")))
        n_ps = len(jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_ps


def test_shard_x_noop_outside_context():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    assert sh.shard_x(x, "batch", None) is x
