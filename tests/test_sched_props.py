"""Property-based scheduler invariants under the VirtualClock.

For random DAGs (<= 200 nodes) run through BOTH dispatch modes:

  * a task is dispatched only after every dependency is DONE (virtual
    trace ordering: first ``submitted`` >= each dep's last ``exec_done``),
  * no task is ever dispatched twice (exactly one ``submitted`` event when
    no faults are injected),
  * streaming is never slower than frontier mode beyond one wave of
    virtual-time skew, and never produces more pods.

Virtual time is what makes this suite feasible: each example schedules
hundreds of multi-second sleep tasks in real milliseconds.
"""
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import Hydra, ProviderSpec, Task, TaskState, Workflow, WorkflowManager
from repro.runtime.clock import virtual_time

pytestmark = pytest.mark.slow  # deselectable on PR CI runs (-m "not slow")

# one wave of sleep: the unit of virtual-time skew for makespan comparison
# (the auto-advancer may tick while a readiness event is still in flight
# between threads, costing a task-duration wave; streaming crosses two more
# thread handoffs than frontier mode, so allow two waves of skew)
WAVE = 1.0
SKEW = 2 * WAVE


def random_dag(seed: int, duration: float = WAVE) -> Workflow:
    """A random DAG of sleep tasks: <= 200 nodes, <= 3 deps per node drawn
    from recent predecessors (bounded depth, realistic workflow shape)."""
    rng = random.Random(seed)
    n = 20 + (seed * 37) % 180
    wf = Workflow(name=f"prop.{seed}.{n}")
    nodes: list[Task] = []
    for i in range(n):
        k = rng.randint(0, min(3, len(nodes)))
        window = nodes[-10:]  # recent predecessors only: keeps depth sane
        deps = rng.sample(window, min(k, len(window))) if window else []
        nodes.append(wf.add(Task(kind="sleep", duration=duration), deps=deps))
    return wf


def run_mode(seed: int, streaming: bool) -> dict:
    # a generous stability window (~10ms of quiet) lets readiness events
    # finish their thread handoffs before the advancer ticks a wave.
    # SCPP (one task per pod) in BOTH modes: co-scheduled MCPP pod tasks
    # execute sequentially by design, which would make makespan measure pod
    # packing rather than scheduling order — the invariant under test here.
    with virtual_time(stability_polls=20) as clock:
        h = Hydra(
            pod_store="memory",
            streaming=streaming,
            batch_window=0.0,
            max_batch=512,
            partitioning="scpp",
        )
        h.register_provider(ProviderSpec(name="p1", concurrency=64))
        h.register_provider(ProviderSpec(name="p2", concurrency=64))
        wf = random_dag(seed)
        WorkflowManager(h, partitioning="scpp").run([wf], timeout=3600)
        ok = wf.done and not wf.failed
        stats = h.stream_stats()
        h.shutdown(wait=True)
        starts = [t.trace.first("exec_start") for t in wf.tasks]
        ends = [t.trace.last("exec_done") for t in wf.tasks]
        makespan = (
            max(e for e in ends if e is not None) - min(s for s in starts if s is not None)
            if all(e is not None for e in ends)
            else float("inf")
        )
        return {"wf": wf, "ok": ok, "makespan": makespan, "pods": stats["n_pods"]}


def check_dispatch_invariants(wf: Workflow) -> None:
    by_uid = {t.uid: t for t in wf.tasks}
    for t in wf.tasks:
        assert t.tstate == TaskState.DONE, f"{t.uid} ended {t.tstate}"
        submitted = [ts for ev, ts in t.trace.events if ev == "submitted"]
        assert len(submitted) == 1, f"{t.uid} dispatched {len(submitted)} times"
        for dep_uid in wf.deps[t.uid]:
            dep = by_uid[dep_uid]
            dep_done = dep.trace.last("exec_done")
            assert dep_done is not None
            assert submitted[0] >= dep_done, (
                f"{t.uid} dispatched at {submitted[0]} before dep "
                f"{dep_uid} finished at {dep_done}"
            )


@given(st.integers(0, 6))
@settings(max_examples=7, deadline=None)
def test_random_dag_scheduler_invariants(seed):
    frontier = run_mode(seed, streaming=False)
    streaming = run_mode(seed, streaming=True)
    assert frontier["ok"] and streaming["ok"]
    check_dispatch_invariants(frontier["wf"])
    check_dispatch_invariants(streaming["wf"])
    # streaming never beaten by frontier beyond the bounded virtual skew
    assert streaming["makespan"] <= frontier["makespan"] + SKEW + 1e-6, (
        f"seed {seed}: streaming {streaming['makespan']} vs "
        f"frontier {frontier['makespan']}"
    )
    # and it never fragments the workload into more pods
    assert streaming["pods"] <= frontier["pods"]
