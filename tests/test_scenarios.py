"""Sea-rise at scale: the standing scenario harness (repro/scenarios) and
the chaos engine (core/chaos.py) it drives.

Two layers of coverage:

  * unit tests of each injection point against a tiny live broker on a
    manually-driven VirtualClock — link windows open/close and restore the
    saved models, quarantine storms gate and lift, preempt kills route
    through the normal retry machinery, site outages take the provider and
    (for groups) its staging site down together;
  * the ISSUE's acceptance scenario: ``searise_at_scale`` (a 1024-member
    FACTS ensemble + train/serve traffic, four correlated fault events)
    must complete with ZERO failed tasks, makespan inflation <= 1.5x vs its
    no-chaos twin, a clean strict ledger, nothing stranded after shutdown,
    and a bit-identical report fingerprint on a rerun with the same seed.

The at-scale runs execute entirely under VirtualClock (modeled runtimes,
real footprints), so ~4k tasks x 3 runs cost tens of real seconds, not
hours."""
from __future__ import annotations

import json

import pytest

from repro.core import Hydra, ProviderSpec, Task, TaskState
from repro.core.autoscaler import LaunchSpec, LatencyModel, ProviderPool
from repro.core.chaos import (
    ChaosEngine,
    LinkWindow,
    PreemptKill,
    QuarantineStorm,
    SiteOutage,
)
from repro.core.staging import FALLBACK_LINK
from repro.runtime.clock import virtual_time
from repro.scenarios import ScenarioSpec, presets
from repro.scenarios.runner import (
    check_invariants,
    makespan_inflation,
    run_scenario,
)

from conftest import wait_until


# ---------------------------------------------------------------------------
# ChaosEngine mechanics (tiny broker, manual clock)
# ---------------------------------------------------------------------------


def _tiny_broker(tmp_path, *, hpc: bool = True) -> Hydra:
    h = Hydra(pod_store="memory", streaming=True, batch_window=0.0, workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="a", platform="cloud", concurrency=2))
    if hpc:
        h.register_provider(
            ProviderSpec(name="hp", platform="hpc", connector="pilot", concurrency=2)
        )
    return h


def test_link_window_overrides_and_restores_models(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path)
        eng = h.staging.engine
        before = eng.links.get(("cloud", "hpc"), FALLBACK_LINK)
        chaos = ChaosEngine(
            h,
            [LinkWindow(at_s=1.0, duration_s=2.0, src_platform="cloud", dst_platform="hpc")],
        ).arm()
        clock.advance(1.0)
        # both directions partitioned while the window is open
        assert eng.links[("cloud", "hpc")].bandwidth_mbps < 1.0
        assert eng.links[("hpc", "cloud")].bandwidth_mbps < 1.0
        assert chaos.stats()["open_link_windows"] == 1
        clock.advance(2.0)
        assert eng.links[("cloud", "hpc")] == before
        assert chaos.stats()["open_link_windows"] == 0
        kinds = [e["kind"] for e in chaos.log]
        assert kinds == ["link_window", "link_restore"]
        h.shutdown(wait=True)


def test_link_degradation_scales_bandwidth_not_partition(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path)
        eng = h.staging.engine
        base = eng.links.get(("cloud", "cloud"), FALLBACK_LINK)
        ChaosEngine(
            h,
            [
                LinkWindow(
                    at_s=0.0,
                    duration_s=5.0,
                    src_platform="cloud",
                    dst_platform="cloud",
                    factor=0.25,
                )
            ],
        ).arm()
        clock.advance(0.0)
        assert eng.links[("cloud", "cloud")].bandwidth_mbps == pytest.approx(
            base.bandwidth_mbps * 0.25
        )
        clock.advance(5.0)
        assert eng.links[("cloud", "cloud")] == base
        h.shutdown(wait=True)


def test_partitioned_transfer_restarts_and_completes_after_restore(tmp_path):
    """An in-flight cross-platform transfer caught by a partition is
    restarted under the (unroutable) window model, then restarted again at
    restore time and completes at real-link speed — the task never fails."""
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path)
        # sole replica on the cloud site: the pull MUST ride cloud->hpc
        h.staging.registry.add("d", 200.0, sites=["a"], pinned=True)
        t = Task(kind="noop", inputs=["d"], provider="hp")  # cloud -> hpc pull
        h.dispatch([t])
        eng = h.staging.engine
        assert wait_until(lambda: eng.active_transfers() == 1)
        chaos = ChaosEngine(
            h,
            [LinkWindow(at_s=1.0, duration_s=4.0, src_platform="cloud", dst_platform="hpc")],
        ).arm()
        clock.advance(1.0)
        (entry,) = [e for e in chaos.log if e["kind"] == "link_window"]
        assert entry["detail"]["restarted_transfers"] >= 1
        # partitioned: nowhere near done after a window's worth of time
        clock.advance(3.0)
        assert not t.done()
        ok = wait_until(lambda: (clock.advance(5.0), t.done())[1], timeout=10.0)
        assert ok and t.exception() is None
        h.shutdown(wait=True)


def test_quarantine_storm_gates_template_then_lifts(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path, hpc=False)
        pool = ProviderPool(
            [
                LaunchSpec(
                    template=ProviderSpec(name="burst", platform="cloud"),
                    max_instances=2,
                    latency=LatencyModel(distribution="fixed", mean_s=1.0),
                )
            ]
        )
        h.autoscale(pool, tick_s=1.0)
        chaos = ChaosEngine(
            h, [QuarantineStorm(at_s=1.0, template="burst", duration_s=3.0)]
        ).arm()
        clock.advance(1.0)
        assert pool.quarantined() == ["burst"]
        clock.advance(3.0)
        assert pool.quarantined() == []
        kinds = [e["kind"] for e in chaos.log]
        assert kinds == ["quarantine_storm", "quarantine_lift"]
        h.shutdown(wait=True)


def test_preempt_kill_retries_task_to_completion(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path)  # two providers: the retry excludes the killer
        t = Task(kind="sleep", duration=5.0)
        h.dispatch([t])
        # the sleep is parked on a virtual deadline: RUNNING is stable here
        assert wait_until(lambda: t.tstate == TaskState.RUNNING, timeout=10.0)
        chaos = ChaosEngine(h, [PreemptKill(at_s=0.0, count=1)])
        detail = chaos._preempt_kill(PreemptKill(at_s=0.0, count=1))
        assert detail["killed"] == 1
        # serve the killed sleep (manager notices FAILED) and then the retry
        assert wait_until(lambda: (clock.advance(5.0), t.done())[1], timeout=15.0)
        assert t.exception() is None and t.retries == 1
        assert "preempted" in [e for e, _ in t.trace.events]
        # across the fleet: exactly one failure, exactly one completion —
        # no stranded future, no double ledger count
        stats = [h.manager(n) for n in ("a", "hp")]
        assert sum(m.failed for m in stats) == 1
        assert sum(m.completed for m in stats) == 1
        h.shutdown(wait=True)


def test_preempt_kill_skips_tasks_out_of_retry_budget(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path, hpc=False)
        t = Task(kind="sleep", duration=5.0, max_retries=0)
        h.dispatch([t])
        assert wait_until(lambda: t.tstate == TaskState.RUNNING, timeout=10.0)
        chaos = ChaosEngine(h, [])
        detail = chaos._preempt_kill(PreemptKill(at_s=0.0, count=4))
        assert detail["killed"] == 0  # no retry budget: not a victim
        assert wait_until(lambda: (clock.advance(5.0), t.done())[1], timeout=15.0)
        assert t.exception() is None
        h.shutdown(wait=True)


def test_site_outage_removes_provider_and_staging_site(tmp_path):
    with virtual_time():
        h = _tiny_broker(tmp_path)
        chaos = ChaosEngine(h, [])
        detail = chaos._site_outage(SiteOutage(at_s=0.0, site="a"))
        assert detail == {"removed": ["a"]}
        assert "a" not in [p.name for p in h.proxy.bind_targets()]
        # double-kill is a no-op, not a raise
        assert chaos._site_outage(SiteOutage(at_s=0.0, site="a")) == {"removed": []}
        h.shutdown(wait=True)


def test_engine_never_raises_out_of_a_clock_callback(tmp_path):
    with virtual_time(auto_advance=False) as clock:
        h = _tiny_broker(tmp_path, hpc=False)
        chaos = ChaosEngine(h, [QuarantineStorm(at_s=0.5, template="ghost")]).arm()
        clock.advance(0.5)  # no autoscaler attached: handler reports, not raises
        (entry,) = chaos.log
        assert entry["detail"] == {"skipped": "no autoscaler attached"}
        h.shutdown(wait=True)


def test_arm_twice_raises_and_planned_schedule_is_sorted(tmp_path):
    with virtual_time():
        h = _tiny_broker(tmp_path, hpc=False)
        events = [
            PreemptKill(at_s=9.0, count=1),
            SiteOutage(at_s=3.0, site="a"),
            QuarantineStorm(at_s=3.0, template="b"),
        ]
        chaos = ChaosEngine(h, events)
        assert chaos.planned() == [
            (3.0, "quarantine_storm", "b"),
            (3.0, "site_outage", "a"),
            (9.0, "preempt_kill", "*"),
        ]
        chaos.arm()
        with pytest.raises(RuntimeError):
            chaos.arm()
        chaos.stop()
        h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Spec round-trip
# ---------------------------------------------------------------------------


def test_scenario_spec_json_roundtrip():
    spec = presets.searise_at_scale(seed=7)
    blob = json.dumps(spec.to_dict())  # must be JSON-serializable as-is
    back = ScenarioSpec.from_dict(json.loads(blob))
    assert back == spec
    # declarative chaos maps onto the typed core events
    kinds = [c.to_core().kind for c in back.chaos]
    assert kinds == ["site_outage", "quarantine_storm", "link_window", "preempt_kill"]


# ---------------------------------------------------------------------------
# Smoke scenario: the full loop at unit-test scale
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_reports():
    spec = presets.searise_smoke()
    return spec, run_scenario(spec, chaos=True), run_scenario(spec, chaos=False)


def test_smoke_scenario_holds_invariants(smoke_reports):
    spec, chaos, base = smoke_reports
    assert check_invariants(chaos, base, spec) == []
    assert chaos.failed_tasks == 0 and base.failed_tasks == 0


def test_smoke_scenario_faults_hit_live_work(smoke_reports):
    """Regression: events scheduled before the cold-staging ramp ends hit an
    idle fleet and verify nothing.  The preset's schedule must land on
    running tasks and produce observable recoveries."""
    spec, chaos, _ = smoke_reports
    assert chaos.preempted_tasks > 0
    assert chaos.recovered_tasks > 0
    assert chaos.recovery_s is not None and chaos.recovery_s > 0
    assert chaos.first_fault_s == pytest.approx(spec.chaos[0].at_s)
    injected = chaos.chaos_stats["injected"]
    assert injected["site_outage"] == 1 and injected["link_window"] == 1
    assert injected["quarantine_storm"] == 1 and injected["preempt_kill"] == 1
    assert injected["link_restore"] == 1 and injected["quarantine_lift"] == 1


def test_smoke_report_round_trips_to_json(smoke_reports):
    _, chaos, _ = smoke_reports
    doc = json.loads(json.dumps(chaos.to_dict()))
    assert doc["failed_tasks"] == 0
    assert doc["fingerprint"] == chaos.fingerprint()
    assert len(doc["events"]) == len(chaos.events)


# ---------------------------------------------------------------------------
# The acceptance scenario: searise_at_scale (ISSUE)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def at_scale_reports():
    spec = presets.searise_at_scale()
    chaos = run_scenario(spec, chaos=True)
    base = run_scenario(spec, chaos=False)
    rerun = run_scenario(spec, chaos=True)
    return spec, chaos, base, rerun


def test_at_scale_is_the_issue_shape(at_scale_reports):
    spec, chaos, _, _ = at_scale_reports
    tr = spec.traffic
    assert tr.facts_members >= 1024  # >= 1k ensemble members
    want = (
        tr.facts_members * 4
        + tr.train_jobs * tr.train_blocks
        + tr.serve_waves * tr.serve_tasks_per_wave
    )
    assert chaos.n_tasks == want
    kinds = {kind for _, kind, _ in chaos.event_schedule}
    assert {"site_outage", "link_window", "preempt_kill"} <= kinds
    assert len(chaos.event_schedule) >= 3


def test_at_scale_zero_failed_tasks_under_chaos(at_scale_reports):
    spec, chaos, base, _ = at_scale_reports
    assert check_invariants(chaos, base, spec) == []
    assert chaos.failed_tasks == 0 and chaos.unresolved_tasks == 0
    assert chaos.failed_workflows == 0
    assert chaos.ledger_error is None


def test_at_scale_makespan_inflation_bounded(at_scale_reports):
    spec, chaos, base, _ = at_scale_reports
    assert makespan_inflation(chaos, base) <= spec.max_makespan_inflation


def test_at_scale_recovers_visibly(at_scale_reports):
    _, chaos, _, _ = at_scale_reports
    assert chaos.preempted_tasks > 0
    assert chaos.recovered_tasks > 0
    assert chaos.first_fault_s is not None


def test_at_scale_nothing_stranded_after_shutdown(at_scale_reports):
    _, chaos, base, _ = at_scale_reports
    for rep in (chaos, base):
        assert rep.stranded_blocked == 0
        assert rep.stranded_retry_timers == 0
        assert rep.pending_deadlines == 0


def test_at_scale_identical_seed_identical_report(at_scale_reports):
    spec, chaos, _, rerun = at_scale_reports
    assert chaos.fingerprint() == rerun.fingerprint()
    assert chaos.event_schedule == rerun.event_schedule
    assert chaos.n_tasks == rerun.n_tasks
    assert rerun.failed_tasks == 0
    # and the planned schedule is exactly the spec's declaration
    assert chaos.event_schedule == [
        (c.at_s, c.to_core().kind, c.to_core().target) for c in spec.chaos
    ]
