"""Streaming dispatcher: micro-batching, late binding, backfill, cycle
detection, and the 10k-task virtual-clock scheduling scenario."""

import pytest

from repro.core import (
    Hydra,
    NoEligibleProvider,
    ProviderSpec,
    Resources,
    Task,
    Workflow,
    WorkflowManager,
)
from repro.runtime.clock import virtual_time


def chain_workflows(n_instances: int, stages: int = 4, kind: str = "noop", duration: float = 0.0):
    wfs = []
    for i in range(n_instances):
        wf = Workflow(name=f"chain.{i:05d}")
        prev = None
        for _ in range(stages):
            t = Task(kind=kind, duration=duration)
            prev = wf.add(t, deps=[prev] if prev else None)
        wfs.append(wf)
    return wfs


@pytest.fixture
def broker(tmp_path):
    h = Hydra(
        pod_store="memory",
        workdir=str(tmp_path),
        streaming=True,
        batch_window=0.001,
        max_batch=256,
    )
    yield h
    h.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Micro-batching + correctness
# ---------------------------------------------------------------------------


def test_streaming_completes_dags_with_fewer_submissions(broker):
    broker.register_provider(ProviderSpec(name="s1", concurrency=8))
    broker.register_provider(ProviderSpec(name="s2", concurrency=8))
    wfm = WorkflowManager(broker)
    assert wfm.streaming  # mode follows the broker
    wfs = chain_workflows(30)
    wfm.run(wfs, timeout=60)
    assert all(w.done and not w.failed for w in wfs)
    stats = broker.stream_stats()
    n_tasks = sum(len(w.tasks) for w in wfs)
    # readiness events coalesced: far fewer pipeline rounds than tasks
    assert stats["n_submits"] < n_tasks / 4
    assert stats["mean_batch_size"] > 1.0
    assert stats["n_pods"] < n_tasks / 2


def test_micro_batched_pods_carry_batch_id(broker):
    broker.register_provider(ProviderSpec(name="b1", concurrency=8))
    wfm = WorkflowManager(broker)
    wfs = chain_workflows(10)
    wfm.run(wfs, timeout=60)
    broker.dispatcher().drain(timeout=10)
    pods = [p for sub in broker._submissions for p in sub.pods]
    assert pods and all(p.batch_id is not None for p in pods)


def test_dispatcher_lazy_start_does_not_flip_mode(tmp_path):
    h = Hydra(pod_store="memory", workdir=str(tmp_path))
    assert not h.streaming
    h.register_provider(ProviderSpec(name="z1", concurrency=4))
    tasks = [Task(kind="noop") for _ in range(8)]
    h.dispatch(tasks)  # lazy-starts the loop for THIS caller only
    # mode is a constructor choice: other WorkflowManagers sharing the
    # broker must not silently switch dispatch paths mid-run
    assert not h.streaming
    assert h.dispatcher().drain(timeout=10)
    for t in tasks:
        t.result(timeout=10)
    h.shutdown(wait=True)


def test_streaming_rejects_conflicting_pod_shaping(tmp_path):
    h = Hydra(pod_store="memory", workdir=str(tmp_path), streaming=True)
    h.register_provider(ProviderSpec(name="cs", concurrency=4))
    wf = Workflow()
    wf.add(Task(kind="noop"))
    with pytest.raises(ValueError, match="pod shaping"):
        WorkflowManager(h, partitioning="scpp").run([wf], wait=False)
    # agreeing (or unset) shaping is fine
    WorkflowManager(h, partitioning=h.partitioning).run([wf], timeout=30)
    assert wf.done
    h.shutdown(wait=True)


def test_retry_releases_load_aware_accounting(tmp_path):
    """Regression: a bound batch whose dispatch round fails must release the
    policy's outstanding counts before being re-bound, or load-aware binding
    would drift by one per task per retry forever."""
    h = Hydra(pod_store="memory", workdir=str(tmp_path), policy="load_aware")
    h.register_provider(ProviderSpec(name="la", concurrency=4))
    d = h.dispatcher()
    tasks = [Task(kind="noop") for _ in range(6)]
    # simulate a post-bind pipeline failure, then recovery
    boom = {"n": 2}
    orig = h.store.serialize

    def flaky(pod):
        if boom["n"] > 0:
            boom["n"] -= 1
            raise OSError("serialize blip")
        orig(pod)

    h.store.serialize = flaky
    d.enqueue(tasks)
    for t in tasks:
        assert t.result(timeout=10) is None
    assert d.drain(timeout=10)
    assert h.policy.outstanding["la"] == 0  # fully released, no drift
    h.shutdown(wait=True)


def test_unplaceable_task_fails_alone_batch_survives(broker):
    broker.register_provider(ProviderSpec(name="small", concurrency=4))
    ok_tasks = [Task(kind="noop") for _ in range(8)]
    monster = Task(kind="noop", resources=Resources(cpus=10_000))
    broker.dispatch(ok_tasks + [monster])
    for t in ok_tasks:
        assert t.result(timeout=10) is None
    with pytest.raises(NoEligibleProvider):
        monster.result(timeout=10)


def test_late_binding_skips_tripped_member(broker):
    """Breaker state is consulted at dispatch time, not DAG-build time."""
    group = broker.register_group(
        "pool", [ProviderSpec(name=n, concurrency=4) for n in ("lb1", "lb2")]
    )
    group.mark_down("lb1")  # open lb1's breaker BEFORE any dispatch
    tasks = [Task(kind="noop") for _ in range(16)]
    broker.dispatch(tasks)
    for t in tasks:
        t.result(timeout=10)
    assert all(t.provider == "lb2" for t in tasks)


def test_backfill_orders_shallow_tasks_first(broker):
    """Deeper-workflow tasks ride along behind frontier work in one batch."""
    broker.register_provider(ProviderSpec(name="bf", concurrency=4))
    d = broker.dispatcher()
    deep = [Task(kind="noop") for _ in range(4)]
    shallow = [Task(kind="noop") for _ in range(4)]
    for t in deep:
        t.depth = 3
    batch_order = []
    orig = broker.submit

    def spy(tasks, **kw):
        batch_order.append([t.depth for t in tasks])
        return orig(tasks, **kw)

    broker.submit = spy
    d.enqueue(deep + shallow)
    assert d.drain(timeout=10)
    merged = [depth for batch in batch_order for depth in batch]
    assert merged == sorted(merged)  # shallow first, deep backfills


def test_persistent_outage_surfaces_with_final_states(tmp_path):
    """Regression: tasks failed by the persistent-outage path must reach a
    FINAL tstate (not just a resolved future), or workflow completion
    (all(t.final)) would hang forever."""
    h = Hydra(pod_store="memory", workdir=str(tmp_path))
    d = h.dispatcher()
    d.max_consecutive_failures = 3  # surface fast: no providers registered
    tasks = [Task(kind="noop") for _ in range(4)]
    h.dispatch(tasks)
    for t in tasks:
        with pytest.raises(RuntimeError):
            t.result(timeout=10)
        assert t.final
    h.shutdown(wait=True)


def test_submission_wait_times_out_under_virtual_clock(tmp_path):
    """Regression: a guard timeout on a frozen virtual clock must return
    False in bounded real time instead of hanging forever."""
    import time as _time

    with virtual_time():
        h = Hydra(pod_store="memory", workdir=str(tmp_path))
        from repro.core import Submission

        sub = Submission([Task(kind="noop")], h)  # never dispatched
        t0 = _time.monotonic()
        assert sub.wait(timeout=0.5) is False
        assert _time.monotonic() - t0 < 30.0
        h.shutdown(wait=True)


def test_stream_stats_shape(broker):
    broker.register_provider(ProviderSpec(name="st", concurrency=4))
    broker.dispatch([Task(kind="noop") for _ in range(4)])
    broker.dispatcher().drain(timeout=10)
    stats = broker.stream_stats()
    for key in ("batches", "tasks_dispatched", "n_submits", "n_pods", "mean_batch_size"):
        assert key in stats


# ---------------------------------------------------------------------------
# Cycle detection (a cyclic DAG used to deadlock the run loop forever)
# ---------------------------------------------------------------------------


def test_self_dependency_rejected():
    wf = Workflow(name="selfdep")
    t = Task(kind="noop")
    with pytest.raises(ValueError, match="cycle"):
        wf.add(t, deps=[t])


def test_two_cycle_via_forward_dep_rejected():
    wf = Workflow(name="two")
    t1, t2 = Task(kind="noop"), Task(kind="noop")
    wf.add(t1, deps=[t2])  # forward dep: t2 not added yet
    with pytest.raises(ValueError, match=f"{t1.uid}"):
        wf.add(t2, deps=[t1])


def test_three_cycle_rejected_with_offending_path():
    wf = Workflow(name="three")
    a, b, c = (Task(kind="noop") for _ in range(3))
    wf.add(a, deps=[c])
    wf.add(b, deps=[a])
    with pytest.raises(ValueError, match="cycle"):
        wf.add(c, deps=[b])


def test_duplicate_add_rejected():
    wf = Workflow(name="dup")
    t = Task(kind="noop")
    wf.add(t)
    with pytest.raises(ValueError, match="already added"):
        wf.add(t)


def test_run_revalidates_hand_built_cycle(tmp_path):
    """Regression: a cycle smuggled past add() (direct graph surgery) must
    raise at run() instead of deadlocking the run loop forever."""
    wf = Workflow(name="smuggled")
    a, b = Task(kind="noop"), Task(kind="noop")
    wf.add(a)
    wf.add(b, deps=[a])
    # surgically close the loop a -> b -> a
    wf.deps[a.uid].add(b.uid)
    wf.children.setdefault(b.uid, []).append(a.uid)
    h = Hydra(pod_store="memory", workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="cy", concurrency=2))
    with pytest.raises(ValueError, match="cycle"):
        WorkflowManager(h).run([wf], wait=False)
    h.shutdown(wait=True)


def test_dangling_dep_rejected_at_run(tmp_path):
    """Regression: a forward dep that is never add()ed can never complete,
    which used to deadlock the run loop just like a cycle."""
    wf = Workflow(name="dangling")
    ghost = Task(kind="noop")
    wf.add(Task(kind="noop"), deps=[ghost])  # ghost never added
    h = Hydra(pod_store="memory", workdir=str(tmp_path))
    h.register_provider(ProviderSpec(name="dg", concurrency=2))
    with pytest.raises(ValueError, match="never added"):
        WorkflowManager(h).run([wf], wait=False)
    h.shutdown(wait=True)


def test_workflow_with_unplaceable_task_reports_failed(broker):
    """A dispatcher-surfaced error (CANCELED + exception on the future) must
    make the workflow read as failed, not as a clean success."""
    broker.register_provider(ProviderSpec(name="wf1", concurrency=4))
    wf = Workflow(name="unplaceable")
    a = wf.add(Task(kind="noop"))
    bad = wf.add(Task(kind="noop", resources=Resources(cpus=10_000)), deps=[a])
    wf.add(Task(kind="noop"), deps=[bad])
    WorkflowManager(broker).run([wf], timeout=30)
    assert wf.done
    assert wf.failed  # the errored task is CANCELED with NoEligibleProvider


def test_diamond_dag_is_not_a_cycle(broker):
    broker.register_provider(ProviderSpec(name="di", concurrency=4))
    wf = Workflow(name="diamond")
    a = wf.add(Task(kind="noop"))
    b = wf.add(Task(kind="noop"), deps=[a])
    c = wf.add(Task(kind="noop"), deps=[a])
    d = wf.add(Task(kind="noop"), deps=[b, c])
    assert wf.find_cycle() is None
    assert wf.depths()[d.uid] == 2
    WorkflowManager(broker).run([wf], timeout=30)
    assert wf.done and not wf.failed


# ---------------------------------------------------------------------------
# The 10k-task virtual-clock scheduling scenario (ISSUE acceptance: the
# virtual-clock scheduler suite completes in well under 60 s wall-clock)
# ---------------------------------------------------------------------------


def test_10k_task_dag_schedule_under_virtual_clock(tmp_path):
    with virtual_time() as clock:
        h = Hydra(
            pod_store="memory",
            workdir=str(tmp_path),
            streaming=True,
            batch_window=0.0,  # virtual window; 0 keeps the pump eager
            max_batch=1024,
        )
        h.register_provider(ProviderSpec(name="v1", concurrency=64))
        h.register_provider(ProviderSpec(name="v2", concurrency=64))
        wfm = WorkflowManager(h)
        wfs = chain_workflows(2500, stages=4)  # 10_000 tasks
        wfm.run(wfs, timeout=300)
        assert all(w.done and not w.failed for w in wfs)
        stats = h.stream_stats()
        assert stats["n_submits"] < 2500  # coalescing held up at scale
        # every trace event carries a virtual timestamp from this run
        t = wfs[0].tasks[0]
        assert all(ts >= 0.0 for _, ts in t.trace.events)
        h.shutdown(wait=True)


def test_virtual_sleep_dag_runs_in_milliseconds(tmp_path):
    """120 virtual seconds of sleep tasks resolve in real milliseconds."""
    with virtual_time() as clock:
        h = Hydra(
            pod_store="memory", workdir=str(tmp_path), streaming=True,
            batch_window=0.0, tasks_per_pod=8,
        )
        h.register_provider(ProviderSpec(name="vs", concurrency=32))
        wfm = WorkflowManager(h)
        wfs = chain_workflows(10, stages=3, kind="sleep", duration=4.0)
        wfm.run(wfs, timeout=600)
        assert all(w.done and not w.failed for w in wfs)
        assert clock.now() >= 12.0  # >= critical path in virtual seconds
        h.shutdown(wait=True)
