"""OVH/TH/TPT/TTX metric derivation from traces (paper §5 definitions)."""
from repro.core import Hydra, ProviderSpec, Task
from repro.core.pod import DiskPodStore, MemoryPodStore, Pod
from repro.runtime.tracing import Trace, compute_metrics


class _FakeTask:
    def __init__(self, t0, t1):
        self.trace = Trace()
        self.trace.add("exec_start", t0)
        self.trace.add("exec_done", t1)


class _FakePod:
    def __init__(self, t0, t1):
        self.trace = Trace()
        self.trace.add("env_setup_start", t0)
        self.trace.add("env_teardown_done", t1)


def test_metric_formulas():
    rt = Trace()
    rt.add("bind_start", 0.0)
    rt.add("bind_done", 1.0)
    rt.add("partition_start", 1.0)
    rt.add("partition_done", 1.5)
    rt.add("serialize_start", 1.5)
    rt.add("serialize_done", 2.5)
    rt.add("submit_start", 2.5)
    rt.add("submit_done", 3.0)
    tasks = [_FakeTask(3.0, 5.0), _FakeTask(3.5, 6.0)]
    pods = [_FakePod(2.9, 6.5)]
    m = compute_metrics(rt, tasks, pods)
    assert abs(m.ovh - 3.0) < 1e-9  # 1 + .5 + 1 + .5
    assert abs(m.th - 2 / 3.0) < 1e-9  # 2 tasks / (3.0 - 0.0)
    assert abs(m.tpt - 3.6) < 1e-9  # 6.5 - 2.9
    assert abs(m.ttx - 3.0) < 1e-9  # 6.0 - 3.0
    assert m.phases["bind"] == 1.0


def test_disk_store_writes_and_cleans(tmp_path):
    store = DiskPodStore(str(tmp_path))
    t = Task(kind="noop")
    pod = Pod("prov", [t], "scpp")
    store.serialize(pod)
    assert pod.path and pod.serialized
    import os

    assert os.path.exists(pod.path)
    store.cleanup()
    assert not os.path.exists(pod.path)


def test_memory_store_serializes_without_files():
    store = MemoryPodStore()
    pod = Pod("prov", [Task(kind="noop")], "mcpp")
    store.serialize(pod)
    assert pod.serialized and pod.path is None


def test_ovh_dominated_by_tasks_not_provider(tmp_path):
    """Paper claim: OVH depends on #tasks/#pods, not on the provider.
    Min-of-3 per provider: a single wall-clock OVH sample on a noisy shared
    core can spike 3x+ from scheduler preemption alone (the same
    robustness treatment test_system gives its OVH comparison)."""
    ovhs = {}
    for prov in ("a", "b"):
        samples = []
        for rep in range(3):
            h = Hydra(pod_store="memory", workdir=str(tmp_path / f"{prov}{rep}"))
            h.register_provider(ProviderSpec(name=prov, concurrency=4))
            sub = h.submit([Task(kind="noop") for _ in range(400)])
            sub.wait(timeout=60)
            samples.append(sub.metrics().ovh)
            h.shutdown(wait=False)
        ovhs[prov] = min(samples)
    ratio = max(ovhs.values()) / max(min(ovhs.values()), 1e-9)
    assert ratio < 3.0  # same order of magnitude on a noisy shared core
