"""End-to-end system behaviour: the paper's full story in one test module -
heterogeneous workload, concurrent cloud+HPC providers, pods, metrics,
fault tolerance, and a compute (JAX train) task brokered like a container.
"""

import numpy as np
import pytest

from repro.core import Hydra, ProviderSpec, Resources, Task, WorkflowManager
from repro.core.managers.compute import ARTIFACTS


@pytest.fixture
def hydra(tmp_path):
    h = Hydra(pod_store="disk", workdir=str(tmp_path), tasks_per_pod=32)
    h.register_provider(ProviderSpec(name="jet2", platform="cloud", concurrency=4))
    h.register_provider(ProviderSpec(name="azure", platform="cloud", concurrency=4))
    h.register_provider(
        ProviderSpec(name="bridges2", platform="hpc", connector="pilot", concurrency=4)
    )
    yield h
    h.shutdown(wait=False)


def test_heterogeneous_workload_end_to_end(hydra):
    """noop + sleep + callable + compute tasks, mixed resources, all finish."""
    rng = np.random.default_rng(0)
    tasks = (
        [Task(kind="noop") for _ in range(50)]
        + [Task(kind="sleep", duration=float(d)) for d in rng.uniform(0.001, 0.01, 20)]
        + [Task(kind="callable", fn=lambda i=i: i * i) for i in range(10)]
        + [Task(kind="compute", arch="llama3-8b", step_kind="train",
                resources=Resources(cpus=2, accels=1))]
    )
    sub = hydra.submit(tasks)
    assert sub.wait(timeout=300)
    assert sub.states == {"DONE": len(tasks)}
    m = sub.metrics()
    assert m.n_tasks == len(tasks)
    assert m.ovh < m.tpt + m.ttx + 10  # broker overhead exists and is bounded
    # callable results correct
    assert [t.result() for t in tasks[70:80]] == [i * i for i in range(10)]
    # compute task really ran a train step
    out = tasks[-1].result()
    assert "loss" in out and np.isfinite(out["loss"])


def test_compile_cache_shared_across_providers(hydra):
    builds_before = ARTIFACTS.builds
    tasks = [Task(kind="compute", arch="granite-3-8b", step_kind="train") for _ in range(4)]
    sub = hydra.submit(tasks)
    assert sub.wait(timeout=300)
    assert sub.states == {"DONE": 4}
    # one image build, rest cache hits (the CaaS "registry" behaviour)
    assert ARTIFACTS.builds - builds_before <= 2  # benign duplicate on race


def test_metrics_scale_with_task_count(hydra):
    # interleaved pairs + majority vote: wall-clock noise on this shared
    # single core arrives in decaying bursts (GC, scheduler, leftover
    # teardown from earlier modules), so back-to-back 100/400 pairs see the
    # same environment and a single distorted pair cannot flip the verdict
    wins = 0
    for _ in range(3):
        ovh = {}
        for n in (100, 400):
            tasks = [Task(kind="noop") for _ in range(n)]
            sub = hydra.submit(tasks)
            sub.wait(timeout=120)
            ovh[n] = sub.metrics().ovh
        wins += ovh[400] > ovh[100]
    assert wins >= 2  # OVH dominated by #tasks (paper claim)


def test_provider_failure_plus_workflows(hydra):
    """Workflows keep completing when a provider dies mid-flight."""
    from repro.facts.workflow import make_workflow

    wfm = WorkflowManager(hydra)
    wfs = [make_workflow(hydra.data, 100 + i, n_samples=50) for i in range(4)]
    import threading

    killer = threading.Timer(0.2, lambda: hydra.manager("azure").fail())
    killer.start()
    wfm.run(wfs)
    killer.cancel()
    assert all(w.done and not w.failed for w in wfs)
