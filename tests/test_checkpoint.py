"""Checkpoint subsystem (ckpt/checkpoint.py) under failure: save -> kill ->
restore round-trips driven under a VirtualClock, crash-consistency of the
atomic step directories and the LATEST pointer, and async-writer error
surfacing.

Complements tests/test_ckpt_data.py (happy-path round-trip + train-restart
equivalence): this file is about what survives a kill."""
from __future__ import annotations

import os
import shutil

import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.runtime.clock import virtual_time


def _state(step: int = 0):
    return {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4) + step},
        "opt": {"m": np.full((3, 4), float(step)), "step": np.asarray(step, np.int32)},
    }


def _assert_tree_equal(a, b):
    import jax

    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_kill_restore_roundtrip_under_virtual_clock(tmp_path):
    """The scenario harness's train traffic models exactly this loop: write
    checkpoints, die mid-run, restart from LATEST.  The writer must not
    depend on wall-clock time — the whole round-trip runs inside an active
    VirtualClock, like every scenario run."""
    with virtual_time():
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=3)
        for step in (1, 2, 3):
            ac.save(step, _state(step))
        ac.wait()
        # "kill": drop the checkpointer mid-lifecycle, start from disk alone
        del ac
        assert ckpt.latest_step(str(tmp_path)) == 3
        step, restored = ckpt.restore(str(tmp_path), _state())
        assert step == 3
        _assert_tree_equal(restored, _state(3))


def test_crash_mid_save_leaves_previous_checkpoint_restorable(tmp_path):
    """A kill between the temp write and the atomic rename leaves a .tmp_*
    directory behind; LATEST and restore() must still serve the last good
    step, and a later save must land normally."""
    ckpt.save(str(tmp_path), 5, _state(5))
    # simulate the torn save: a half-written temp dir that never renamed
    torn = tmp_path / ".tmp_torn"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"partial garbage")
    assert ckpt.latest_step(str(tmp_path)) == 5
    step, restored = ckpt.restore(str(tmp_path), _state())
    assert step == 5
    _assert_tree_equal(restored, _state(5))
    ckpt.save(str(tmp_path), 6, _state(6))
    assert ckpt.latest_step(str(tmp_path)) == 6


def test_latest_pointing_at_missing_step_reports_no_checkpoint(tmp_path):
    ckpt.save(str(tmp_path), 9, _state(9))
    shutil.rmtree(tmp_path / "step_00000009")  # retention raced the pointer
    assert ckpt.latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), _state())


def test_async_retention_keeps_only_newest(tmp_path):
    with virtual_time():
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        for step in range(1, 6):
            ac.save(step, _state(step))
        ac.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_async_write_error_surfaces_on_next_wait(tmp_path):
    blocked = tmp_path / "not_a_dir"
    blocked.write_text("a file where the checkpoint dir should go")
    ac = ckpt.AsyncCheckpointer(str(blocked))
    ac.save(1, _state(1))
    with pytest.raises(OSError):
        ac.wait()
    # the error is consumed, not re-raised forever
    ac.wait()


def test_restore_specific_step_while_latest_moves_on(tmp_path):
    for step in (1, 2):
        ckpt.save(str(tmp_path), step, _state(step))
    step, restored = ckpt.restore(str(tmp_path), _state(), step=1)
    assert step == 1
    _assert_tree_equal(restored, _state(1))
