"""JAX version compatibility shims.

The container pins jax 0.4.x while parts of this codebase were written
against newer releases.  Two surfaces differ:

  * ``jax.make_mesh``: newer JAX wants explicit Auto ``axis_types``; 0.4.x
    has neither the kwarg nor ``jax.sharding.AxisType``.
  * ``jax.shard_map``: newer JAX exposes it at top level with ``check_vma``;
    0.4.x has ``jax.experimental.shard_map.shard_map`` with ``check_rep``.
  * ``compiled.cost_analysis()``: newer JAX returns one dict; 0.4.x returns
    a list of per-computation dicts.

Only these shims may branch on the JAX version; call sites stay uniform.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def compat_shard_map(f, mesh, in_specs, out_specs):
    """shard_map with replication/VMA checking off (we manage collectives
    explicitly in compression/attention paths)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def compat_cost_analysis(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
