"""Logical-axis -> mesh-axis sharding rules (MaxText-style), per strategy.

A *strategy* maps logical parameter/activation axis names to mesh axes.  The
same model code serves every strategy; the compute manager picks (or the
hillclimb overrides) the strategy per architecture.

Mesh axes (production): single-pod ("data", "model") = (16, 16);
multi-pod ("pod", "data", "model") = (2, 16, 16).  "pod" is an outer
data-parallel axis crossing the inter-pod DCI links.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Parameter logical axes.
_TP_PARAM: dict[str, AxisVal] = {
    "layers": None,
    "embed": None,
    "embed_table": None,  # input embedding table's d_model dim
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": "model",  # EP: experts over model axis (arctic)
    "expert_mlp": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "dt_rank": None,
    "conv": None,
    "rnn": "model",
    "norm": None,
    # when a param dim cannot shard (e.g. 56 heads or 8 KV heads on a 16-way
    # model axis), the dropped mesh axis spills onto the embed/mlp dim: the
    # matmul becomes row/column-parallel instead of replicating the weight
    "__spill__": ("embed", "mlp"),
}

# FSDP(+TP): additionally shard the replicated matrix dim over "data".
_FSDP_TP_PARAM = dict(_TP_PARAM, embed="data", embed_table="data")

# Pure FSDP (no tensor parallelism): everything big over ("data","model")
# treated as one flat fsdp axis - used as a hillclimb variant.
_FSDP_PARAM = dict(
    _TP_PARAM,
    mlp=("data", "model"),
    heads=("data", "model"),
    kv_heads=None,
    vocab=("data", "model"),
    experts=("data", "model"),
    ssm_inner=("data", "model"),
    rnn=("data", "model"),
    embed=None,
)

# Activation logical axes ("batch" resolves to the dp axes of the live mesh).
_ACT_BASE: dict[str, AxisVal] = {
    "batch": "__dp__",  # placeholder -> ("pod","data") or ("data",)
    "seq": None,
    "embed_act": None,
    "heads_act": "model",
    "kv_heads_act": "model",
    "mlp_act": "model",
    "vocab_act": "model",
    "experts_act": "model",
    "ssm_inner_act": "model",
    "rnn_act": "model",
    "group_act": "__dp__",
    "cache_batch": "__dp__",  # cache batch dim (decouples from token batch)
    "cache_seq": None,
    # when a dim cannot shard (e.g. 8 KV heads on a 16-way model axis), the
    # dropped mesh axis spills onto these dims instead: a KV cache becomes
    # sequence-sharded (distributed flash-decode layout)
    "__spill__": ("cache_seq",),
}

# Sequence-parallel variant: shard seq over "model" in norm/elementwise regions.
_ACT_SP = dict(_ACT_BASE, seq="model")


@dataclass(frozen=True)
class Strategy:
    """A named sharding strategy = param rules + activation rules + options."""

    name: str
    param_rules: dict[str, AxisVal]
    act_rules: dict[str, AxisVal]
    zero1: bool = True  # shard optimizer state over "data" (ZeRO-1)
    fsdp_pod: bool = False  # extend FSDP sharding over the "pod" axis too
    flash_decode: bool = False  # distributed flash-decode over seq-sharded caches

    def with_overrides(self, **param_overrides: AxisVal) -> "Strategy":
        return replace(self, param_rules={**self.param_rules, **param_overrides})


STRATEGIES: dict[str, Strategy] = {
    "tp": Strategy("tp", _TP_PARAM, _ACT_BASE),
    "fsdp_tp": Strategy("fsdp_tp", _FSDP_TP_PARAM, _ACT_BASE),
    "fsdp": Strategy("fsdp", _FSDP_PARAM, _ACT_BASE),
    "tp_sp": Strategy("tp_sp", _TP_PARAM, _ACT_SP),
    "fsdp_tp_sp": Strategy("fsdp_tp_sp", _FSDP_TP_PARAM, _ACT_SP),
    # §Perf serving strategy: params 2D-sharded (data x model) like fsdp_tp,
    # but token activations REPLICATED over the data axis, so GSPMD computes
    # partial matmuls + activation all-reduces (2D tensor parallelism) instead
    # of all-gathering the weights every layer (FSDP) - the right trade for
    # decode, where weights >> activations.  Caches stay batch-sharded via
    # the separate cache_batch axis.
    "serve_2dtp": Strategy(
        "serve_2dtp",
        # embed table stays 1D (vocab-only) sharded: a 2D-sharded table makes
        # GSPMD all-gather it for every lookup (measured: +4.2GB/step)
        dict(_FSDP_TP_PARAM, embed_table=None),
        dict(_ACT_BASE, batch=None),
        zero1=False,
    ),
}


def default_strategy(arch) -> Strategy:
    """Per-arch default strategy (baseline; §Perf hillclimbs override)."""
    big = arch.param_count() > 100e9
    strat = STRATEGIES["fsdp_tp" if big else "tp"]
    if arch.family == "moe" and arch.n_experts and arch.n_experts < 16:
        # grok: 8 experts cannot shard over 16-way model axis -> expert-internal TP
        strat = strat.with_overrides(experts=None, expert_mlp="model")
    return strat


# ---------------------------------------------------------------------------
# Resolution: logical axes -> PartitionSpec
# ---------------------------------------------------------------------------


def dp_axes(mesh_axis_names) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names)


def resolve_axes(
    logical_axes: tuple[Optional[str], ...],
    rules: dict[str, AxisVal],
    mesh_axis_names,
    shape: Optional[tuple[int, ...]] = None,
    axis_sizes: Optional[dict[str, int]] = None,
) -> P:
    """Map logical axis names to a PartitionSpec for the live mesh.

    When ``shape``/``axis_sizes`` are given, a mesh axis that does not divide
    its dim is dropped (dim replicated) and, if the rules declare
    ``__spill__`` targets, re-assigned to the first eligible spill dim.
    """
    used: set[str] = set()
    dropped: list[str] = []
    out: list[Optional[tuple[str, ...]]] = []

    def divides(dim: int, axes: tuple[str, ...]) -> bool:
        if axis_sizes is None:
            return True
        n = 1
        for a in axes:
            n *= axis_sizes.get(a, 1)
        return n > 0 and dim % n == 0

    for i, name in enumerate(logical_axes):
        val: AxisVal = None if name is None else rules.get(name, None)
        if val == "__dp__":
            val = dp_axes(mesh_axis_names)
        if isinstance(val, str):
            val = (val,)
        if val is not None:
            val = tuple(a for a in val if a in mesh_axis_names and a not in used)
            if shape is not None and val:
                keep: list[str] = []
                for a in val:
                    if divides(shape[i], tuple(keep) + (a,)):
                        keep.append(a)
                    else:
                        dropped.append(a)
                val = tuple(keep)
            used.update(val)
            val = val if val else None
        out.append(val)

    # spill dropped mesh axes onto eligible dims (e.g. cache seq dim)
    spill_names = rules.get("__spill__", ()) or ()
    for a in dropped:
        for i, name in enumerate(logical_axes):
            if name not in spill_names:
                continue
            cur = out[i] or ()
            if a in used:
                break
            if shape is not None and not divides(shape[i], cur + (a,)):
                continue
            out[i] = cur + (a,)
            used.add(a)
            break

    final = [v[0] if (v is not None and len(v) == 1) else v for v in out]
    return P(*final)


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def param_pspec_tree(specs, strategy: Strategy, mesh: Mesh):
    """Spec tree -> PartitionSpec tree under the given strategy."""
    from repro.models.spec import ParamSpec, is_spec_leaf

    rules = dict(strategy.param_rules)
    if strategy.fsdp_pod and "pod" in mesh.axis_names:
        # extend the fsdp ("data") shards over ("pod","data")
        rules = {
            k: (("pod", "data") if v == "data" else v) for k, v in rules.items()
        }
    sizes = mesh_axis_sizes(mesh)
    return jax.tree.map(
        lambda s: resolve_axes(s.axes, rules, mesh.axis_names, s.shape, sizes),
        specs,
        is_leaf=is_spec_leaf,
    )


def param_sharding_tree(specs, strategy: Strategy, mesh: Mesh):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        param_pspec_tree(specs, strategy, mesh),
    )


# ---------------------------------------------------------------------------
# Activation sharding context (used by model code via shard_x)
# ---------------------------------------------------------------------------


class _Ctx:
    rules: Optional[dict[str, AxisVal]] = None
    mesh: Optional[Mesh] = None
    flash_decode: bool = False


_CTX = _Ctx()


class activation_rules:
    """Context manager installing activation rules for model-internal
    ``with_sharding_constraint`` calls.  No-op when not installed."""

    def __init__(self, strategy: Strategy, mesh: Mesh):
        self.rules = strategy.act_rules
        self.mesh = mesh
        self.flash_decode = strategy.flash_decode

    def __enter__(self):
        _CTX.rules, _CTX.mesh = self.rules, self.mesh
        _CTX.flash_decode = self.flash_decode
        return self

    def __exit__(self, *exc):
        _CTX.rules, _CTX.mesh, _CTX.flash_decode = None, None, False
        return False


def flash_decode_enabled() -> bool:
    return (
        _CTX.flash_decode
        and _CTX.mesh is not None
        and "model" in _CTX.mesh.axis_names
    )


def shard_x(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain an activation to the current rules (no-op outside context).

    No divisibility check here: GSPMD pads uneven *intermediate* shardings
    (e.g. 56 heads over 16 shards); only jit-boundary shardings must divide.
    """
    if _CTX.rules is None or _CTX.mesh is None:
        return x
    spec = resolve_axes(tuple(logical_axes), _CTX.rules, _CTX.mesh.axis_names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))
