"""Three-term roofline from a compiled dry-run artifact.

Hardware constants (TPU v5e target):
    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link per chip

Terms (seconds, per step):
    compute    = HLO_FLOPs_per_chip / peak
    memory     = HLO_bytes_per_chip / hbm_bw
    collective = collective_bytes_per_chip / link_bw

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per train step
(3x forward-only for serve steps); the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_total: float
    hbm_bytes_est_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def t_memory_est(self) -> float:
        """Fusion-aware HBM-traffic estimate (see roofline/hlo.py); the raw
        cost_analysis bytes (t_memory) are an unfused upper bound on CPU."""
        return self.hbm_bytes_est_per_chip / HBM_BW

    @property
    def bottleneck_est(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory_est,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_est(self) -> float:
        return max(self.t_compute, self.t_memory_est, self.t_collective)

    @property
    def mfu_est(self) -> float:
        """MODEL_FLOPS / (chips * peak * step_est): the roofline fraction with
        the fusion-aware memory term."""
        denom = self.n_chips * PEAK_FLOPS * self.step_time_est
        return self.model_flops_total / denom if denom else 0.0

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap model: step >= max(terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs across all chips)."""
        total_hlo = self.flops_per_chip * self.n_chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """MODEL_FLOPS / (chips * peak * step_lower_bound): the roofline
        fraction achievable if the step ran exactly at its dominant term."""
        denom = self.n_chips * PEAK_FLOPS * self.step_time_lower_bound
        return self.model_flops_total / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.n_chips,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "t_memory_est_s": round(self.t_memory_est, 6),
            "bottleneck": self.bottleneck,
            "bottleneck_est": self.bottleneck_est,
            "model_flops": f"{self.model_flops_total:.3e}",
            "hlo_flops_per_chip": f"{self.flops_per_chip:.3e}",
            "useful_flops_frac": round(self.useful_flops_fraction, 4),
            "mfu_upper_bound": round(self.mfu_upper_bound, 4),
            "mfu_est": round(self.mfu_est, 4),
        }


def model_flops(arch, shape) -> float:
    """6*N*D train / 2*N*D forward-only, with N = active params (MoE-aware)."""
    n_active = arch.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
