"""HLO parsing for the roofline's collective term.

``compiled.cost_analysis()`` has no collective-bytes entry, so we parse the
post-optimization HLO text and sum the operand/result sizes of every
communication op.  SPMD modules are per-device, so the parsed sizes are
per-chip bytes; the collective term is per_chip_bytes / link_bw, which equals
the assignment's total_bytes / (chips * link_bw).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# result type(s) of an HLO instruction: "bf16[2,4096,512]{2,1,0}"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "  %name = <result-types> op-name(" with optional tuple result
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+("
    + "|".join(COLLECTIVE_OPS)
    + r")(?:-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=lambda: defaultdict(int))
    count_by_op: dict = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def row(self) -> dict:
        return {
            "collective_bytes": self.total_bytes,
            **{f"{k}_bytes": v for k, v in sorted(self.bytes_by_op.items())},
            **{f"{k}_count": v for k, v in sorted(self.count_by_op.items())},
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-chip result sizes of every collective op in the module.

    ``-start``/``-done`` async pairs are counted once (on the start op).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        if "-done(" in line:  # async completion: already counted at -start
            continue
        result_types, op = m.group(1), m.group(2)
        stats.bytes_by_op[op] += _shape_bytes(result_types)
        stats.count_by_op[op] += 1
    return stats


def count_op(hlo_text: str, name: str) -> int:
    pat = re.compile(r"=\s*[\w\[\]{},. ]*?\s" + re.escape(name) + r"\(")
    return sum(1 for line in hlo_text.splitlines() if pat.search(line))


# ---------------------------------------------------------------------------
# Fusion-aware HBM traffic estimate
# ---------------------------------------------------------------------------
#
# cost_analysis()['bytes accessed'] on the CPU backend counts every op's
# operands unfused, inflating the memory term ~100x vs what a TPU (with
# aggressive loop fusion) actually moves through HBM.  This parser estimates
# HBM traffic by counting only materializing ops - dots, fusions, collectives,
# slices/scatters, copies - and treating bare elementwise/reduce chains as
# fused into their producers (free).  It is an *estimate*, reported alongside
# the raw cost_analysis value in §Roofline.

_MATERIALIZING = (
    "dot", "fusion", "convolution", "copy", "transpose",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


# slicing ops move only the sliced/updated region, not the whole source
# (in-place on real hardware); counting full operands would punish unrolled
# scans for every per-step xs slice.
_SLICE_READS = ("slice", "dynamic-slice", "gather")
_SLICE_WRITES = ("dynamic-update-slice", "scatter")


def parse_hbm_traffic(hlo_text: str) -> int:
    """Estimated HBM bytes moved: sum of (output + operand) bytes over
    materializing ops only (loop bodies counted once, like cost_analysis -
    use the same depth-extrapolation to fix trip counts).  Slice reads count
    2x the slice size; slice updates count 2x the update size."""
    shapes: dict[str, int] = {}
    total = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, result_types, op, operands = m.groups()
        out_bytes = _shape_bytes(result_types)
        shapes[name] = out_bytes
        base = op.rstrip("0123456789.")
        if base.endswith("-start") or base.endswith("-done"):
            base = base.rsplit("-", 1)[0]
        if base not in _MATERIALIZING:
            continue
        if op.endswith("-done"):
            continue  # async pair: counted at -start
        arg_section = operands.split("), ")[0]
        refs = _OPERAND_RE.findall(arg_section)
        if base in _SLICE_READS:
            total += 2 * out_bytes
            continue
        if base in _SLICE_WRITES:
            upd_idx = 1 if base == "dynamic-update-slice" else 2
            upd = shapes.get(refs[upd_idx], 0) if len(refs) > upd_idx else out_bytes
            total += 2 * upd
            continue
        in_bytes = sum(shapes.get(ref, 0) for ref in refs)
        total += out_bytes + in_bytes
    return total
