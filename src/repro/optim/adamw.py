"""AdamW with fp32 state over bf16 params, global-norm clipping, and
ZeRO-1-style optimizer-state sharding (state pspecs shard the first
replicated dim of every param over "data").

No optax dependency - the update is a hand-rolled pytree map so the optimizer
state sharding stays fully under our control for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec, is_spec_leaf


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.peak_lr * warm * frac


def init_state(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state sharding specs
# ---------------------------------------------------------------------------


def opt_state_specs(param_specs) -> dict:
    """Spec tree for (m, v): same shapes as params, fp32, same logical axes.

    The ZeRO trick happens at PartitionSpec resolution: see zero1_pspec.
    """
    f32 = lambda s: ParamSpec(s.shape, s.axes, "float32", "zeros")
    return {
        "m": jax.tree.map(f32, param_specs, is_leaf=is_spec_leaf),
        "v": jax.tree.map(f32, param_specs, is_leaf=is_spec_leaf),
        "step": ParamSpec((), (), "int32", "zeros"),
    }


def zero1_pspec(param_pspec, shape, data_size: int) -> "jax.sharding.PartitionSpec":
    """Extend a param's PartitionSpec with 'data' on its largest unsharded,
    divisible dim.  This shards m/v over the data axis even when the param
    itself is only tensor-parallel - ZeRO-1.  Falls back to the param's own
    sharding when no dim divides (tiny tensors: norm scales, gates)."""
    from jax.sharding import PartitionSpec as P

    spec = list(param_pspec) + [None] * (len(shape) - len(param_pspec))
    used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
    if "data" in used or not shape:
        return P(*spec)
    candidates = [
        i for i, s in enumerate(spec) if s is None and shape[i] % data_size == 0
    ]
    if not candidates:
        return P(*spec)
    i = max(candidates, key=lambda i: shape[i])
    spec[i] = "data"
    return P(*spec)


def opt_pspec_tree(param_specs, param_pspecs, zero1: bool, data_size: int = 1):
    """PartitionSpecs for the optimizer state tree."""
    from jax.sharding import PartitionSpec as P

    def one(spec: ParamSpec, pspec):
        return zero1_pspec(pspec, spec.shape, data_size) if zero1 else pspec

    m = jax.tree.map(one, param_specs, param_pspecs, is_leaf=is_spec_leaf)
    return {"m": m, "v": jax.tree.map(lambda x: x, m), "step": P()}
