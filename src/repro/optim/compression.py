"""Gradient compression for the data-parallel reduction (1-bit-Adam family).

Two-phase int8 all-reduce with error feedback, built from explicit
collectives inside ``shard_map``:

  phase 1 (reduce-scatter): each DP shard block-quantizes (grad + worker
    error) to int8 with per-block fp32 scales and ``all_to_all``s the int8
    payload so each shard owns 1/n of the blocks.  Wire: 1 byte/elem + 1.6%
    scales (vs 2 bytes for a bf16 ring RS).
  phase 2 (all-gather): the owner sums its received contributions in fp32,
    re-quantizes the SUM to int8 (owner error feedback), and ``all_gather``s
    the int8 payload + scales.  Wire: 1 byte/elem.

Total wire ~2.06 bytes/elem vs 4 (bf16 all-reduce) / 8 (fp32) - the knob that
shrinks the cross-pod ("pod"-axis DCI) collective term in §Roofline.  Both
quantization errors are carried into the next step (error feedback), which
keeps the compressed SGD/Adam iteration convergent (Karimireddy et al. 2019).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

BLOCK = 256


def _n_blocks(size: int, n_dev: int) -> int:
    nb = -(-size // BLOCK)
    return -(-nb // n_dev) * n_dev  # pad so every shard owns nb/n_dev blocks


def _to_blocks(x: jax.Array, n_dev: int) -> jax.Array:
    nb = _n_blocks(x.size, n_dev)
    flat = jnp.zeros((nb * BLOCK,), jnp.float32).at[: x.size].set(
        x.astype(jnp.float32).reshape(-1)
    )
    return flat.reshape(nb, BLOCK)


def _quant(blocks: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def compression_state(param_shapes, n_dev: int):
    """(worker_err, owner_err) zero states for one param of given shape."""

    def one(shape):
        size = math.prod(shape) if shape else 1
        nb = _n_blocks(size, n_dev)
        return {
            "worker_err": jnp.zeros(shape, jnp.float32),
            "owner_err": jnp.zeros((nb // n_dev, BLOCK), jnp.float32),
        }

    return jax.tree.map(lambda p: one(p.shape), param_shapes)


def compressed_mean(x: jax.Array, state: dict, axis_name) -> tuple[jax.Array, dict]:
    """Error-feedback int8 mean-all-reduce over ``axis_name`` (inside shard_map).

    x: this shard's local gradient (full param shape - DP replicates params).
    Returns (mean over shards, new compression state).
    """
    n = jax.lax.psum(1, axis_name)
    blocks = _to_blocks(x, n)  # (nb, BLOCK)
    nb = blocks.shape[0]
    # add worker error feedback (same padded layout)
    blocks = blocks + _to_blocks(state["worker_err"], n)

    q, scale = _quant(blocks)
    worker_err = blocks - _dequant(q, scale)  # residual kept locally

    # --- phase 1: all_to_all the int8 payload; shard i receives every peer's
    # contribution for its owned block range.
    owned = nb // n
    q_recv = jax.lax.all_to_all(q.reshape(n, owned, BLOCK), axis_name, 0, 0, tiled=True)
    s_recv = jax.lax.all_to_all(scale.reshape(n, owned), axis_name, 0, 0, tiled=True)
    # (n*owned, BLOCK): n contributions for my owned blocks
    contrib = _dequant(q_recv.reshape(n, owned, BLOCK), s_recv.reshape(n, owned))
    total = jnp.sum(contrib, axis=0) + state["owner_err"]  # (owned, BLOCK)

    q2, scale2 = _quant(total)
    owner_err = total - _dequant(q2, scale2)

    # --- phase 2: all_gather int8 sums + scales, reconstruct the full mean.
    q_all = jax.lax.all_gather(q2, axis_name, axis=0, tiled=True)  # (nb, BLOCK)
    s_all = jax.lax.all_gather(scale2, axis_name, axis=0, tiled=True)  # (nb,)
    mean = (_dequant(q_all, s_all) / n).reshape(-1)[: x.size].reshape(x.shape)

    new_state = {
        "worker_err": worker_err.reshape(-1)[: x.size].reshape(x.shape),
        "owner_err": owner_err,
    }
    return mean.astype(x.dtype), new_state
