"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` (exact published numbers) in
``src/repro/configs/<id>.py``.  Each config also knows how to produce a
``reduced()`` variant for CPU smoke tests and the ``input_specs()`` /
``state_specs()`` ShapeDtypeStruct stand-ins used by the multi-pod dry-run
(no device allocation, weak-type correct).

Shapes (assigned):
    train_4k     seq_len=4096    global_batch=256   -> train_step
    prefill_32k  seq_len=32768   global_batch=32    -> serve prefill
    decode_32k   seq_len=32768   global_batch=128   -> serve decode (1 token, cache=seq_len)
    long_500k    seq_len=524288  global_batch=1     -> serve decode, sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    sub_quadratic_only: bool = False


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode", sub_quadratic_only=True),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    """Exact architecture hyper-parameters (published numbers).

    ``family`` selects the substrate:
      dense   - decoder-only GQA transformer
      moe     - decoder-only GQA transformer with MoE FFN (optionally + dense residual)
      ssm     - attention-free Mamba1 stack
      hybrid  - RG-LRU + local attention (RecurrentGemma pattern, 2 LRU : 1 attn)
      audio   - encoder/decoder transformer; frontend stubbed (frame embeddings)
      vlm     - decoder-only GQA transformer + cross-attn image layers; patch
                embeddings stubbed
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    source: str = ""

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False  # arctic: dense MLP residual in parallel
    capacity_factor: float = 1.25
    moe_group_size: int = 256  # token group size for capacity-based dispatch

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    ssm_chunk: int = 256  # seq chunk for train-time scan
    ssm_scan: str = "assoc"  # "assoc" (tree scan) | "seq" (strip-mined, §Perf)

    # --- hybrid (RG-LRU) ---
    rnn_width: int = 0  # 0 -> d_model
    local_window: int = 2048
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")

    # --- enc-dec (audio) ---
    n_enc_layers: int = 0
    enc_len_train: int = 4096  # stub frontend frames for train shape
    enc_len_serve: int = 4096

    # --- vlm ---
    cross_attn_period: int = 0  # a cross-attn layer every N layers
    n_img_tokens: int = 1024  # stub patch embeddings

    # --- common knobs ---
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "dots"  # none | dots | full
    logit_chunk: int = 0  # 0 = no chunking of the LM head

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True when the token mixer cost is sub-quadratic in seq_len."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_enc_dec(self) -> bool:
        return self.family == "audio"

    def supports(self, shape: ShapeConfig) -> bool:
        """Whether this arch runs the given assigned shape (see DESIGN.md)."""
        if shape.sub_quadratic_only and not self.sub_quadratic:
            return False
        return True

    # ------------------------------------------------------------------
    # Parameter counting (for MODEL_FLOPS = 6*N*D and memory estimates)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            return d * H * hd + 2 * d * KV * hd + H * hd * d

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # SwiGLU: gate, up, down

        if self.family == "dense":
            per = attn_params() + mlp_params(f) + 2 * d
            return L * per + emb + d
        if self.family == "vlm":
            # every `period`-th layer is a gated cross-attn block (replacing,
            # not adding to, a self-attn layer)
            n_x = L // self.cross_attn_period if self.cross_attn_period else 0
            n_self = L - n_x
            per_self = attn_params() + mlp_params(f) + 2 * d
            per_x = attn_params() + mlp_params(f) + 2 * d + 2  # + 2 scalar gates
            return n_self * per_self + n_x * per_x + emb + d
        if self.family == "moe":
            E, K = self.n_experts, self.top_k
            router = d * E
            per_expert = mlp_params(f)
            dense_res = mlp_params(f) if self.moe_dense_residual else 0
            per = attn_params() + router + E * per_expert + dense_res + 2 * d
            if active_only:
                per = attn_params() + router + K * per_expert + dense_res + 2 * d
            return L * per + emb + d
        if self.family == "ssm":
            di, N, R, C = self.d_inner, self.ssm_state, self.dt_rank, self.ssm_conv
            per = (
                d * 2 * di  # in_proj
                + di * C  # conv
                + di * (R + 2 * N)  # x_proj -> dt, B, C
                + R * di + di  # dt_proj
                + di * N + di  # A_log, D
                + di * d  # out_proj
                + d  # norm
            )
            return L * per + emb + d
        if self.family == "hybrid":
            dr = self.rnn_dim
            nb = 16
            while dr % nb:
                nb //= 2
            nb = max(nb, 1)
            rec = (
                2 * d * dr  # w_x, w_gate
                + dr * 4 + dr  # conv1d width 4 + bias
                + 2 * (dr * dr // nb) + 2 * dr  # block-diagonal RG-LRU gates + biases
                + dr  # Lambda
                + dr * d  # out proj
                + 2 * d  # norms
                + mlp_params(f)
            )
            attn = attn_params() + 2 * d + mlp_params(f)
            n_attn = sum(1 for i in range(L) if self.layer_kind(i) == "attn")
            n_rec = L - n_attn
            return n_rec * rec + n_attn * attn + emb + d
        if self.family == "audio":
            Le, Ld = self.n_enc_layers, self.n_layers
            enc = Le * (attn_params() + mlp_params(f) + 2 * d)
            dec = Ld * (2 * attn_params() + mlp_params(f) + 3 * d)
            return enc + dec + emb + 2 * d
        raise ValueError(self.family)

    def layer_kind(self, i: int) -> str:
        """Layer type at depth i (hybrid/vlm patterns)."""
        if self.family == "hybrid":
            pat = self.block_pattern or ("rec", "rec", "attn")
            return pat[i % len(pat)]
        if self.family == "vlm" and self.cross_attn_period:
            return "xattn" if (i % self.cross_attn_period == self.cross_attn_period - 1) else "self"
        return "self"

    # ------------------------------------------------------------------
    def replace(self, **kw: Any) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        kw: dict[str, Any] = dict(
            n_layers=max(2, _pattern_len(self)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
        )
        if self.family == "moe":
            # capacity_factor = E/k -> capacity == group: no token ever drops,
            # so results are group-size invariant (makes smoke tests exact).
            kw.update(n_experts=4, top_k=2, moe_group_size=16, capacity_factor=2.0)
        if self.family == "ssm":
            kw.update(ssm_state=4, ssm_chunk=8, ssm_dt_rank=4)
        if self.family == "hybrid":
            kw.update(rnn_width=64, local_window=16, n_layers=2 * len(self.block_pattern or ("rec", "rec", "attn")))
        if self.family == "audio":
            kw.update(n_enc_layers=2, enc_len_train=16, enc_len_serve=16)
        if self.family == "vlm":
            kw.update(cross_attn_period=2, n_img_tokens=8, n_layers=4)
        return self.replace(**kw)


def _pattern_len(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return len(cfg.block_pattern or ("rec", "rec", "attn"))
    if cfg.family == "vlm" and cfg.cross_attn_period:
        return cfg.cross_attn_period
    return 2


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def token_batch_spec(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract input pytree for one step of the given kind.

    train  : {tokens, labels[, enc_frames | img_embeds]}
    prefill: {tokens[, enc_frames | img_embeds]}
    decode : {tokens (B,1), pos (B,)} - cache/state specs come from the model.
    """
    import jax

    B, L = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: dict[str, Any] = {
            "tokens": sds((B, L), i32),
            "labels": sds((B, L), i32),
        }
        if cfg.family == "audio":
            batch["enc_frames"] = sds((B, cfg.enc_len_train, cfg.d_model), bf16)
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), bf16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, L), i32)}
        if cfg.family == "audio":
            batch["enc_frames"] = sds((B, cfg.enc_len_serve, cfg.d_model), bf16)
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model), bf16)
        return batch
    if shape.kind == "decode":
        return {
            "tokens": sds((B, 1), i32),
            "pos": sds((B,), i32),
        }
    raise ValueError(shape.kind)
