"""recurrentgemma-2b — RG-LRU + local attention hybrid, pattern 2 recurrent :
1 local-attn [arXiv:2402.19427]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    rnn_width=2560,
    local_window=2048,
    block_pattern=("rec", "rec", "attn"),
    rope_theta=10_000.0,
    source="arXiv:2402.19427",
)
