"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.configs.llama3_8b import CONFIG as _llama3_8b
from repro.configs.internlm2_20b import CONFIG as _internlm2_20b
from repro.configs.granite_3_8b import CONFIG as _granite_3_8b
from repro.configs.llama3_405b import CONFIG as _llama3_405b
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba_7b
from repro.configs.arctic_480b import CONFIG as _arctic_480b
from repro.configs.grok_1_314b import CONFIG as _grok_1_314b
from repro.configs.seamless_m4t_medium import CONFIG as _seamless_m4t_medium
from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma_2b
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_3_2_vision_11b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        _llama3_8b,
        _internlm2_20b,
        _granite_3_8b,
        _llama3_405b,
        _falcon_mamba_7b,
        _arctic_480b,
        _grok_1_314b,
        _seamless_m4t_medium,
        _recurrentgemma_2b,
        _llama_3_2_vision_11b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells(include_skips: bool = False):
    """Every assigned (arch, shape) cell; skipped cells included on request."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            if arch.supports(shape):
                yield arch, shape, True
            elif include_skips:
                yield arch, shape, False
