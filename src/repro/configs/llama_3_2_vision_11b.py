"""llama-3.2-vision-11b — dense GQA transformer with cross-attn image layers
every 5th layer; vision frontend stubbed as precomputed patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    cross_attn_period=5,  # 8 cross-attn layers over 40
    n_img_tokens=1024,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
