from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, token_batch_spec
from repro.configs.registry import ARCHS, all_cells, get_arch, get_shape

__all__ = [
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "token_batch_spec",
    "ARCHS",
    "all_cells",
    "get_arch",
    "get_shape",
]
