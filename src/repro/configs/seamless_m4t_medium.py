"""seamless-m4t-medium — encoder/decoder transformer backbone, multimodal
frontend stubbed as precomputed frame embeddings [arXiv:2308.11596]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,  # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    enc_len_train=4096,
    enc_len_serve=4096,
    rope_theta=10_000.0,
    source="arXiv:2308.11596",
)
