"""Clock abstraction: wall time for production, virtual time for tests.

Every timestamp and every timed wait in the broker core goes through the
*active clock* (``get_clock()``):

  * ``runtime/tracing.now`` stamps trace events,
  * ``core/fault.py`` breaker reset windows and the straggler watchdog tick,
  * the managers' modeled latencies (submit round-trips, env bring-up,
    HPC queue waits) and ``sleep`` tasks,
  * the streaming dispatcher's micro-batch window (``core/dispatcher.py``).

``WallClock`` is the default: ``time.perf_counter`` + ``time.sleep``.

``VirtualClock`` decouples scheduler time from wall time so that DAG
scheduling scenarios with thousands of multi-second sleep tasks run in
(real) milliseconds, deterministically enough for property tests: virtual
time only moves when the auto-advancer jumps it to the earliest pending
deadline, so every sleeper wakes at *exactly* its requested deadline and
trace timestamps are exact virtual instants rather than noisy wall times.

Threading model: sleepers park on one condition variable keyed by a heap of
deadlines.  A daemon auto-advancer polls (real time); once the pending
deadline set has been stable for ``stability_polls`` consecutive polls --
giving in-flight threads a grace window to reach their ``sleep()`` call --
it jumps ``now`` to the earliest deadline and wakes everyone.  Tests that
want full manual control pass ``auto_advance=False`` and call ``advance``.
"""
from __future__ import annotations

import heapq
import threading
import time
from contextlib import contextmanager
from typing import Callable, Optional


class ScheduledCall:
    """Handle for a ``Clock.call_later`` registration: cancellable once."""

    def __init__(self, deadline: float, fn: Callable[[], None]):
        self.deadline = deadline
        self._fn: Optional[Callable[[], None]] = fn
        self._lock = threading.Lock()

    def cancel(self) -> bool:
        """Prevent the callback from firing; True iff it had not fired yet."""
        with self._lock:
            fired = self._fn is None
            self._fn = None
            return not fired

    @property
    def active(self) -> bool:
        with self._lock:
            return self._fn is not None

    def _fire(self) -> None:
        with self._lock:
            fn, self._fn = self._fn, None
        if fn is not None:
            fn()


class Clock:
    """Interface: the broker core only ever uses these five methods."""

    name = "base"

    def now(self) -> float:
        raise NotImplementedError

    def stamp(self) -> float:
        """Lock-free best-effort ``now()`` for high-rate telemetry stamps
        (the event bus calls this adjacent to every hot-path counter).  May
        trail an in-flight advance by one tick; never goes backwards within
        a thread.  Defaults to ``now()`` — clocks whose ``now()`` takes a
        lock should override with an unsynchronized read."""
        return self.now()

    def sleep(self, duration: float) -> None:
        raise NotImplementedError

    def wait_event(self, event: threading.Event, timeout: Optional[float] = None) -> bool:
        """``event.wait(timeout)`` with the timeout measured on THIS clock."""
        raise NotImplementedError

    def call_later(self, delay: float, fn: Callable[[], None]) -> ScheduledCall:
        """Schedule ``fn()`` to run once, ``delay`` clock-seconds from now
        (the autoscaler's acquisition-completion path).  The callback runs on
        a clock-owned thread and must not ``sleep()`` on this same clock."""
        raise NotImplementedError

    @contextmanager
    def hold(self):
        """Scoped advancement barrier: while held, a virtual clock will not
        auto-advance (no-op on wall clocks).  The streaming dispatcher holds
        the clock while draining/dispatching a batch so virtual time cannot
        jump while readiness events are mid-flight between threads.  Never
        ``sleep()`` on the same clock inside a hold — the advancer only
        honours holds for a bounded number of polls (liveness valve), so a
        sleep-under-hold degrades to slow ticks instead of deadlock."""
        yield

    def close(self) -> None:
        pass


class WallClock(Clock):
    name = "wall"

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, duration: float) -> None:
        if duration > 0:
            time.sleep(duration)

    def wait_event(self, event: threading.Event, timeout: Optional[float] = None) -> bool:
        return event.wait(timeout)

    def call_later(self, delay: float, fn: Callable[[], None]) -> ScheduledCall:
        call = ScheduledCall(self.now() + max(0.0, delay), fn)
        timer = threading.Timer(max(0.0, delay), call._fire)
        timer.daemon = True
        timer.start()
        return call


class VirtualClock(Clock):
    name = "virtual"

    def __init__(
        self,
        start: float = 0.0,
        auto_advance: bool = True,
        poll_s: float = 0.0005,
        stability_polls: int = 2,
    ):
        self._now = float(start)
        self._cond = threading.Condition()
        self._sleepers: list[float] = []  # heap of pending virtual deadlines
        self._timers: list[tuple[float, int, ScheduledCall]] = []  # call_later heap
        self._timer_seq = 0
        self._holds = 0  # active hold() scopes: advancement barrier
        self._closed = False
        self._poll_s = poll_s
        self._stability_polls = max(1, stability_polls)
        self._stop = threading.Event()
        self._advancer: Optional[threading.Thread] = None
        self.advances = 0  # ticks performed (observability/tests)
        if auto_advance:
            self._advancer = threading.Thread(
                target=self._advance_loop, daemon=True, name="virtual-clock"
            )
            self._advancer.start()

    # -- reading / driving time ----------------------------------------
    def now(self) -> float:
        with self._cond:
            return self._now

    def stamp(self) -> float:
        # GIL-atomic float read; racing an advance yields the pre-advance
        # instant, which is a valid (momentarily stale) observation — and
        # skipping the cond keeps emit() off the clock's contended lock
        return self._now

    def advance(self, dt: float) -> float:
        """Manually move time forward and wake any due sleepers/timers."""
        with self._cond:
            self._now += max(0.0, dt)
            due = self._pop_due_timers()
            self._cond.notify_all()
            t = self._now
        for call in due:
            call._fire()
        return t

    def advance_to(self, t: float) -> float:
        with self._cond:
            self._now = max(self._now, t)
            due = self._pop_due_timers()
            self._cond.notify_all()
            t = self._now
        for call in due:
            call._fire()
        return t

    def pending_deadlines(self) -> int:
        with self._cond:
            self._purge_cancelled()
            # cancelled timers below the heap head are lazily deleted and
            # will never fire: they are not *pending* (scenario residue
            # checks read this after shutdown)
            live = sum(1 for _, _, call in self._timers if call.active)
            return len(self._sleepers) + live

    # -- delayed callbacks -------------------------------------------------
    def call_later(self, delay: float, fn: Callable[[], None]) -> ScheduledCall:
        """Register a virtual-deadline callback: fired by advance()/the
        auto-advancer once virtual time reaches it.  The deadline counts as a
        pending deadline, so the advancer will jump to it when it is next."""
        with self._cond:
            call = ScheduledCall(self._now + max(0.0, delay), fn)
            if self._closed:
                call.cancel()  # a closed clock never fires
                return call
            if call.deadline <= self._now:
                due = [call]
            else:
                self._timer_seq += 1
                heapq.heappush(self._timers, (call.deadline, self._timer_seq, call))
                due = []
            self._cond.notify_all()
        for c in due:
            c._fire()
        return call

    def _pop_due_timers(self) -> list[ScheduledCall]:
        # callers hold self._cond
        due = []
        while self._timers and self._timers[0][0] <= self._now:
            due.append(heapq.heappop(self._timers)[2])
        return due

    def _purge_cancelled(self) -> None:
        # callers hold self._cond: drop cancelled timers from the heap head
        # so they cannot attract an advancer jump to a dead deadline
        while self._timers and not self._timers[0][2].active:
            heapq.heappop(self._timers)

    def _earliest_deadline(self) -> Optional[float]:
        # callers hold self._cond
        self._purge_cancelled()
        heads = []
        if self._sleepers:
            heads.append(self._sleepers[0])
        if self._timers:
            heads.append(self._timers[0][0])
        return min(heads) if heads else None

    # -- virtual waiting -------------------------------------------------
    def sleep(self, duration: float) -> None:
        if duration <= 0:
            return
        with self._cond:
            if self._closed:
                return
            deadline = self._now + duration
            heapq.heappush(self._sleepers, deadline)
            while self._now < deadline and not self._closed:
                # the real-time timeout is a liveness guard only; wakeups
                # come from advance()/the auto-advancer notifying the cond
                self._cond.wait(timeout=0.05)
            self._drop_passed()

    def wait_event(self, event: threading.Event, timeout: Optional[float] = None) -> bool:
        if timeout is None:
            return event.wait()
        with self._cond:
            deadline = self._now + timeout
            heapq.heappush(self._sleepers, deadline)
            try:
                while True:
                    if event.is_set():
                        return True
                    if self._now >= deadline or self._closed:
                        return event.is_set()
                    self._cond.wait(timeout=0.01)
            finally:
                # withdraw our deadline if time never reached it (event won)
                if deadline in self._sleepers:
                    self._sleepers.remove(deadline)
                    heapq.heapify(self._sleepers)
                self._drop_passed()

    def _drop_passed(self) -> None:
        # callers hold self._cond
        while self._sleepers and self._sleepers[0] <= self._now:
            heapq.heappop(self._sleepers)

    @contextmanager
    def hold(self):
        with self._cond:
            self._holds += 1
        try:
            yield
        finally:
            with self._cond:
                self._holds = max(0, self._holds - 1)

    # -- auto-advancer ---------------------------------------------------
    def _advance_loop(self) -> None:
        stable = 0
        held_polls = 0
        last_sig: Optional[tuple] = None
        while not self._stop.wait(self._poll_s):
            fire: list[ScheduledCall] = []
            with self._cond:
                self._drop_passed()
                earliest = self._earliest_deadline()
                if earliest is None:
                    stable, last_sig = 0, None
                    continue
                if self._holds > 0 and held_polls < 100:
                    # a dispatch round is mid-flight: defer the tick
                    # (bounded: ~100 polls, the sleep-under-hold valve)
                    held_polls += 1
                    stable, last_sig = 0, None
                    continue
                held_polls = 0
                sig = (len(self._sleepers), len(self._timers), earliest)
                stable = stable + 1 if sig == last_sig else 1
                last_sig = sig
                if stable >= self._stability_polls:
                    self._now = max(self._now, earliest)
                    self.advances += 1
                    stable, last_sig = 0, None
                    self._drop_passed()
                    fire = self._pop_due_timers()
                    self._cond.notify_all()
            for call in fire:  # outside the cond: callbacks may re-enter the clock
                call._fire()

    def close(self) -> None:
        """Stop the advancer and release every parked sleeper immediately.
        Unfired call_later registrations are dropped, not fired: the clock's
        owner is tearing the world down."""
        self._stop.set()
        with self._cond:
            self._closed = True
            if self._sleepers:
                self._now = max(self._now, max(self._sleepers))
                self._sleepers.clear()
            for _, _, call in self._timers:
                call.cancel()
            self._timers.clear()
            self._cond.notify_all()
        if self._advancer is not None:
            self._advancer.join(timeout=2.0)


# ---------------------------------------------------------------------------
# Active-clock registry
# ---------------------------------------------------------------------------

_active: Clock = WallClock()
_registry_lock = threading.Lock()


def get_clock() -> Clock:
    return _active


def set_clock(clock: Clock) -> Clock:
    """Install ``clock`` as the process-wide active clock; returns the old one."""
    global _active
    with _registry_lock:
        previous = _active
        _active = clock
        return previous


def now() -> float:
    return _active.now()


def guard_wait(
    event: threading.Event,
    timeout: Optional[float] = None,
    in_flight: Optional[Callable[[], bool]] = None,
) -> bool:
    """Completion-event wait with a *guard* timeout (Submission.wait,
    WorkflowManager.run): returns when the event fires, or when the timeout
    elapses on EITHER the active clock or real time, whichever comes first.

    Unlike ``Clock.wait_event`` this does not eagerly register the deadline
    as a virtual sleeper: a guard must not invite the auto-advancer to jump
    to the timeout while real (non-sleeping) work is still executing.  The
    real-time bound is what keeps a frozen virtual clock from turning a
    guard into an infinite hang.

    Idle valve: when nothing at all is in flight on a virtual clock (no
    pending sleeper/timer deadlines and virtual time not moving for a short
    real-time grace window), no event source can exist that the guard would
    be shielding — so the remaining timeout IS registered as a sleeper and
    the guard elapses at the *virtual* deadline instead of burning the full
    real-time budget (``Submission.wait(timeout=...)`` with no tasks in
    flight used to block for ``timeout`` real seconds).

    ``in_flight`` refines the valve for callers that can SEE their work:
    while it returns True (e.g. a task is executing pure-CPU compute that
    never touches the clock), the valve stays closed even though the clock
    looks idle, so real work cannot be cut short by a phantom virtual
    timeout."""
    clock = get_clock()
    if timeout is None or isinstance(clock, WallClock):
        return clock.wait_event(event, timeout)
    v_deadline = clock.now() + timeout
    r_deadline = time.monotonic() + timeout
    idle_polls = 0
    last_v = clock.now()
    # the valve needs an auto-advancer to serve the registered deadline: on a
    # manually-driven clock it would trade a bounded wait for a hang
    auto = getattr(clock, "_advancer", None) is not None
    pending = getattr(clock, "pending_deadlines", None) if auto else None
    while True:
        if event.is_set():
            return True
        v_now = clock.now()
        if v_now >= v_deadline or time.monotonic() >= r_deadline:
            return event.is_set()
        if pending is not None:
            if v_now == last_v and pending() == 0 and not (in_flight and in_flight()):
                idle_polls += 1
            else:
                idle_polls = 0
            last_v = v_now
            if idle_polls >= 5:  # ~100ms real grace: in-flight threads have
                # reached their sleep() by now, or there are none
                clock.wait_event(event, max(0.0, v_deadline - v_now))
                return event.is_set()
        event.wait(0.02)


@contextmanager
def use_clock(clock: Clock):
    """Scoped clock swap (tests): restores the previous clock on exit."""
    previous = set_clock(clock)
    try:
        yield clock
    finally:
        set_clock(previous)


@contextmanager
def virtual_time(start: float = 0.0, auto_advance: bool = True, **kw):
    """Scoped VirtualClock that is closed (all sleepers released) on exit."""
    clock = VirtualClock(start=start, auto_advance=auto_advance, **kw)
    try:
        with use_clock(clock):
            yield clock
    finally:
        clock.close()
