"""Clock abstraction: wall time for production, virtual time for tests.

Every timestamp and every timed wait in the broker core goes through the
*active clock* (``get_clock()``):

  * ``runtime/tracing.now`` stamps trace events,
  * ``core/fault.py`` breaker reset windows and the straggler watchdog tick,
  * the managers' modeled latencies (submit round-trips, env bring-up,
    HPC queue waits) and ``sleep`` tasks,
  * the streaming dispatcher's micro-batch window (``core/dispatcher.py``).

``WallClock`` is the default: ``time.perf_counter`` + ``time.sleep``.

``VirtualClock`` decouples scheduler time from wall time so that DAG
scheduling scenarios with thousands of multi-second sleep tasks run in
(real) milliseconds, deterministically enough for property tests: virtual
time only moves when the auto-advancer jumps it to the earliest pending
deadline, so every sleeper wakes at *exactly* its requested deadline and
trace timestamps are exact virtual instants rather than noisy wall times.

Threading model: sleepers park on one condition variable keyed by a heap of
deadlines.  A daemon auto-advancer polls (real time); once the pending
deadline set has been stable for ``stability_polls`` consecutive polls --
giving in-flight threads a grace window to reach their ``sleep()`` call --
it jumps ``now`` to the earliest deadline and wakes everyone.  Tests that
want full manual control pass ``auto_advance=False`` and call ``advance``.
"""
from __future__ import annotations

import heapq
import threading
import time
from contextlib import contextmanager
from typing import Optional


class Clock:
    """Interface: the broker core only ever uses these four methods."""

    name = "base"

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, duration: float) -> None:
        raise NotImplementedError

    def wait_event(self, event: threading.Event, timeout: Optional[float] = None) -> bool:
        """``event.wait(timeout)`` with the timeout measured on THIS clock."""
        raise NotImplementedError

    @contextmanager
    def hold(self):
        """Scoped advancement barrier: while held, a virtual clock will not
        auto-advance (no-op on wall clocks).  The streaming dispatcher holds
        the clock while draining/dispatching a batch so virtual time cannot
        jump while readiness events are mid-flight between threads.  Never
        ``sleep()`` on the same clock inside a hold — the advancer only
        honours holds for a bounded number of polls (liveness valve), so a
        sleep-under-hold degrades to slow ticks instead of deadlock."""
        yield

    def close(self) -> None:
        pass


class WallClock(Clock):
    name = "wall"

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, duration: float) -> None:
        if duration > 0:
            time.sleep(duration)

    def wait_event(self, event: threading.Event, timeout: Optional[float] = None) -> bool:
        return event.wait(timeout)


class VirtualClock(Clock):
    name = "virtual"

    def __init__(
        self,
        start: float = 0.0,
        auto_advance: bool = True,
        poll_s: float = 0.0005,
        stability_polls: int = 2,
    ):
        self._now = float(start)
        self._cond = threading.Condition()
        self._sleepers: list[float] = []  # heap of pending virtual deadlines
        self._holds = 0  # active hold() scopes: advancement barrier
        self._closed = False
        self._poll_s = poll_s
        self._stability_polls = max(1, stability_polls)
        self._stop = threading.Event()
        self._advancer: Optional[threading.Thread] = None
        self.advances = 0  # ticks performed (observability/tests)
        if auto_advance:
            self._advancer = threading.Thread(
                target=self._advance_loop, daemon=True, name="virtual-clock"
            )
            self._advancer.start()

    # -- reading / driving time ----------------------------------------
    def now(self) -> float:
        with self._cond:
            return self._now

    def advance(self, dt: float) -> float:
        """Manually move time forward and wake any due sleepers."""
        with self._cond:
            self._now += max(0.0, dt)
            self._cond.notify_all()
            return self._now

    def advance_to(self, t: float) -> float:
        with self._cond:
            self._now = max(self._now, t)
            self._cond.notify_all()
            return self._now

    def pending_deadlines(self) -> int:
        with self._cond:
            return len(self._sleepers)

    # -- virtual waiting -------------------------------------------------
    def sleep(self, duration: float) -> None:
        if duration <= 0:
            return
        with self._cond:
            if self._closed:
                return
            deadline = self._now + duration
            heapq.heappush(self._sleepers, deadline)
            while self._now < deadline and not self._closed:
                # the real-time timeout is a liveness guard only; wakeups
                # come from advance()/the auto-advancer notifying the cond
                self._cond.wait(timeout=0.05)
            self._drop_passed()

    def wait_event(self, event: threading.Event, timeout: Optional[float] = None) -> bool:
        if timeout is None:
            return event.wait()
        with self._cond:
            deadline = self._now + timeout
            heapq.heappush(self._sleepers, deadline)
            try:
                while True:
                    if event.is_set():
                        return True
                    if self._now >= deadline or self._closed:
                        return event.is_set()
                    self._cond.wait(timeout=0.01)
            finally:
                # withdraw our deadline if time never reached it (event won)
                if deadline in self._sleepers:
                    self._sleepers.remove(deadline)
                    heapq.heapify(self._sleepers)
                self._drop_passed()

    def _drop_passed(self) -> None:
        # callers hold self._cond
        while self._sleepers and self._sleepers[0] <= self._now:
            heapq.heappop(self._sleepers)

    @contextmanager
    def hold(self):
        with self._cond:
            self._holds += 1
        try:
            yield
        finally:
            with self._cond:
                self._holds = max(0, self._holds - 1)

    # -- auto-advancer ---------------------------------------------------
    def _advance_loop(self) -> None:
        stable = 0
        held_polls = 0
        last_sig: Optional[tuple] = None
        while not self._stop.wait(self._poll_s):
            with self._cond:
                self._drop_passed()
                if not self._sleepers:
                    stable, last_sig = 0, None
                    continue
                if self._holds > 0 and held_polls < 100:
                    # a dispatch round is mid-flight: defer the tick
                    # (bounded: ~100 polls, the sleep-under-hold valve)
                    held_polls += 1
                    stable, last_sig = 0, None
                    continue
                held_polls = 0
                sig = (len(self._sleepers), self._sleepers[0])
                stable = stable + 1 if sig == last_sig else 1
                last_sig = sig
                if stable >= self._stability_polls:
                    self._now = max(self._now, self._sleepers[0])
                    self.advances += 1
                    stable, last_sig = 0, None
                    self._drop_passed()
                    self._cond.notify_all()

    def close(self) -> None:
        """Stop the advancer and release every parked sleeper immediately."""
        self._stop.set()
        with self._cond:
            self._closed = True
            if self._sleepers:
                self._now = max(self._now, max(self._sleepers))
                self._sleepers.clear()
            self._cond.notify_all()
        if self._advancer is not None:
            self._advancer.join(timeout=2.0)


# ---------------------------------------------------------------------------
# Active-clock registry
# ---------------------------------------------------------------------------

_active: Clock = WallClock()
_registry_lock = threading.Lock()


def get_clock() -> Clock:
    return _active


def set_clock(clock: Clock) -> Clock:
    """Install ``clock`` as the process-wide active clock; returns the old one."""
    global _active
    with _registry_lock:
        previous = _active
        _active = clock
        return previous


def now() -> float:
    return _active.now()


def guard_wait(event: threading.Event, timeout: Optional[float] = None) -> bool:
    """Completion-event wait with a *guard* timeout (Submission.wait,
    WorkflowManager.run): returns when the event fires, or when the timeout
    elapses on EITHER the active clock or real time, whichever comes first.

    Unlike ``Clock.wait_event`` this never registers the deadline as a
    virtual sleeper: a guard must not invite the auto-advancer to jump to
    the timeout while real (non-sleeping) work is still executing.  The
    real-time bound is what keeps a frozen virtual clock from turning a
    guard into an infinite hang."""
    clock = get_clock()
    if timeout is None or isinstance(clock, WallClock):
        return clock.wait_event(event, timeout)
    v_deadline = clock.now() + timeout
    r_deadline = time.monotonic() + timeout
    while True:
        if event.is_set():
            return True
        if clock.now() >= v_deadline or time.monotonic() >= r_deadline:
            return event.is_set()
        event.wait(0.02)


@contextmanager
def use_clock(clock: Clock):
    """Scoped clock swap (tests): restores the previous clock on exit."""
    previous = set_clock(clock)
    try:
        yield clock
    finally:
        set_clock(previous)


@contextmanager
def virtual_time(start: float = 0.0, auto_advance: bool = True, **kw):
    """Scoped VirtualClock that is closed (all sleepers released) on exit."""
    clock = VirtualClock(start=start, auto_advance=auto_advance, **kw)
    try:
        with use_clock(clock):
            yield clock
    finally:
        clock.close()
