"""Event tracing + the paper's four metrics (OVH, TH, TPT, TTX).

Definitions (Hydra paper §5):
  OVH - time Hydra spends preparing the workload for execution and
        communicating with the platform middleware to initiate execution
        (bind + partition + serialize + submit phases).
  TH  - broker throughput: tasks *processed* per second (not executed).
  TPT - task total processing time on the platform: execute the tasks AND
        prepare/shut down the task execution environments.
  TTX - total time the platform takes to execute all submitted tasks.

Every Task/Pod/Provider carries a trace: a list of (event, t) stamped by the
*active clock* (runtime/clock.py) — ``time.perf_counter`` under the default
WallClock, exact virtual instants under a VirtualClock.  Metrics are derived
purely from traces, so they are platform- and workload-agnostic, exactly as
in the paper, and scheduler tests can replay 10k-task scenarios in virtual
time without distorting a single metric formula.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Iterable, Optional

from repro.runtime.clock import now


@dataclass
class Trace:
    events: list[tuple[str, float]] = field(default_factory=list)

    def add(self, event: str, t: Optional[float] = None) -> float:
        t = now() if t is None else t
        self.events.append((event, t))
        return t

    def first(self, event: str) -> Optional[float]:
        for e, t in self.events:
            if e == event:
                return t
        return None

    def last(self, event: str) -> Optional[float]:
        out = None
        for e, t in self.events:
            if e == event:
                out = t
        return out

    def span(self, start: str, end: str) -> Optional[float]:
        t0, t1 = self.first(start), self.last(end)
        if t0 is None or t1 is None:
            return None
        return t1 - t0


# ---------------------------------------------------------------------------
# Metric aggregation
# ---------------------------------------------------------------------------

# Broker-side (OVH) phases, in order.
OVH_PHASES = [
    ("bind_start", "bind_done"),
    ("partition_start", "partition_done"),
    ("serialize_start", "serialize_done"),
    ("submit_start", "submit_done"),
]


@dataclass
class Metrics:
    ovh: float  # broker overhead (s)
    th: float  # broker throughput (tasks/s)
    tpt: float  # platform processing time (s), incl. env setup/teardown
    ttx: float  # platform execution time (s)
    n_tasks: int
    n_pods: int
    phases: dict[str, float] = field(default_factory=dict)

    def row(self) -> dict:
        return {
            "ovh_s": round(self.ovh, 6),
            "th_tasks_per_s": round(self.th, 2),
            "tpt_s": round(self.tpt, 6),
            "ttx_s": round(self.ttx, 6),
            "n_tasks": self.n_tasks,
            "n_pods": self.n_pods,
            **{f"phase_{k}_s": round(v, 6) for k, v in self.phases.items()},
        }

    def otel(self) -> dict:
        """The same row under the OTel-style metric names the event bus
        uses (core/events.py, docs/OBSERVABILITY.md), so run-level metrics
        and log-derived counters share one namespace in exported JSON."""
        return {
            "hydra.run.ovh_s": round(self.ovh, 6),
            "hydra.run.th_tasks_per_s": round(self.th, 2),
            "hydra.run.tpt_s": round(self.tpt, 6),
            "hydra.run.ttx_s": round(self.ttx, 6),
            "hydra.run.n_tasks": self.n_tasks,
            "hydra.run.n_pods": self.n_pods,
            **{
                f"hydra.run.phase.{k}_s": round(v, 6)
                for k, v in self.phases.items()
            },
        }


def compute_metrics(run_trace: Trace, tasks: Iterable, pods: Iterable) -> Metrics:
    """Derive the paper's metrics from the broker run trace + task traces."""
    tasks, pods = list(tasks), list(pods)
    phases = {}
    ovh = 0.0
    for start, end in OVH_PHASES:
        d = run_trace.span(start, end)
        if d is not None:
            phases[start.rsplit("_", 1)[0]] = d
            ovh += d

    # TH: tasks processed by the broker / broker processing window
    t0 = run_trace.first("bind_start")
    t1 = run_trace.last("submit_done")
    th = len(tasks) / (t1 - t0) if (t0 is not None and t1 is not None and t1 > t0) else 0.0

    # TPT: platform window incl. env setup/teardown (pod env_up .. env_down)
    env_up = [t for p in pods if (t := p.trace.first("env_setup_start")) is not None]
    env_dn = [t for p in pods if (t := p.trace.last("env_teardown_done")) is not None]
    tpt = (max(env_dn) - min(env_up)) if env_up and env_dn else 0.0

    # TTX: first task exec start .. last task exec done
    starts = [t for task in tasks if (t := task.trace.first("exec_start")) is not None]
    ends = [t for task in tasks if (t := task.trace.last("exec_done")) is not None]
    ttx = (max(ends) - min(starts)) if starts and ends else 0.0

    return Metrics(ovh, th, tpt, ttx, len(tasks), len(pods), phases)


class Counter:
    """Thread-safe monotonically increasing id generator."""

    def __init__(self, prefix: str):
        self.prefix = prefix
        self._n = 0
        self._lock = threading.Lock()

    def next(self) -> str:
        with self._lock:
            self._n += 1
            return f"{self.prefix}.{self._n:06d}"
