"""FACTS-like sea-level projection science, in JAX (paper §4).

The real FACTS (Framework for Assessing Changes To Sea-level) composes
modules that turn climate forcings into probabilistic sea-level projections.
This module implements a faithful miniature of its 4-stage workflow so that
Experiment 4 runs the *same shape of computation* end-to-end:

  pre-processing : synthesize + normalize a forcing series (GSAT anomaly)
                   and a short observed sea-level record per site
  fitting        : fit a semi-empirical emulator  dS/dt = a*T + b  (ridge
                   regression with parameter covariance, cf. Rahmstorf-style
                   semi-empirical models used for FACTS' 2lm emulators)
  projecting     : Monte-Carlo ensemble over emulator parameter uncertainty
                   + residual noise, integrated to 2100
  post-processing: quantiles (5/17/50/83/95) of projected rise

Every stage is pure JAX/numpy, seeded per (site, instance) - deterministic,
restartable, and cheap enough to run hundreds of concurrent instances (the
paper runs 50-800).
"""
from __future__ import annotations


import numpy as np

import jax
import jax.numpy as jnp

YEARS_HIST = 120  # observed record length
YEAR_END = 2100
N_SAMPLES = 1000
QUANTILES = (0.05, 0.17, 0.50, 0.83, 0.95)


def preprocess(site: int, seed: int = 0) -> dict:
    """Synthesize forcing + observations for a site; normalize."""
    rng = np.random.default_rng((seed, site))
    years = np.arange(1900, 1900 + YEARS_HIST)
    # GSAT anomaly: slow trend + ENSO-ish oscillation + noise
    trend = 0.008 * (years - 1900) + 0.004 * np.maximum(years - 1970, 0)
    osc = 0.08 * np.sin(2 * np.pi * (years - 1900) / 6.3)
    gsat = trend + osc + rng.normal(0, 0.05, YEARS_HIST)
    # "true" local sensitivity varies by site
    a_true = 1.8 + 0.6 * rng.normal()
    b_true = 0.3 + 0.1 * rng.normal()
    rate = a_true * gsat + b_true + rng.normal(0, 0.25, YEARS_HIST)  # mm/yr
    sea_level = np.cumsum(rate)  # mm
    gsat_n = (gsat - gsat.mean()) / (gsat.std() + 1e-9)
    return {
        "site": site,
        "years": years,
        "gsat": gsat,
        "gsat_norm": gsat_n,
        "sea_level_mm": sea_level,
    }


def fit(pre: dict, ridge: float = 1e-3) -> dict:
    """Fit dS/dt = a*T + b with ridge regression; return params + covariance."""
    gsat = jnp.asarray(pre["gsat"], jnp.float32)
    s = jnp.asarray(pre["sea_level_mm"], jnp.float32)
    rate = jnp.diff(s, prepend=s[:1])
    X = jnp.stack([gsat, jnp.ones_like(gsat)], axis=-1)  # (T, 2)
    XtX = X.T @ X + ridge * jnp.eye(2)
    theta = jnp.linalg.solve(XtX, X.T @ rate)
    resid = rate - X @ theta
    sigma2 = jnp.mean(resid**2)
    cov = sigma2 * jnp.linalg.inv(XtX)
    return {
        "site": pre["site"],
        "theta": np.asarray(theta),
        "cov": np.asarray(cov),
        "sigma2": float(sigma2),
    }


def project(pre: dict, fitted: dict, n_samples: int = N_SAMPLES, seed: int = 0) -> dict:
    """Monte-Carlo projection of sea-level rise to YEAR_END (vectorized JAX)."""
    key = jax.random.key((seed << 16) ^ fitted["site"])
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jnp.asarray(fitted["theta"], jnp.float32)
    cov = jnp.asarray(fitted["cov"], jnp.float32)
    chol = jnp.linalg.cholesky(cov + 1e-9 * jnp.eye(2))
    thetas = theta[None, :] + jax.random.normal(k1, (n_samples, 2)) @ chol.T

    years_f = jnp.arange(pre["years"][-1] + 1, YEAR_END + 1)
    n_f = years_f.shape[0]
    # future forcing scenario: continued warming + scenario spread
    base = 0.02 * (years_f - pre["years"][-1]) + float(pre["gsat"][-20:].mean())
    scen = base[None, :] * (1.0 + 0.3 * jax.random.normal(k2, (n_samples, 1)))
    rates = thetas[:, :1] * scen + thetas[:, 1:2]  # (S, n_f) mm/yr
    noise = jnp.sqrt(fitted["sigma2"]) * jax.random.normal(k3, (n_samples, n_f))
    rise = jnp.cumsum(rates + noise, axis=1)  # (S, n_f) mm above present
    return {
        "site": fitted["site"],
        "years": np.asarray(years_f),
        "rise_mm": np.asarray(rise[:, -1]),  # at YEAR_END
        "trajectories": np.asarray(rise[:, :: max(1, n_f // 20)]),
    }


def postprocess(proj: dict) -> dict:
    """Quantiles of end-of-century rise (the FACTS headline numbers)."""
    q = np.quantile(proj["rise_mm"], QUANTILES)
    return {
        "site": proj["site"],
        "quantiles": dict(zip([f"p{int(100*x)}" for x in QUANTILES], q.tolist())),
        "mean_mm": float(proj["rise_mm"].mean()),
        "std_mm": float(proj["rise_mm"].std()),
    }
