"""FACTS workflow assembly: 4 chained tasks per instance, staged through the
DataManager exactly like the paper's pre-staged input files (§5.4).

Each stage is a ``callable`` Task; inter-stage data moves through the
provider-local site store (pickled npz blobs), so a stage re-bound to a
different provider after a failure still finds its inputs in the shared
store - the same pattern Hydra uses with cloud object stores.

Data footprints (paper: ~1 core / ~2 GB per stage): when a
``DatasetRegistry`` (core/staging.py) is passed, every stage declares its
real data dependencies — a shared climate-forcing dataset feeding *every*
instance's preprocess stage, plus the per-instance pre/fit/proj/result
chain — so the staging subsystem charges cross-site movement and the
data-gravity policy can keep a chain's stages where its bytes already live.
The physical pickle blobs stay tiny; the registry carries the modeled sizes.
"""
from __future__ import annotations

import pickle
from typing import Optional

from repro.core.managers.data import DataManager
from repro.core.managers.workflow import Workflow
from repro.core.task import Resources, Task
from repro.facts import model as facts

# Modeled footprints (MB), shaped after the paper's FACTS deployment: the
# forcing archive is the heavyweight shared input; projections dominate the
# per-instance chain.
FORCING_DATASET = "facts/forcing/era5"
FORCING_MB = 2048.0
STAGE_MB = {"pre": 512.0, "fit": 64.0, "proj": 1024.0, "result": 16.0}


def _put(dm: DataManager, rel: str, obj) -> None:
    dm.put_bytes("shared", rel, pickle.dumps(obj))


def _get(dm: DataManager, rel: str):
    return pickle.loads(dm.get_bytes("shared", rel))


def register_forcing(registry) -> None:
    """Declare the shared climate-forcing input (idempotent): one pinned
    replica in the shared store, the cold-read source every site pulls."""
    registry.add(FORCING_DATASET, FORCING_MB, sites=["shared"], pinned=True)


def make_workflow(
    dm: DataManager,
    instance: int,
    seed: int = 0,
    n_samples: int = facts.N_SAMPLES,
    provider: Optional[str] = None,
    registry=None,
) -> Workflow:
    """One FACTS instance: pre -> fit -> project -> post (1 core, ~2GB each
    in the paper; tiny here, same DAG shape).  With ``registry`` the stages
    declare their modeled data footprints for the staging subsystem."""
    wf = Workflow(name=f"facts.{instance:05d}")
    base = f"facts/{instance:05d}"
    res = Resources(cpus=1, memory_mb=2048)

    def stage_pre():
        pre = facts.preprocess(instance, seed)
        _put(dm, f"{base}/pre.pkl", pre)
        return pre["site"]

    def stage_fit():
        pre = _get(dm, f"{base}/pre.pkl")
        fitted = facts.fit(pre)
        _put(dm, f"{base}/fit.pkl", fitted)
        return fitted["theta"].tolist()

    def stage_project():
        pre = _get(dm, f"{base}/pre.pkl")
        fitted = _get(dm, f"{base}/fit.pkl")
        proj = facts.project(pre, fitted, n_samples=n_samples, seed=seed)
        _put(dm, f"{base}/proj.pkl", proj)
        return float(proj["rise_mm"].mean())

    def stage_post():
        proj = _get(dm, f"{base}/proj.pkl")
        out = facts.postprocess(proj)
        _put(dm, f"{base}/result.pkl", out)
        return out

    io = {"pre": {}, "fit": {}, "proj": {}, "post": {}}
    if registry is not None:
        register_forcing(registry)
        io = {
            "pre": dict(
                inputs=[FORCING_DATASET],
                outputs={f"{base}/pre": STAGE_MB["pre"]},
            ),
            "fit": dict(
                inputs=[f"{base}/pre"],
                outputs={f"{base}/fit": STAGE_MB["fit"]},
            ),
            "proj": dict(
                inputs=[f"{base}/pre", f"{base}/fit"],
                outputs={f"{base}/proj": STAGE_MB["proj"]},
            ),
            "post": dict(
                inputs=[f"{base}/proj"],
                outputs={f"{base}/result": STAGE_MB["result"]},
            ),
        }

    t_pre = wf.add(
        Task(kind="callable", fn=stage_pre, resources=res, provider=provider, **io["pre"])
    )
    t_fit = wf.add(
        Task(kind="callable", fn=stage_fit, resources=res, provider=provider, **io["fit"]),
        deps=[t_pre],
    )
    t_proj = wf.add(
        Task(
            kind="callable", fn=stage_project, resources=res, provider=provider, **io["proj"]
        ),
        deps=[t_fit],
    )
    wf.add(
        Task(kind="callable", fn=stage_post, resources=res, provider=provider, **io["post"]),
        deps=[t_proj],
    )
    return wf


def result_of(dm: DataManager, instance: int) -> dict:
    return _get(dm, f"facts/{instance:05d}/result.pkl")
