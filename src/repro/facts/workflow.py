"""FACTS workflow assembly: 4 chained tasks per instance, staged through the
DataManager exactly like the paper's pre-staged input files (§5.4).

Each stage is a ``callable`` Task; inter-stage data moves through the
provider-local site store (pickled npz blobs), so a stage re-bound to a
different provider after a failure still finds its inputs in the shared
store - the same pattern Hydra uses with cloud object stores.
"""
from __future__ import annotations

import pickle
from typing import Optional

from repro.core.managers.data import DataManager
from repro.core.managers.workflow import Workflow
from repro.core.task import Resources, Task
from repro.facts import model as facts


def _put(dm: DataManager, rel: str, obj) -> None:
    dm.put_bytes("shared", rel, pickle.dumps(obj))


def _get(dm: DataManager, rel: str):
    return pickle.loads(dm.get_bytes("shared", rel))


def make_workflow(
    dm: DataManager,
    instance: int,
    seed: int = 0,
    n_samples: int = facts.N_SAMPLES,
    provider: Optional[str] = None,
) -> Workflow:
    """One FACTS instance: pre -> fit -> project -> post (1 core, ~2GB each
    in the paper; tiny here, same DAG shape)."""
    wf = Workflow(name=f"facts.{instance:05d}")
    base = f"facts/{instance:05d}"
    res = Resources(cpus=1, memory_mb=2048)

    def stage_pre():
        pre = facts.preprocess(instance, seed)
        _put(dm, f"{base}/pre.pkl", pre)
        return pre["site"]

    def stage_fit():
        pre = _get(dm, f"{base}/pre.pkl")
        fitted = facts.fit(pre)
        _put(dm, f"{base}/fit.pkl", fitted)
        return fitted["theta"].tolist()

    def stage_project():
        pre = _get(dm, f"{base}/pre.pkl")
        fitted = _get(dm, f"{base}/fit.pkl")
        proj = facts.project(pre, fitted, n_samples=n_samples, seed=seed)
        _put(dm, f"{base}/proj.pkl", proj)
        return float(proj["rise_mm"].mean())

    def stage_post():
        proj = _get(dm, f"{base}/proj.pkl")
        out = facts.postprocess(proj)
        _put(dm, f"{base}/result.pkl", out)
        return out

    t_pre = wf.add(Task(kind="callable", fn=stage_pre, resources=res, provider=provider))
    t_fit = wf.add(Task(kind="callable", fn=stage_fit, resources=res, provider=provider), deps=[t_pre])
    t_proj = wf.add(Task(kind="callable", fn=stage_project, resources=res, provider=provider), deps=[t_fit])
    wf.add(Task(kind="callable", fn=stage_post, resources=res, provider=provider), deps=[t_proj])
    return wf


def result_of(dm: DataManager, instance: int) -> dict:
    return _get(dm, f"facts/{instance:05d}/result.pkl")
