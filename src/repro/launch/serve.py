"""Serving driver: batched prefill + autoregressive decode with KV cache /
recurrent state (per family).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \\
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.model import Model


def serve(
    arch_name: str,
    *,
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    temperature: float = 0.0,
    seed: int = 0,
) -> dict:
    arch = get_arch(arch_name)
    if reduced:
        arch = arch.reduced()
    model = Model(arch)
    rng = np.random.default_rng(seed)
    params = model.init(jax.random.key(seed))

    prompts = jnp.asarray(rng.integers(0, arch.vocab_size, (batch, prompt_len)), jnp.int32)
    batch_in = {"tokens": prompts}
    if arch.family == "audio":
        batch_in["enc_frames"] = jnp.asarray(
            rng.normal(size=(batch, arch.enc_len_serve, arch.d_model)), jnp.float32
        )
    if arch.family == "vlm":
        batch_in["img_embeds"] = jnp.asarray(
            rng.normal(size=(batch, arch.n_img_tokens, arch.d_model)), jnp.float32
        )

    cache_len = prompt_len + gen
    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))(params, batch_in)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step)
    key = jax.random.key(seed + 1)

    def sample(lg, key):
        if temperature <= 0:
            return jnp.argmax(lg[:, 0, :], axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg[:, 0, :] / temperature).astype(jnp.int32)

    toks = sample(logits, key)[:, None]
    generated = [toks]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, toks, pos)
        key, sub = jax.random.split(key)
        toks = sample(logits, sub)[:, None]
        generated.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0
    out_tokens = jnp.concatenate(generated, axis=1)
    return {
        "arch": arch_name,
        "tokens": np.asarray(out_tokens),
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(gen - 1, 1),
        "tokens_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = serve(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, temperature=args.temperature,
    )
    print(f"{args.arch}: prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_s_per_token']*1e3:.1f} ms/tok, "
          f"{out['tokens_per_s']:.1f} tok/s")
    print("sample tokens:", out["tokens"][0][:12].tolist())


if __name__ == "__main__":
    main()
