"""Training driver: data pipeline + train step + checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \\
        --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 50

Production semantics on a real fleet, CPU-sized defaults here:
  * restart-safe: resumes from the latest checkpoint (data stream is
    step-indexed, so the token stream realigns exactly),
  * async checkpointing overlaps the save with training,
  * optional int8 error-feedback gradient compression over the DP axes,
  * runs standalone or brokered (examples/train_lm.py submits this loop as a
    Hydra compute task).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax

from repro.ckpt import checkpoint as ckpt_lib
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.optim import adamw
from repro.parallel.sharding import STRATEGIES, default_strategy
from repro.train import step as step_lib


def train(
    arch_name: str,
    *,
    reduced: bool = True,
    steps: int = 100,
    seq_len: int = 64,
    global_batch: int = 8,
    peak_lr: float = 3e-4,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    strategy_name: Optional[str] = None,
    log_every: int = 10,
    seed: int = 0,
) -> dict:
    arch = get_arch(arch_name)
    if reduced:
        arch = arch.reduced()
    model = Model(arch)
    mesh = make_local_mesh(len(jax.devices()))
    strategy = STRATEGIES[strategy_name] if strategy_name else default_strategy(arch)
    if arch.family == "moe" and arch.n_experts < 16:
        strategy = strategy.with_overrides(experts=None)
    opt_cfg = adamw.AdamWConfig(peak_lr=peak_lr, warmup_steps=max(steps // 10, 1), total_steps=steps)
    train_step = jax.jit(step_lib.make_train_step(model, strategy, mesh, opt_cfg), donate_argnums=(0, 1))

    dc = DataConfig(
        vocab_size=arch.vocab_size, seq_len=seq_len, global_batch=global_batch,
        seed=seed, enc_len=arch.enc_len_train, d_model=arch.d_model,
        n_img_tokens=arch.n_img_tokens, family=arch.family,
    )

    start_step = 0
    params, opt = step_lib.init_train_state(model, jax.random.key(seed))
    checkpointer = None
    if ckpt_dir:
        checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir)
        latest = ckpt_lib.latest_step(ckpt_dir)
        if latest is not None:
            start_step, restored = ckpt_lib.restore(ckpt_dir, {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            print(f"resumed from step {start_step}")

    prefetch = Prefetcher(dc, start_step=start_step, depth=2)
    losses = []
    t0 = time.perf_counter()
    try:
        for _ in range(start_step, steps):
            step_idx, batch = next(prefetch)
            params, opt, metrics = train_step(params, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if log_every and (step_idx + 1) % log_every == 0:
                dt = (time.perf_counter() - t0) / max(len(losses), 1)
                print(f"step {step_idx + 1:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms/step)")
            if checkpointer and (step_idx + 1) % ckpt_every == 0:
                checkpointer.save(step_idx + 1, {"params": params, "opt": opt})
    finally:
        prefetch.close()
        if checkpointer:
            checkpointer.wait()
    return {
        "arch": arch_name,
        "steps": len(losses),
        "first_loss": losses[0] if losses else None,
        "final_loss": losses[-1] if losses else None,
        "params": params,
        "opt": opt,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--strategy", default=None)
    args = ap.parse_args()
    out = train(
        args.arch, reduced=args.reduced, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, peak_lr=args.lr, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, strategy_name=args.strategy,
    )
    print(f"done: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
