"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Shapes:
    single-pod : (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
    multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"pod" is the outer data-parallel axis crossing inter-pod DCI links.
"""
from __future__ import annotations

from repro.compat import compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(n_devices: int = 1, model_parallel: int = 1):
    """Small mesh over locally visible devices (tests, examples)."""
    data = max(1, n_devices // model_parallel)
    return compat_make_mesh((data, model_parallel), ("data", "model"))
