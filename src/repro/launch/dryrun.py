import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without real hardware:
``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed on the
single-pod (16x16) and multi-pod (2x16x16) production meshes for every
assigned architecture and input shape.  Outputs per cell:

  * compiled.memory_analysis()  - proves the state fits per device,
  * compiled.cost_analysis()    - HLO FLOPs / bytes for §Roofline,
  * parsed collective bytes     - §Roofline collective term,
  * a JSON artifact under artifacts/dryrun/ consumed by the roofline report.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
      PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape, token_batch_spec, ARCHS, SHAPES
from repro.compat import compat_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim import adamw
from repro.parallel.sharding import STRATEGIES, default_strategy, mesh_axis_sizes, resolve_axes
from repro.roofline.hlo import parse_collectives, parse_hbm_traffic
from repro.roofline.model import Roofline, model_flops
from repro.train import step as step_lib

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def build_cell(arch, shape_name: str, mesh, strategy_name: Optional[str] = None):
    """Returns (jitted_fn, abstract_args: tuple, meta) ready to .lower().

    ``arch`` is an ArchConfig (possibly a reduced-depth cost variant).
    """
    shape = get_shape(shape_name)
    if not arch.supports(shape):
        raise ValueError(f"{arch.name} skips {shape_name} (sub-quadratic only)")
    model = Model(arch)
    strategy = STRATEGIES[strategy_name] if strategy_name else default_strategy(arch)
    if arch.family == "moe" and arch.n_experts < 16:
        strategy = strategy.with_overrides(experts=None)

    batch_specs = token_batch_spec(arch, shape)
    named = lambda tree: jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree)

    if shape.kind == "train":
        shardings = step_lib.make_shardings(model, strategy, mesh, batch_specs)
        opt_cfg = adamw.AdamWConfig()
        fn = step_lib.make_train_step(model, strategy, mesh, opt_cfg)
        params, opt = step_lib.abstract_train_state(model)
        metrics_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), step_lib.metrics_struct(model)
        )
        metrics_sh["grad_norm"] = NamedSharding(mesh, P())
        metrics_sh["lr"] = NamedSharding(mesh, P())
        jfn = jax.jit(
            fn,
            in_shardings=(named(shardings.params), named(shardings.opt), named(shardings.batch)),
            out_shardings=(named(shardings.params), named(shardings.opt), metrics_sh),
            donate_argnums=(0, 1),
        )
        args = (params, opt, batch_specs)
    elif shape.kind == "prefill":
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        shardings = step_lib.make_shardings(model, strategy, mesh, batch_specs, cache_specs)
        fn = step_lib.make_prefill_step(model, strategy, mesh, cache_len=shape.seq_len)
        params = model.abstract_params()
        logits_ps = resolve_axes(
            ("batch", None, "vocab_act"), strategy.act_rules, mesh.axis_names,
            (shape.global_batch, 1, arch.vocab_size), mesh_axis_sizes(mesh))
        jfn = jax.jit(
            fn,
            in_shardings=(named(shardings.params), named(shardings.batch)),
            out_shardings=(
                NamedSharding(mesh, logits_ps),
                named(shardings.cache),
            ),
        )
        args = (params, batch_specs)
    elif shape.kind == "decode":
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        shardings = step_lib.make_shardings(model, strategy, mesh, batch_specs, cache_specs)
        fn = step_lib.make_decode_step(model, strategy, mesh)
        params = model.abstract_params()
        cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        logits_ps = resolve_axes(
            ("batch", None, "vocab_act"), strategy.act_rules, mesh.axis_names,
            (shape.global_batch, 1, arch.vocab_size), mesh_axis_sizes(mesh))
        jfn = jax.jit(
            fn,
            in_shardings=(named(shardings.params), named(shardings.cache), named(shardings.batch)),
            out_shardings=(NamedSharding(mesh, logits_ps), named(shardings.cache)),
            donate_argnums=(1,),
        )
        args = (params, cache, batch_specs)
    else:
        raise ValueError(shape.kind)
    meta = {
        "arch": arch.name,
        "shape": shape_name,
        "strategy": strategy.name,
        "kind": shape.kind,
        "n_chips": mesh.size,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
    }
    return jfn, args, meta


def depth_unit(arch) -> tuple[int, float]:
    """(layers per depth-unit, number of depth-units in the full model)."""
    if arch.family == "hybrid":
        p = len(arch.block_pattern or ("rec", "rec", "attn"))
        return p, arch.n_layers / p
    if arch.family == "vlm":
        p = arch.cross_attn_period
        return p, arch.n_layers / p
    return 1, float(arch.n_layers)


def depth_variant(arch, units: int):
    p, _ = depth_unit(arch)
    kw = {"n_layers": units * p}
    if arch.family == "audio":
        kw["n_enc_layers"] = units  # enc and dec depths extrapolate together
    return arch.replace(**kw)


def measure_costs(arch, shape_name: str, mesh, strategy_name, units: int) -> dict:
    """Lower a reduced-depth, fully-unrolled variant and read exact costs
    (no while loops -> cost_analysis and HLO collectives are exact)."""
    from repro.models.layers import unroll_all_scans

    variant = depth_variant(arch, units)
    with unroll_all_scans():
        jfn, args, _ = build_cell(variant, shape_name, mesh, strategy_name)
        lowered = jfn.lower(*args)
    compiled = lowered.compile()
    cost = compat_cost_analysis(compiled)
    text = compiled.as_text()
    coll = parse_collectives(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "hbm": float(parse_hbm_traffic(text)),
        "coll": float(coll.total_bytes),
    }


def extrapolate_costs(arch, shape_name: str, mesh, strategy_name) -> dict:
    """True per-step cost = alpha + units_full * beta, solved from exact
    unrolled measurements at depth-units 1 and 2 (see layers.unroll_all_scans)."""
    m1 = measure_costs(arch, shape_name, mesh, strategy_name, 1)
    m2 = measure_costs(arch, shape_name, mesh, strategy_name, 2)
    _, units_full = depth_unit(arch)
    out = {}
    for k in ("flops", "bytes", "hbm", "coll"):
        beta = m2[k] - m1[k]
        alpha = max(m1[k] - beta, 0.0)
        out[k] = alpha + units_full * beta
        out[f"{k}_per_layer_unit"] = beta
        out[f"{k}_outside_layers"] = alpha
    return out


def _mem_fields(mem) -> dict:
    out = {}
    for f in (
        "temp_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def run_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool = False,
    strategy_name: Optional[str] = None,
    save: bool = True,
    verbose: bool = True,
    extrapolate: bool = True,
    arch_overrides: Optional[dict] = None,
    label: Optional[str] = None,
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = get_arch(arch_name)
    if arch_overrides:
        arch = arch.replace(**arch_overrides)
    jfn, args, meta = build_cell(arch, shape_name, mesh, strategy_name)
    t0 = time.perf_counter()
    lowered = jfn.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compat_cost_analysis(compiled)
    coll = parse_collectives(compiled.as_text())

    shape = get_shape(shape_name)
    if extrapolate:
        ext = extrapolate_costs(arch, shape_name, mesh, strategy_name)
        flops, byts, collb = ext["flops"], ext["bytes"], ext["coll"]
        hbm = ext["hbm"]
    else:
        ext = None
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        collb = float(coll.total_bytes)
        hbm = float(parse_hbm_traffic(compiled.as_text()))
    rl = Roofline(
        arch=arch_name,
        shape=shape_name,
        mesh=meta["mesh"],
        n_chips=meta["n_chips"],
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_bytes_per_chip=collb,
        model_flops_total=model_flops(arch, shape),
        hbm_bytes_est_per_chip=hbm,
    )
    record = {
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_fields(mem),
        "raw_cost_flops_per_chip": float(cost.get("flops", 0.0)),
        "raw_cost_bytes_per_chip": float(cost.get("bytes accessed", 0.0)),
        "raw_collectives": coll.row(),
        "extrapolated": ext,
        "flops_per_chip": flops,
        "bytes_per_chip": byts,
        "collective_bytes_per_chip": collb,
        "roofline": rl.row(),
    }
    if verbose:
        print(f"== {arch_name} x {shape_name} on {meta['mesh']} ({meta['strategy']}) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost (extrapolated over scan trip counts): flops={flops:.3e} bytes={byts:.3e} coll={collb:.3e}")
        print(f"  raw collectives (loop bodies once): {coll.row()}")
        print(f"  roofline: {rl.row()}")
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        sname = label or strategy_name or "default"
        path = os.path.join(
            ARTIFACT_DIR, f"{arch_name}__{shape_name}__{meta['mesh']}__{sname}.json"
        )
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
    return record


def kernel_report(save: bool = True, verbose: bool = True) -> list[dict]:
    """Roofline-predicted Pallas kernel configs (kernels/autotune.py
    ``predict_best``): for every registered kernel at its smoke and full
    bench shapes, the config the pruned model sweep picks, its predicted
    arithmetic intensity, and the sweep accounting.  Pure model — no
    execution, no compilation — so the rows sit next to the HLO-derived
    roofline cells and predicted-vs-measured drift is visible in one place
    (benchmarks/roofline_report.py reads the saved artifact)."""
    from repro.kernels import registry as kreg
    from repro.kernels.autotune import predict_best

    rows = []
    for name, kdef in kreg.KERNELS.items():
        for tier in ("smoke", "full"):
            shape = dict(getattr(kdef, f"{tier}_shape"))
            rows.append({"tier": tier, **predict_best(name, shape)})
            if verbose:
                r = rows[-1]
                print(
                    f"  {name:18s} {tier:5s} config={r['config']:28s} "
                    f"intensity={r['intensity_flops_per_byte']:9.3f} "
                    f"swept {r['swept']}/{r['exhaustive']}"
                )
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        path = os.path.join(ARTIFACT_DIR, "kernels__predicted.json")
        with open(path, "w") as f:
            json.dump({"kind": "kernel_predictions", "rows": rows}, f, indent=2)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--all", action="store_true", help="every supported (arch x shape) cell")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            if get_arch(a).supports(get_shape(s)):
                cells.append((a, s))
            else:
                print(f"SKIP {a} x {s} (sub-quadratic only; see DESIGN.md)")

    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    failures = []
    for a, s in cells:
        for mp in pods:
            try:
                run_cell(a, s, multi_pod=mp, strategy_name=args.strategy,
                         extrapolate=not mp)
            except Exception as e:
                failures.append((a, s, mp, repr(e)))
                traceback.print_exc()
    print("\n== Pallas kernel predicted configs (roofline model, no execution) ==")
    kernel_report()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"\nall {len(cells) * len(pods)} cells compiled OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
