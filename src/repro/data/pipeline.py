"""Synthetic shard-aware data pipeline with background prefetch.

Deterministic synthetic token streams (seeded per shard) stand in for a
tokenized corpus: each *data shard* (one per DP rank group) draws from its own
PRNG stream, so global batches are reproducible under any DP layout and across
restarts (the stream is indexed by step, not by wall clock).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

import jax


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # frontend stubs
    enc_len: int = 0
    d_model: int = 0
    n_img_tokens: int = 0
    family: str = "dense"


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Deterministic batch for a global step (host numpy; restart-safe)."""
    rng = np.random.default_rng((cfg.seed, step))
    toks = rng.integers(0, cfg.vocab_size, (cfg.global_batch, cfg.seq_len + 1), dtype=np.int64)
    batch = {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }
    if cfg.family == "audio":
        batch["enc_frames"] = rng.normal(
            size=(cfg.global_batch, cfg.enc_len, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "vlm":
        batch["img_embeds"] = rng.normal(
            size=(cfg.global_batch, cfg.n_img_tokens, cfg.d_model)
        ).astype(np.float32)
    return batch


class Prefetcher:
    """Background thread that keeps ``depth`` batches ready (device-put if
    shardings are given) so the train loop never waits on the host."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2, shardings=None):
        self.cfg = cfg
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_at(self.cfg, step)
            if self.shardings is not None:
                batch = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), batch, self.shardings
                )
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def data_config_for(arch, shape, seed: int = 0) -> DataConfig:
    return DataConfig(
        vocab_size=arch.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        enc_len=arch.enc_len_train,
        d_model=arch.d_model,
        n_img_tokens=arch.n_img_tokens,
        family=arch.family,
    )
