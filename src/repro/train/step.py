"""Train / serve step builders: model + sharding strategy + optimizer -> jittable steps.

These are what the broker's compute manager compiles ("container images") and
what the multi-pod dry-run lowers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.models.spec import is_spec_leaf, tree_sds
from repro.optim import adamw
from repro.parallel.sharding import (
    Strategy,
    activation_rules,
    dp_axes,
    param_pspec_tree,
    resolve_axes,
)


# ---------------------------------------------------------------------------
# Sharding bundles
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepShardings:
    params: Any  # PartitionSpec tree
    opt: Any
    batch: Any
    cache: Optional[Any] = None


def batch_pspecs(batch_specs: dict, mesh: Mesh, strategy: Optional[Strategy] = None) -> dict:
    """tokens/labels (B, L) -> P(dp, None); stub embeddings (B, T, D) likewise.
    Respects the strategy's "batch" activation rule (serve_2dtp replicates)."""
    from repro.parallel.sharding import mesh_axis_sizes, resolve_axes as _resolve

    rules = {"batch": strategy.act_rules.get("batch", "__dp__") if strategy else "__dp__"}
    sizes = mesh_axis_sizes(mesh)

    def one(sds):
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        return _resolve(axes, rules, mesh.axis_names, tuple(sds.shape), sizes)

    return jax.tree.map(one, batch_specs)


def act_pspec_tree(specs, strategy: Strategy, mesh: Mesh):
    """Cache/state spec tree -> PartitionSpecs via the *activation* rules."""
    from repro.parallel.sharding import mesh_axis_sizes

    sizes = mesh_axis_sizes(mesh)
    return jax.tree.map(
        lambda s: resolve_axes(s.axes, strategy.act_rules, mesh.axis_names, s.shape, sizes),
        specs,
        is_leaf=is_spec_leaf,
    )


def make_shardings(
    model: Model,
    strategy: Strategy,
    mesh: Mesh,
    batch_specs: dict,
    cache_specs=None,
) -> StepShardings:
    pspecs = param_pspec_tree(model.specs(), strategy, mesh)
    from repro.parallel.sharding import mesh_axis_sizes

    opt = adamw.opt_pspec_tree(
        model.specs(), pspecs, strategy.zero1, mesh_axis_sizes(mesh).get("data", 1)
    )
    batch = batch_pspecs(batch_specs, mesh, strategy)
    cache = act_pspec_tree(cache_specs, strategy, mesh) if cache_specs is not None else None
    return StepShardings(pspecs, opt, batch, cache)


def named(tree, mesh: Mesh):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(model: Model, strategy: Strategy, mesh: Mesh, opt_cfg: adamw.AdamWConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        with activation_rules(strategy, mesh):
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_compressed_train_step(
    model: Model, strategy: Strategy, mesh: Mesh, opt_cfg: adamw.AdamWConfig
):
    """Train step with int8 error-feedback gradient reduction over the DP axes.

    shard_map over the dp axes (model axis left to GSPMD via auto) computes
    LOCAL gradients per DP shard, then the explicit compressed all-reduce
    replaces the implicit bf16/fp32 psum.  comp_state carries the error
    feedback between steps.
    """
    from repro.optim.compression import compressed_mean

    dp = dp_axes(mesh.axis_names)
    auto = frozenset(a for a in mesh.axis_names if a not in dp)
    pspecs = param_pspec_tree(model.specs(), strategy, mesh)

    def local_grads(params, batch):
        with activation_rules(strategy, mesh):
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
        return grads, metrics

    def train_step(params, opt_state, comp_state, batch):
        def shard_body(params, batch, comp_state):
            grads, metrics = local_grads(params, batch)
            out = jax.tree.map(
                lambda g, st: compressed_mean(g, st, dp),
                grads,
                comp_state,
                is_leaf=lambda x: isinstance(x, dict) and "worker_err" in x,
            )
            mean_grads = jax.tree.map(
                lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple)
            )
            new_comp = jax.tree.map(
                lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple)
            )
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp), metrics)
            return mean_grads, new_comp, metrics

        # params replicated over dp (their model-axis sharding is auto-handled)
        batch_specs = jax.tree.map(lambda _: P(dp if len(dp) > 1 else dp[0]), batch)
        rep = P()
        from repro.compat import compat_shard_map

        grads, comp_state, metrics = compat_shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, params), batch_specs, jax.tree.map(lambda _: rep, comp_state)),
            out_specs=(
                jax.tree.map(lambda _: rep, params),
                jax.tree.map(lambda _: rep, comp_state),
                jax.tree.map(lambda _: rep, metrics_struct(model)),
            ),
        )(params, batch, comp_state)
        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, comp_state, {**metrics, **opt_metrics}

    return train_step


def metrics_struct(model: Model):
    keys = ["ce", "tokens", "loss"]
    if model.cfg.family == "moe":
        keys += ["aux_loss", "z_loss"]
    return {k: 0.0 for k in keys}


def make_prefill_step(model: Model, strategy: Strategy, mesh: Mesh, cache_len: int):
    def prefill_step(params, batch):
        with activation_rules(strategy, mesh):
            return model.prefill(params, batch, cache_len=cache_len)

    return prefill_step


def make_decode_step(model: Model, strategy: Strategy, mesh: Mesh):
    def decode_step(params, cache, batch):
        with activation_rules(strategy, mesh):
            logits, cache = model.decode_step(params, cache, batch["tokens"], batch["pos"])
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# Abstract state (for dry-run and init)
# ---------------------------------------------------------------------------


def abstract_train_state(model: Model):
    params = model.abstract_params()
    opt = tree_sds(adamw.opt_state_specs(model.specs()))
    return params, opt


def init_train_state(model: Model, rng: jax.Array):
    params = model.init(rng)
    return params, adamw.init_state(params)
