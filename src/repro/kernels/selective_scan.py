"""Mamba1 selective-scan chunk Pallas TPU kernel.

Computes one sequence chunk of the diagonal SSM recurrence
    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) B_t,   y_t = <h_t, C_t>
carrying the (d_inner, N) state in VMEM across the chunk's timesteps.

TPU mapping: grid = (batch, d_inner blocks).  Per grid cell the kernel holds
    x/dt tiles   (chunk, block_d)      ~ chunk*block_d*4B
    B/C tiles    (chunk, N)
    state        (block_d, N) fp32 scratch
entirely in VMEM and walks the chunk sequentially with a fori_loop - the
hardware-aware "materialize (L, d, N) only chunk-wise" trick from the Mamba
paper, re-tiled for VMEM instead of SRAM (see DESIGN.md hardware adaptation).
block_d defaults to 512 (multiple of the 128-lane width); the fp32 footprint
at chunk=256, N=16 is ~1.6 MB, well inside 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_ref, *, chunk: int):
    a = a_ref[...].astype(jnp.float32)  # (block_d, N)
    h = h0_ref[0].astype(jnp.float32)  # (block_d, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (block_d,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        da = jnp.exp(dt_t[:, None] * a)  # (block_d, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h)
    h_ref[0] = h.astype(h_ref.dtype)


def selective_scan_chunk(
    x: jax.Array,  # (B, chunk, di)
    dt: jax.Array,  # (B, chunk, di) fp32
    b: jax.Array,  # (B, chunk, N) fp32
    c: jax.Array,  # (B, chunk, N) fp32
    a: jax.Array,  # (di, N) fp32
    h0: jax.Array,  # (B, di, N) fp32
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
):
    """Returns (y (B, chunk, di) fp32, h_last (B, di, N) fp32)."""
    B, chunk, di = x.shape
    N = b.shape[-1]
    block_d = min(block_d, di)
    assert di % block_d == 0, (di, block_d)
    nd = di // block_d

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, nd),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d: (b_, 0, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, d: (b_, 0, d)),
            pl.BlockSpec((1, chunk, N), lambda b_, d: (b_, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b_, d: (b_, 0, 0)),
            pl.BlockSpec((block_d, N), lambda b_, d: (d, 0)),
            pl.BlockSpec((1, block_d, N), lambda b_, d: (b_, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d: (b_, 0, d)),
            pl.BlockSpec((1, block_d, N), lambda b_, d: (b_, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, chunk, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, b, c, a, h0)
    return y, h_last
