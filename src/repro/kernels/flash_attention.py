"""Flash attention (forward) Pallas TPU kernel with GQA-aware KV indexing.

TPU mapping of the paper-agnostic attention hot-spot:
  * grid = (batch, q_heads, q_blocks, kv_blocks); the innermost kv dimension
    executes sequentially on TPU, so the online-softmax running state lives in
    VMEM scratch that persists across kv iterations.
  * BlockSpecs tile Q/K/V into (block_q x head_dim) / (block_k x head_dim)
    VMEM tiles; block sizes are multiples of 128 to keep the MXU matmuls
    hardware-aligned.
  * GQA: the K/V BlockSpec index_map folds the query head onto its KV head
    (h -> h * n_kv // n_heads), so grouped heads read the same KV tile and
    nothing is materialized H-wide in HBM (unlike the XLA path).
  * causal: fully-masked kv blocks are skipped with pl.when - this is the
    ~2x FLOP saving over the XLA blockwise path recorded in §Roofline.

Validated against ref.attention_ref in interpret mode (CPU container); the
TPU target is v5e (16 MB VMEM: worst tile footprint here is
2*(block_q + 2*block_k) * hd * 4B ~ 1.5 MB at the defaults).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip kv blocks that are entirely masked out
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window is not None:
        live = jnp.logical_and(live, q_start - (k_start + block_k - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG)

        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_prev * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, Lq, hd)
    k: jax.Array,  # (B, KV, Lk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, h, lq, hd = q.shape
    n_kv, lk = k.shape[1], k.shape[2]
    assert h % n_kv == 0, (h, n_kv)
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, block_q, lk, block_k)
    nq, nk = lq // block_q, lk // block_k
    scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b_, h_, qi, ki, n_kv=n_kv, h=h: (b_, h_ * n_kv // h, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, hd),
                lambda b_, h_, qi, ki, n_kv=n_kv, h=h: (b_, h_ * n_kv // h, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
