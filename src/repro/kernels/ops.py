"""Jit'd dispatch wrappers over the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute in interpret
mode - the kernel body runs step-by-step in Python/XLA so correctness (and
the BlockSpec tiling logic) is fully exercised without Mosaic.  On a real
v5e these same calls lower to Mosaic TPU kernels.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru_scan as _rg
from repro.kernels import selective_scan as _ss


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: int = _fa.DEFAULT_BLOCK_Q, block_k: int = _fa.DEFAULT_BLOCK_K,
):
    """q (B,H,Lq,hd); k,v (B,KV,Lk,hd) -> (B,H,Lq,hd)."""
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("block_d",))
def selective_scan_chunk(x, dt, b, c, a, h0, *, block_d: int = _ss.DEFAULT_BLOCK_D):
    """One SSM chunk: returns (y (B,chunk,di) f32, h_last (B,di,N) f32)."""
    return _ss.selective_scan_chunk(x, dt, b, c, a, h0, block_d=block_d, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_d",))
def rglru_scan(log_a, gx, h0=None, *, block_d: int = _rg.DEFAULT_BLOCK_D):
    """RG-LRU over a sequence: returns (y (B,L,dr) f32, h_last (B,dr) f32)."""
    return _rg.rglru_scan(log_a, gx, h0, block_d=block_d, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def moe_gmm(
    x, w, *,
    block_c: int = _gmm.DEFAULT_BLOCK_C,
    block_f: int = _gmm.DEFAULT_BLOCK_F,
    block_d: int = _gmm.DEFAULT_BLOCK_D,
):
    """Grouped expert matmul: x (E,C,D) @ w (E,D,F) -> (E,C,F)."""
    return _gmm.moe_gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d, interpret=_interpret())
