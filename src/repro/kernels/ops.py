"""Jit'd dispatch wrappers over the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute in interpret
mode - the kernel body runs step-by-step in Python/XLA so correctness (and
the BlockSpec tiling logic) is fully exercised without Mosaic.  On a real
v5e these same calls lower to Mosaic TPU kernels.

Block-config resolution happens OUTSIDE the jitted functions (block sizes
are static jit arguments, so a cache lookup inside the trace would bake the
first answer in forever): each public wrapper resolves

  explicit caller argument  >  autotuned cache (HYDRA_AUTOTUNE=1 only)
                            >  the kernel's committed default

then calls the private jitted dispatcher.  With the env gate off (the
default) the tuner is never consulted and behavior is bit-identical to the
static defaults; see kernels/autotune.py for the cache.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru_scan as _rg
from repro.kernels import selective_scan as _ss
from repro.kernels.autotune import tuned_config


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(kernel: str, shape: dict, dtype, defaults: dict, explicit: dict) -> dict:
    """explicit arg > tuned cache (env-gated) > committed default."""
    if all(v is not None for v in explicit.values()):
        return explicit
    tuned = tuned_config(kernel, shape, str(jax.numpy.dtype(dtype))) or {}
    return {
        k: v if v is not None else tuned.get(k, defaults[k])
        for k, v in explicit.items()
    }


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def _flash_attention_jit(q, k, v, *, causal, window, block_q, block_k):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    block_q: Optional[int] = None, block_k: Optional[int] = None,
):
    """q (B,H,Lq,hd); k,v (B,KV,Lk,hd) -> (B,H,Lq,hd)."""
    b, h, lq, hd = q.shape
    shape = {
        "B": b, "H": h, "KV": k.shape[1], "L": lq, "hd": hd,
        "causal": causal, "window": window,
    }
    cfg = _resolve(
        "flash_attention", shape, q.dtype,
        {"block_q": _fa.DEFAULT_BLOCK_Q, "block_k": _fa.DEFAULT_BLOCK_K},
        {"block_q": block_q, "block_k": block_k},
    )
    return _flash_attention_jit(
        q, k, v, causal=causal, window=window,
        block_q=cfg["block_q"], block_k=cfg["block_k"],
    )


@partial(jax.jit, static_argnames=("block_d",))
def _selective_scan_jit(x, dt, b, c, a, h0, *, block_d):
    return _ss.selective_scan_chunk(x, dt, b, c, a, h0, block_d=block_d, interpret=_interpret())


def selective_scan_chunk(x, dt, b, c, a, h0, *, block_d: Optional[int] = None):
    """One SSM chunk: returns (y (B,chunk,di) f32, h_last (B,di,N) f32)."""
    B, chunk, di = x.shape
    shape = {"B": B, "chunk": chunk, "di": di, "N": b.shape[-1]}
    cfg = _resolve(
        "selective_scan", shape, x.dtype,
        {"block_d": _ss.DEFAULT_BLOCK_D}, {"block_d": block_d},
    )
    return _selective_scan_jit(x, dt, b, c, a, h0, block_d=cfg["block_d"])


@partial(jax.jit, static_argnames=("block_d",))
def _rglru_scan_jit(log_a, gx, h0, *, block_d):
    return _rg.rglru_scan(log_a, gx, h0, block_d=block_d, interpret=_interpret())


def rglru_scan(log_a, gx, h0=None, *, block_d: Optional[int] = None):
    """RG-LRU over a sequence: returns (y (B,L,dr) f32, h_last (B,dr) f32)."""
    B, L, dr = log_a.shape
    shape = {"B": B, "L": L, "dr": dr}
    cfg = _resolve(
        "rglru_scan", shape, log_a.dtype,
        {"block_d": _rg.DEFAULT_BLOCK_D}, {"block_d": block_d},
    )
    return _rglru_scan_jit(log_a, gx, h0, block_d=cfg["block_d"])


@partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def _moe_gmm_jit(x, w, *, block_c, block_f, block_d):
    return _gmm.moe_gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d, interpret=_interpret())


def moe_gmm(
    x, w, *,
    block_c: Optional[int] = None,
    block_f: Optional[int] = None,
    block_d: Optional[int] = None,
):
    """Grouped expert matmul: x (E,C,D) @ w (E,D,F) -> (E,C,F)."""
    E, C, D = x.shape
    shape = {"E": E, "C": C, "D": D, "F": w.shape[-1]}
    cfg = _resolve(
        "moe_gmm", shape, x.dtype,
        {
            "block_c": _gmm.DEFAULT_BLOCK_C,
            "block_f": _gmm.DEFAULT_BLOCK_F,
            "block_d": _gmm.DEFAULT_BLOCK_D,
        },
        {"block_c": block_c, "block_f": block_f, "block_d": block_d},
    )
    return _moe_gmm_jit(
        x, w, block_c=cfg["block_c"], block_f=cfg["block_f"], block_d=cfg["block_d"]
    )
