"""Pure-jnp oracles for every Pallas kernel (exact, unblocked math)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, Lq, hd)
    k: jax.Array,  # (B, KV, Lk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
) -> jax.Array:
    b, h, lq, hd = q.shape
    n_kv, lk = k.shape[1], k.shape[2]
    rep = h // n_kv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (hd**0.5)
    q_pos = jnp.arange(lq)[:, None]
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def selective_scan_chunk_ref(x, dt, b, c, a, h0):
    """Sequential reference of the SSM chunk recurrence (fp32)."""
    B, chunk, di = x.shape

    def step(h, t):
        dt_t = dt[:, t, :].astype(jnp.float32)  # (B, di)
        x_t = x[:, t, :].astype(jnp.float32)
        b_t = b[:, t, :].astype(jnp.float32)  # (B, N)
        c_t = c[:, t, :].astype(jnp.float32)
        da = jnp.exp(dt_t[..., None] * a[None])  # (B, di, N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1)  # (B, di)
        return h, y_t

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(chunk))
    return ys.swapaxes(0, 1), h  # (B, chunk, di), (B, di, N)


def rglru_ref(log_a, gx, h0=None):
    B, L, dr = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, dr), jnp.float32)

    def step(h, t):
        h = jnp.exp(log_a[:, t, :].astype(jnp.float32)) * h + gx[:, t, :].astype(jnp.float32)
        return h, h

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(L))
    return ys.swapaxes(0, 1), h


def moe_gmm_ref(x, w):
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)
