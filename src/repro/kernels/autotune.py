"""Roofline-driven Pallas autotuner.

Sweeps the block/grid configs of every registered kernel per (device,
problem shape), prunes the sweep with the roofline cost model before any
candidate runs, times the survivors (warm-up + min-of-N), and caches each
winner as a *replicated dataset* in the broker's staging registry — so
tuned configs flow through data-gravity placement and survive site death
exactly like any other artifact.

Pruning (the "provably dominated" rule)
---------------------------------------
Every admissible config computes the same result, so under the roofline
model ``t = max(flops/peak, hbm_bytes/bw) + grid_cells * launch_overhead``
a config A cannot beat a config B whose modeled FLOPs, HBM traffic, AND
grid-cell count are all <= A's (with at least one strictly smaller).  The
sweep therefore keeps only:

  1. configs whose VMEM tile footprint fits the per-core budget (16 MB on
     the v5e target), and
  2. the Pareto frontier of (flops, hbm_bytes, grid_cells) among those.

On the attention kernels this is a real three-way frontier (bigger blocks
=> fewer cell launches and less re-fetched K/V but more masked-out FLOPs);
on rglru the traffic is config-independent and the frontier collapses to
the single largest admissible block.

Cache keys and determinism
--------------------------
Winners key as ``tune:<kernel>:<device>:<shape-sig>`` where the shape sig
is the canonical sorted ``k=v`` string from kernels/registry.py.  The
cached dataset payload is canonical JSON of the *choice* (kernel, device,
shape, dtype, chosen config, sweep accounting, seed) — never the raw
timings — so identically-seeded runs produce byte-identical payloads and
the determinism test can compare them directly.  A cache hit returns the
stored result without re-timing and without emitting ``kernel.tune``.

Timers: ``timer="wall"`` (default) measures real executions of the
interpret path on this container (Mosaic on a real TPU); ``timer="model"``
scores candidates purely with the roofline expression above — fully
deterministic, used by the determinism tests and the dry-run report's
predicted-config rows.

``ops.py`` consults the process-global tuner (:func:`tuned_config`) only
when ``HYDRA_AUTOTUNE=1``; with the gate off every entry point falls back
to the kernels' committed defaults, bit-identical to the pre-autotune
behavior.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.kernels import registry as kreg
from repro.roofline.model import HBM_BW, PEAK_FLOPS

# v5e per-core VMEM budget (see kernels/flash_attention.py footprint note)
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

# modeled per-grid-cell launch overhead for the timer="model" roofline
# expression.  The absolute value only shifts the modeled times; what
# matters is that cell count is priced at all, so the model prefers fewer
# launches when FLOPs/traffic tie (which is also what the interpret path
# measures: its per-cell Python dispatch dominates at bench shapes).
MODEL_CELL_OVERHEAD_S = 1e-6

PAYLOAD_VERSION = 1


def autotune_enabled() -> bool:
    """The ``HYDRA_AUTOTUNE=1`` gate consulted by kernels/ops.py."""
    return os.environ.get("HYDRA_AUTOTUNE", "") not in ("", "0")


def device_kind() -> str:
    import jax

    return jax.default_backend()


@dataclass
class TuneResult:
    kernel: str
    device: str
    sig: str
    key: str
    config: dict
    exhaustive: int  # full sweep-space size
    swept: int  # survivors actually timed
    pruned: int  # exhaustive - swept
    best_s: float  # winner's min-of-N (or modeled) seconds
    timings: dict = field(default_factory=dict)  # config sig -> seconds
    cached: bool = False  # True on cache hits (no re-timing happened)

    @property
    def sweep_cut(self) -> float:
        return self.exhaustive / self.swept if self.swept else float("inf")


class Autotuner:
    """Sweep, prune, time, cache.  One per broker (``Hydra.
    enable_kernel_autotune``) or process-global for bare ops calls."""

    def __init__(
        self,
        *,
        registry=None,  # staging DatasetRegistry (winners become datasets)
        events=None,  # EventBus (kernel.tune on cache misses)
        seed: int = 0,
        reps: int = 3,
        warmup: int = 1,
        timer: str = "wall",
        vmem_budget: int = VMEM_BUDGET_BYTES,
    ):
        assert timer in ("wall", "model"), timer
        self.registry = registry
        self.events = events
        self.seed = seed
        self.reps = reps
        self.warmup = warmup
        self.timer = timer
        self.vmem_budget = vmem_budget
        self._results: dict = {}  # cache key -> TuneResult
        self._payloads: dict = {}  # cache key -> bytes
        self._lock = threading.RLock()
        # legacy accumulators (HYDRA_EVENTS_CHECK ground truth, mirrored by
        # broker._events_recompute when this tuner is broker-attached)
        self.tunes = 0
        self.swept_configs = 0

    # -- wiring --------------------------------------------------------
    def attach(self, registry=None, events=None) -> "Autotuner":
        if registry is not None:
            self.registry = registry
        if events is not None:
            self.events = events
        return self

    # -- keys ----------------------------------------------------------
    def cache_key(self, kernel: str, shape: dict, dtype: str, device: Optional[str] = None) -> str:
        device = device or device_kind()
        return f"tune:{kernel}:{device}:{kreg.shape_sig(shape, dtype)}"

    # -- pruning -------------------------------------------------------
    def prune(self, kernel: str, shape: dict, dtype: str = "float32"):
        """Returns ``(survivors, exhaustive_n)`` where survivors is the
        VMEM-admissible Pareto frontier of (flops, hbm_bytes, grid_cells),
        in sweep-space order (ties resolved deterministically downstream)."""
        kdef = kreg.get_kernel(kernel)
        space = kdef.space(shape)
        exhaustive = len(space)
        costed = [(cfg, kdef.cost(shape, cfg, dtype)) for cfg in space]
        fits = [(cfg, c) for cfg, c in costed if c.vmem_bytes <= self.vmem_budget]
        if not fits:
            # every candidate over budget (degenerate tiny-VMEM override):
            # fall back to the kernel defaults rather than an empty sweep
            return [kdef.defaults(shape)], exhaustive

        def dominated(ci: kreg.Cost) -> bool:
            for _, cj in fits:
                if cj is ci:
                    continue
                if (
                    cj.flops <= ci.flops
                    and cj.hbm_bytes <= ci.hbm_bytes
                    and cj.grid_cells <= ci.grid_cells
                    and (
                        cj.flops < ci.flops
                        or cj.hbm_bytes < ci.hbm_bytes
                        or cj.grid_cells < ci.grid_cells
                    )
                ):
                    return True
            return False

        survivors = [cfg for cfg, c in fits if not dominated(c)]
        return survivors, exhaustive

    # -- timing --------------------------------------------------------
    def _time_wall(self, thunk: Callable[[], object]) -> float:
        import jax

        for _ in range(self.warmup):
            jax.block_until_ready(thunk())
        best = float("inf")
        for _ in range(self.reps):
            t0 = time.perf_counter()
            jax.block_until_ready(thunk())
            best = min(best, time.perf_counter() - t0)
        return best

    @staticmethod
    def model_time_s(cost: kreg.Cost) -> float:
        """Roofline-modeled seconds: max(compute, memory) + launch tax."""
        return (
            max(cost.flops / PEAK_FLOPS, cost.hbm_bytes / HBM_BW)
            + cost.grid_cells * MODEL_CELL_OVERHEAD_S
        )

    # -- the sweep -----------------------------------------------------
    def tune(self, kernel: str, shape: dict, dtype: str = "float32") -> TuneResult:
        """Sweep (or cache-hit) the winning config for one problem.

        Coarse-grained lock: tuning is rare and cache lookups from task
        threads are cheap; holding the lock across the sweep also keeps
        the cache-miss event count exact (one ``kernel.tune`` per key)."""
        with self._lock:
            key = self.cache_key(kernel, shape, dtype)
            hit = self._results.get(key)
            if hit is not None:
                return TuneResult(**{**vars(hit), "cached": True})
            kdef = kreg.get_kernel(kernel)
            survivors, exhaustive = self.prune(kernel, shape, dtype)
            interpret = kreg.interpret_default()
            args = None
            if self.timer == "wall":
                args = kdef.make_args(shape, dtype, self.seed)
            best_cfg, best_s, timings = None, float("inf"), {}
            for cfg in survivors:
                if self.timer == "wall":
                    t = self._time_wall(lambda: kdef.call(shape, args, cfg, interpret))
                else:
                    t = self.model_time_s(kdef.cost(shape, cfg, dtype))
                timings[kreg.config_sig(cfg)] = t
                # strict < : ties keep the earlier (canonical-order) config,
                # so the choice is deterministic under the modeled timer
                if t < best_s:
                    best_cfg, best_s = cfg, t
            result = TuneResult(
                kernel=kernel,
                device=key.split(":")[2],
                sig=kreg.shape_sig(shape, dtype),
                key=key,
                config=dict(best_cfg),
                exhaustive=exhaustive,
                swept=len(survivors),
                pruned=exhaustive - len(survivors),
                best_s=best_s,
                timings=timings,
            )
            payload = self._payload_bytes(result, shape, dtype)
            self._results[key] = result
            self._payloads[key] = payload
            self._register_dataset(key, payload)
            self.tunes += 1
            self.swept_configs += result.swept
            if self.events is not None:
                self.events.emit(
                    "kernel.tune",
                    kernel=kernel,
                    sig=result.sig,
                    config=kreg.config_sig(result.config),
                    swept=result.swept,
                    exhaustive=exhaustive,
                )
            return result

    def _payload_bytes(self, result: TuneResult, shape: dict, dtype: str) -> bytes:
        # choice only, never timings: byte-identical across same-seed runs
        doc = {
            "version": PAYLOAD_VERSION,
            "kernel": result.kernel,
            "device": result.device,
            "dtype": dtype,
            "shape": {k: shape[k] for k in sorted(shape)},
            "sig": result.sig,
            "config": result.config,
            "exhaustive": result.exhaustive,
            "swept": result.swept,
            "pruned": result.pruned,
            "seed": self.seed,
            "reps": self.reps,
            "timer": self.timer,
        }
        return json.dumps(doc, sort_keys=True, separators=(",", ":")).encode()

    def _register_dataset(self, key: str, payload: bytes) -> None:
        if self.registry is None:
            return
        from repro.core.staging import SHARED_SITE

        # pinned shared-store replica: a tuned config is authoritative
        # metadata, never LRU-evicted, and survives any one site's death
        self.registry.add(
            key, size_mb=max(len(payload) / 1e6, 1e-6),
            sites=(SHARED_SITE,), pinned=True,
        )

    # -- consultation (the ops.py fast path) ---------------------------
    def lookup(self, kernel: str, shape: dict, dtype: str = "float32") -> Optional[dict]:
        """Cached winner for this problem, or None (caller uses defaults).
        Never triggers a sweep: the dispatch fast path must stay cheap and
        deterministic."""
        with self._lock:
            hit = self._results.get(self.cache_key(kernel, shape, dtype))
            return dict(hit.config) if hit is not None else None

    def payload(self, key: str) -> Optional[bytes]:
        with self._lock:
            return self._payloads.get(key)

    def results(self) -> dict:
        with self._lock:
            return dict(self._results)

    def stats(self) -> dict:
        with self._lock:
            return {"tunes": self.tunes, "swept_configs": self.swept_configs}


# ---------------------------------------------------------------------------
# process-global tuner (bare ops.py calls outside any broker)
# ---------------------------------------------------------------------------

_GLOBAL: Optional[Autotuner] = None
_GLOBAL_LOCK = threading.Lock()


def get_autotuner() -> Autotuner:
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = Autotuner()
        return _GLOBAL


def set_autotuner(tuner: Optional[Autotuner]) -> None:
    """Install (or clear, with None) the process-global tuner consulted by
    kernels/ops.py under HYDRA_AUTOTUNE=1."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        _GLOBAL = tuner


def unset_autotuner(tuner: Autotuner) -> None:
    """Clear the global slot only if ``tuner`` still owns it (broker
    shutdown must not clobber a successor broker's tuner)."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is tuner:
            _GLOBAL = None


def tuned_config(kernel: str, shape: dict, dtype: str = "float32") -> Optional[dict]:
    """Env-gated cache consultation for the ops.py entry points: None when
    the gate is off or the problem was never tuned (deterministic fallback
    to the committed defaults)."""
    if not autotune_enabled():
        return None
    return get_autotuner().lookup(kernel, shape, dtype)


def predict_best(kernel: str, shape: dict, dtype: str = "float32") -> dict:
    """Pure-model prediction (no execution): the config the roofline picks
    plus its predicted intensity — the dry-run report row that sits next to
    the HLO-derived intensity so predicted-vs-measured drift is visible."""
    tuner = Autotuner(timer="model")
    kdef = kreg.get_kernel(kernel)
    survivors, exhaustive = tuner.prune(kernel, shape, dtype)
    best_cfg, best_t = None, float("inf")
    for cfg in survivors:
        t = tuner.model_time_s(kdef.cost(shape, cfg, dtype))
        if t < best_t:
            best_cfg, best_t = cfg, t
    cost = kdef.cost(shape, best_cfg, dtype)
    return {
        "kernel": kernel,
        "sig": kreg.shape_sig(shape, dtype),
        "config": kreg.config_sig(best_cfg),
        "swept": len(survivors),
        "exhaustive": exhaustive,
        "intensity_flops_per_byte": round(cost.intensity, 3),
        "t_model_s": best_t,
    }


__all__ = [
    "VMEM_BUDGET_BYTES",
    "TuneResult",
    "Autotuner",
    "autotune_enabled",
    "get_autotuner",
    "set_autotuner",
    "unset_autotuner",
    "tuned_config",
    "predict_best",
]
