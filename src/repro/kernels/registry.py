"""Kernel registry: one shared description of every Pallas kernel.

Each :class:`KernelDef` bundles what the rest of the system needs to treat a
kernel as brokered work rather than a hand-called function:

  make_args     seeded, deterministic problem-instance builder (same seed +
                same shape => bit-identical operands on every host)
  call / ref    the Pallas path (explicit block config + interpret flag) and
                the pure-jnp oracle from kernels/ref.py
  space         the exhaustive block/tile sweep space for a problem shape
  cost          the roofline cost model for one (shape, config) point:
                FLOPs, modeled HBM traffic, VMEM tile footprint, grid cells

The cost model mirrors the BlockSpec tiling exactly: traffic counts one tile
fetch per *launched* grid cell (Pallas copies blocks for masked-out cells
too), while FLOPs count only *live* cells (``pl.when`` skips the math), so
larger attention blocks trade extra masked FLOPs for fewer cell launches and
less re-fetched K/V — the three-way frontier the autotuner prunes on
(kernels/autotune.py).

Consumers: the autotuner, the ``kind="kernel"`` task runtime
(core/managers/compute.py), benchmarks/kernels_bench.py, and the parity
tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ref as _ref
from repro.kernels import rglru_scan as _rg
from repro.kernels import selective_scan as _ss

# power-of-two block candidates; a config is admissible only if every block
# divides its dimension (after the kernels' own min(block, dim) clamp)
_BLOCK_CANDIDATES = (32, 64, 128, 256, 512, 1024)


@dataclass(frozen=True)
class Cost:
    """Roofline cost of one (shape, config) point."""

    flops: float
    hbm_bytes: float
    vmem_bytes: float
    grid_cells: int

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (FLOPs per modeled HBM byte)."""
        return self.flops / self.hbm_bytes if self.hbm_bytes else 0.0


@dataclass(frozen=True)
class KernelDef:
    name: str
    params: tuple  # config keys, canonical order
    defaults: Callable[[dict], dict]
    make_args: Callable[[dict, str, int], tuple]
    call: Callable[[dict, tuple, dict, bool], Any]
    ref: Callable[[dict, tuple], Any]
    space: Callable[[dict], list]
    cost: Callable[[dict, dict, str], Cost]
    tiny_shape: dict  # default payload shape for kind="kernel" tasks
    smoke_shape: dict  # CI bench shape (BENCH_smoke.json rows)
    full_shape: dict  # nightly sweep shape


def _isz(dtype: str) -> int:
    return jnp.dtype(dtype).itemsize


def _divisors(dim: int, candidates=_BLOCK_CANDIDATES) -> list:
    out = [c for c in candidates if c <= dim and dim % c == 0]
    return out or [dim]


def shape_sig(shape: dict, dtype: str) -> str:
    """Canonical shape signature used in tune-cache keys: sorted ``k=v``
    pairs + dtype, no spaces (dataset names must be stable strings)."""
    parts = [f"{k}={shape[k]}".lower() for k in sorted(shape)]
    parts.append(f"dtype={dtype}")
    return ",".join(parts)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


def _fa_blocks(shape: dict, config: dict) -> tuple:
    lq = shape["L"]
    bq = min(config["block_q"], lq)
    bk = min(config["block_k"], lq)
    return bq, bk, lq // bq, lq // bk


def _fa_live_cells(shape: dict, config: dict) -> int:
    bq, bk, nq, nk = _fa_blocks(shape, config)
    window = shape.get("window")
    live = 0
    for qi in range(nq):
        for ki in range(nk):
            ok = True
            if shape.get("causal", True):
                ok = ki * bk <= qi * bq + bq - 1
            if window is not None:
                ok = ok and (qi * bq - (ki * bk + bk - 1) < window)
            live += ok
    return live


def _fa_defaults(shape: dict) -> dict:
    return {"block_q": _fa.DEFAULT_BLOCK_Q, "block_k": _fa.DEFAULT_BLOCK_K}


def _fa_make_args(shape: dict, dtype: str, seed: int) -> tuple:
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, H, KV, L, hd = shape["B"], shape["H"], shape["KV"], shape["L"], shape["hd"]
    q = jax.random.normal(kq, (B, H, L, hd), jnp.dtype(dtype))
    k = jax.random.normal(kk, (B, KV, L, hd), jnp.dtype(dtype))
    v = jax.random.normal(kv, (B, KV, L, hd), jnp.dtype(dtype))
    return q, k, v


def _fa_call(shape: dict, args: tuple, config: dict, interpret: bool):
    q, k, v = args
    return _fa.flash_attention(
        q, k, v,
        causal=shape.get("causal", True), window=shape.get("window"),
        block_q=config["block_q"], block_k=config["block_k"],
        interpret=interpret,
    )


def _fa_ref(shape: dict, args: tuple):
    q, k, v = args
    return _ref.attention_ref(
        q, k, v, causal=shape.get("causal", True), window=shape.get("window")
    )


def _fa_space(shape: dict) -> list:
    divs = _divisors(shape["L"], candidates=(32, 64, 128, 256, 512))
    return [{"block_q": bq, "block_k": bk} for bq in divs for bk in divs]


def _fa_cost(shape: dict, config: dict, dtype: str) -> Cost:
    B, H, hd = shape["B"], shape["H"], shape["hd"]
    isz = _isz(dtype)
    bq, bk, nq, nk = _fa_blocks(shape, config)
    live = _fa_live_cells(shape, config)
    cells = B * H * nq * nk
    # two MXU matmuls (q@k^T and p@v) per LIVE cell; masked cells skip math
    flops = 4.0 * B * H * live * bq * bk * hd
    # tile traffic per LAUNCHED cell (block copies happen even when masked):
    # q tile + k tile + v tile in, plus the output written once per q row
    hbm = isz * B * H * (nq * nk * (bq + 2 * bk) * hd + shape["L"] * hd)
    # q/k/v input tiles + fp32 scratch (m, l, acc) + output tile
    vmem = isz * (bq + 2 * bk) * hd + 4 * bq * (2 + hd) + isz * bq * hd
    return Cost(flops, float(hbm), float(vmem), cells)


# ---------------------------------------------------------------------------
# selective_scan
# ---------------------------------------------------------------------------


def _ss_defaults(shape: dict) -> dict:
    return {"block_d": _ss.DEFAULT_BLOCK_D}


def _ss_make_args(shape: dict, dtype: str, seed: int) -> tuple:
    kx, kdt, kb, kc, ka = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, ck, di, N = shape["B"], shape["chunk"], shape["di"], shape["N"]
    x = jax.random.normal(kx, (B, ck, di), jnp.dtype(dtype))
    dt = jax.random.uniform(kdt, (B, ck, di), jnp.float32, 0.001, 0.1)
    b = jax.random.normal(kb, (B, ck, N), jnp.float32)
    c = jax.random.normal(kc, (B, ck, N), jnp.float32)
    a = -jax.random.uniform(ka, (di, N), jnp.float32, 0.5, 2.0)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    return x, dt, b, c, a, h0


def _ss_call(shape: dict, args: tuple, config: dict, interpret: bool):
    return _ss.selective_scan_chunk(
        *args, block_d=config["block_d"], interpret=interpret
    )


def _ss_ref(shape: dict, args: tuple):
    return _ref.selective_scan_chunk_ref(*args)


def _ss_space(shape: dict) -> list:
    return [{"block_d": bd} for bd in _divisors(shape["di"])]


def _ss_cost(shape: dict, config: dict, dtype: str) -> Cost:
    B, ck, di, N = shape["B"], shape["chunk"], shape["di"], shape["N"]
    isz = _isz(dtype)
    bd = min(config["block_d"], di)
    nd = di // bd
    cells = B * nd
    # per timestep per channel: exp-discretize + state update + y reduction
    flops = 6.0 * B * ck * di * N
    # per cell: x/dt in, B/C in (re-fetched per d-block: the config lever),
    # a + h0 in, y + h out
    per_cell = (
        isz * ck * bd + 4 * ck * bd  # x (dtype) + dt (f32)
        + 4 * (2 * ck * N + 2 * bd * N)  # b, c, a, h0
        + 4 * (ck * bd + bd * N)  # y, h_last
    )
    vmem = isz * ck * bd + 4 * (2 * ck * bd + 2 * ck * N + 3 * bd * N)
    return Cost(flops, float(cells * per_cell), float(vmem), cells)


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------


def _rg_defaults(shape: dict) -> dict:
    return {"block_d": _rg.DEFAULT_BLOCK_D}


def _rg_make_args(shape: dict, dtype: str, seed: int) -> tuple:
    ka, kg = jax.random.split(jax.random.PRNGKey(seed), 2)
    B, L, dr = shape["B"], shape["L"], shape["dr"]
    log_a = -jax.random.uniform(ka, (B, L, dr), jnp.float32, 0.01, 1.0)
    gx = jax.random.normal(kg, (B, L, dr), jnp.float32)
    h0 = jnp.zeros((B, dr), jnp.float32)
    return log_a, gx, h0


def _rg_call(shape: dict, args: tuple, config: dict, interpret: bool):
    return _rg.rglru_scan(*args, block_d=config["block_d"], interpret=interpret)


def _rg_ref(shape: dict, args: tuple):
    return _ref.rglru_ref(*args)


def _rg_space(shape: dict) -> list:
    return [{"block_d": bd} for bd in _divisors(shape["dr"])]


def _rg_cost(shape: dict, config: dict, dtype: str) -> Cost:
    B, L, dr = shape["B"], shape["L"], shape["dr"]
    bd = min(config["block_d"], dr)
    cells = B * (dr // bd)
    # exp + multiply-add per (t, channel); traffic is config-independent
    # (log_a/gx/y each touched once, h tiles sum to B*dr regardless of bd),
    # so the frontier collapses to minimum grid cells: the pruner keeps only
    # the largest admissible block
    flops = 3.0 * B * L * dr
    hbm = 4.0 * (3 * B * L * dr + 2 * B * dr)
    vmem = 4.0 * (3 * L * bd + 2 * bd)
    return Cost(flops, hbm, vmem, cells)


# ---------------------------------------------------------------------------
# moe_gmm
# ---------------------------------------------------------------------------


def _gmm_defaults(shape: dict) -> dict:
    return {
        "block_c": _gmm.DEFAULT_BLOCK_C,
        "block_f": _gmm.DEFAULT_BLOCK_F,
        "block_d": _gmm.DEFAULT_BLOCK_D,
    }


def _gmm_make_args(shape: dict, dtype: str, seed: int) -> tuple:
    kx, kw = jax.random.split(jax.random.PRNGKey(seed), 2)
    E, C, D, F = shape["E"], shape["C"], shape["D"], shape["F"]
    scale = 1.0 / (D**0.5)
    x = jax.random.normal(kx, (E, C, D), jnp.dtype(dtype))
    w = (jax.random.normal(kw, (E, D, F), jnp.float32) * scale).astype(jnp.dtype(dtype))
    return x, w


def _gmm_call(shape: dict, args: tuple, config: dict, interpret: bool):
    x, w = args
    return _gmm.moe_gmm(
        x, w,
        block_c=config["block_c"], block_f=config["block_f"],
        block_d=config["block_d"], interpret=interpret,
    )


def _gmm_ref(shape: dict, args: tuple):
    return _ref.moe_gmm_ref(*args)


def _gmm_space(shape: dict) -> list:
    return [
        {"block_c": bc, "block_f": bf, "block_d": bd}
        for bc in _divisors(shape["C"], candidates=(32, 64, 128, 256))
        for bf in _divisors(shape["F"], candidates=(64, 128, 256, 512))
        for bd in _divisors(shape["D"], candidates=(128, 256, 512))
    ]


def _gmm_cost(shape: dict, config: dict, dtype: str) -> Cost:
    E, C, D, F = shape["E"], shape["C"], shape["D"], shape["F"]
    isz = _isz(dtype)
    bc = min(config["block_c"], C)
    bf = min(config["block_f"], F)
    bd = min(config["block_d"], D)
    nc, nf, nd = C // bc, F // bf, D // bd
    cells = E * nc * nf * nd
    flops = 2.0 * E * C * D * F
    # x tiles re-fetched per f-block, w tiles per c-block, y written per
    # d-block (interpret copies the out tile back every cell)
    hbm = isz * (nf * E * C * D + nc * E * D * F + nd * E * C * F)
    vmem = isz * (bc * bd + bd * bf + bc * bf) + 4 * bc * bf
    return Cost(flops, float(hbm), float(vmem), cells)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

KERNELS: dict = {
    k.name: k
    for k in (
        KernelDef(
            name="flash_attention",
            params=("block_q", "block_k"),
            defaults=_fa_defaults,
            make_args=_fa_make_args,
            call=_fa_call,
            ref=_fa_ref,
            space=_fa_space,
            cost=_fa_cost,
            tiny_shape={"B": 1, "H": 2, "KV": 1, "L": 128, "hd": 32, "causal": True, "window": None},
            smoke_shape={"B": 1, "H": 4, "KV": 2, "L": 256, "hd": 64, "causal": True, "window": None},
            full_shape={"B": 1, "H": 8, "KV": 2, "L": 512, "hd": 64, "causal": True, "window": None},
        ),
        KernelDef(
            name="selective_scan",
            params=("block_d",),
            defaults=_ss_defaults,
            make_args=_ss_make_args,
            call=_ss_call,
            ref=_ss_ref,
            space=_ss_space,
            cost=_ss_cost,
            tiny_shape={"B": 1, "chunk": 32, "di": 128, "N": 8},
            smoke_shape={"B": 2, "chunk": 64, "di": 256, "N": 16},
            full_shape={"B": 2, "chunk": 128, "di": 1024, "N": 16},
        ),
        KernelDef(
            name="rglru_scan",
            params=("block_d",),
            defaults=_rg_defaults,
            make_args=_rg_make_args,
            call=_rg_call,
            ref=_rg_ref,
            space=_rg_space,
            cost=_rg_cost,
            tiny_shape={"B": 1, "L": 64, "dr": 128},
            smoke_shape={"B": 2, "L": 128, "dr": 512},
            full_shape={"B": 2, "L": 256, "dr": 1024},
        ),
        KernelDef(
            name="moe_gmm",
            params=("block_c", "block_f", "block_d"),
            defaults=_gmm_defaults,
            make_args=_gmm_make_args,
            call=_gmm_call,
            ref=_gmm_ref,
            space=_gmm_space,
            cost=_gmm_cost,
            tiny_shape={"E": 2, "C": 64, "D": 128, "F": 128},
            smoke_shape={"E": 4, "C": 128, "D": 256, "F": 512},
            full_shape={"E": 8, "C": 256, "D": 512, "F": 512},
        ),
    )
}


def get_kernel(name: str) -> KernelDef:
    kdef = KERNELS.get(name)
    if kdef is None:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(KERNELS)}"
        )
    return kdef


def config_sig(config: dict) -> str:
    """Canonical ``k=v`` string of a block config (event attrs, payloads)."""
    return ",".join(f"{k}={config[k]}" for k in sorted(config))


def interpret_default() -> bool:
    """Interpret mode everywhere but a real TPU backend (same rule as
    kernels/ops.py)."""
    return jax.default_backend() != "tpu"


def max_abs_err(a, b) -> float:
    """Max elementwise |a - b| across a pytree pair (parity gate metric)."""
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(leaves_a, leaves_b)
    )


__all__ = [
    "Cost",
    "KernelDef",
    "KERNELS",
    "get_kernel",
    "shape_sig",
    "config_sig",
    "interpret_default",
    "max_abs_err",
]
