"""Grouped (per-expert) matmul Pallas TPU kernel for MoE FFN compute.

Computes y[e] = x[e] @ w[e] for capacity-dispatched expert inputs
x (E, C, D) and stacked expert weights w (E, D, F) - the compute hot-spot of
the MoE layer once tokens have been dispatched.

TPU mapping: grid = (E, C blocks, F blocks, D blocks) with an fp32 VMEM
accumulator carried across the innermost (sequential) D dimension; every
block dim is a multiple of 128 so the (block_c x block_d) @ (block_d x
block_f) product runs on the MXU at full tile occupancy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 128
DEFAULT_BLOCK_F = 256
DEFAULT_BLOCK_D = 512


def _gmm_kernel(x_ref, w_ref, y_ref, acc_scr, *, n_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0]  # (block_c, block_d)
    w = w_ref[0]  # (block_d, block_f)
    acc_scr[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(di == n_d_blocks - 1)
    def _finalize():
        y_ref[0] = acc_scr[...].astype(y_ref.dtype)


def moe_gmm(
    x: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    *,
    block_c: int = DEFAULT_BLOCK_C,
    block_f: int = DEFAULT_BLOCK_F,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = x.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    nc, nf, nd = C // block_c, F // block_f, D // block_d

    kernel = functools.partial(_gmm_kernel, n_d_blocks=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, block_d, block_f), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
