"""RG-LRU linear-recurrence Pallas TPU kernel (RecurrentGemma).

    h_t = exp(log_a_t) * h_{t-1} + gx_t          (elementwise over d_rnn)

Grid = (batch, d_rnn blocks); the (block_d,) state lives in registers/VMEM
and the kernel walks the full sequence with a fori_loop.  The sequential
walk is the TPU analogue of Griffin's scan (the recurrence is memory-bound:
one load of log_a/gx and one store of y per step; block_d=512 lanes keeps
the VPU busy).  Gates/log_a are precomputed outside (they are dense matmuls
that XLA already maps to the MXU well).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _rglru_kernel(loga_ref, gx_ref, h0_ref, y_ref, h_ref, *, seq: int):
    h = h0_ref[0].astype(jnp.float32)  # (block_d,)

    def step(t, h):
        a_t = jnp.exp(loga_ref[0, t, :].astype(jnp.float32))
        h = a_t * h + gx_ref[0, t, :].astype(jnp.float32)
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq, step, h)
    h_ref[0] = h.astype(h_ref.dtype)


def rglru_scan(
    log_a: jax.Array,  # (B, L, dr) fp32
    gx: jax.Array,  # (B, L, dr) fp32
    h0: jax.Array,  # (B, dr) fp32 (zeros if None)
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
):
    """Returns (y (B, L, dr) fp32, h_last (B, dr) fp32)."""
    B, L, dr = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, dr), jnp.float32)
    block_d = min(block_d, dr)
    assert dr % block_d == 0, (dr, block_d)

    kernel = functools.partial(_rglru_kernel, seq=L)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, dr // block_d),
        in_specs=[
            pl.BlockSpec((1, L, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, L, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, block_d), lambda b, d: (b, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, block_d), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, block_d), lambda b, d: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, dr), jnp.float32),
            jax.ShapeDtypeStruct((B, dr), jnp.float32),
        ],
        interpret=interpret,
    )(log_a, gx, h0)
    return y, h_last
