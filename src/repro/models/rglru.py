"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local (sliding
window) MQA attention in a repeating (rec, rec, attn) pattern.

The RG-LRU gate matrices are block-diagonal (Griffin §2.3) with one block per
tensor-parallel shard, so gate matmuls are fully local under TP.  The
recurrence is diagonal, evaluated with ``lax.associative_scan`` over the full
sequence at train time (O(L) memory in (B, L, d_rnn)) and as an O(1)-state
step at decode time — which is why recurrentgemma runs the long_500k cell
with a bounded (window-sized) attention cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    apply_rope,
    causal_conv1d,
    conv1d_step,
    embed_tokens,
    geglu,
    rms_norm,
    scan_layers,
    scan_layers_carry,
)
from repro.models.spec import ParamSpec, dense, stacked
from repro.models.transformer import _head, attn_specs, write_cache
from repro.parallel.sharding import shard_x

N_GATE_BLOCKS = 16  # block-diagonal gate blocks == model-axis size
LRU_C = 8.0


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _gate_blocks(cfg: ArchConfig) -> int:
    nb = N_GATE_BLOCKS
    while cfg.rnn_dim % nb:
        nb //= 2
    return max(nb, 1)


def rec_specs(cfg: ArchConfig, dt: str) -> dict:
    D, dr, K = cfg.d_model, cfg.rnn_dim, 4
    nb = _gate_blocks(cfg)
    bd = dr // nb
    return {
        "ln": ParamSpec((D,), ("norm",), dt, "zeros"),
        "w_x": dense((D, dr), ("embed", "rnn"), dt),
        "w_gate": dense((D, dr), ("embed", "rnn"), dt),
        "conv_w": dense((dr, K), ("rnn", "conv"), dt, scale=0.5),
        "conv_b": ParamSpec((dr,), ("rnn",), dt, "zeros"),
        "w_rec_gate": dense((nb, bd, bd), ("rnn", None, None), dt),
        "b_rec_gate": ParamSpec((dr,), ("rnn",), dt, "zeros"),
        "w_in_gate": dense((nb, bd, bd), ("rnn", None, None), dt),
        "b_in_gate": ParamSpec((dr,), ("rnn",), dt, "zeros"),
        "lam": ParamSpec((dr,), ("rnn",), "float32", "rglru_lambda"),
        "w_out": dense((dr, D), ("rnn", "embed"), dt),
        "ln_mlp": ParamSpec((D,), ("norm",), dt, "zeros"),
        "mlp": {
            "w_gate": dense((D, cfg.d_ff), ("embed", "mlp"), dt),
            "w_up": dense((D, cfg.d_ff), ("embed", "mlp"), dt),
            "w_down": dense((cfg.d_ff, D), ("mlp", "embed"), dt),
        },
    }


def attn_block_specs(cfg: ArchConfig, dt: str) -> dict:
    return {
        "ln": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "attn": attn_specs(cfg, dt),
        "ln_mlp": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "mlp": {
            "w_gate": dense((cfg.d_model, cfg.d_ff), ("embed", "mlp"), dt),
            "w_up": dense((cfg.d_model, cfg.d_ff), ("embed", "mlp"), dt),
            "w_down": dense((cfg.d_ff, cfg.d_model), ("mlp", "embed"), dt),
        },
    }


def _layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_superblocks, n_tail_rec_layers)."""
    p = len(cfg.block_pattern or ("rec", "rec", "attn"))
    return cfg.n_layers // p, cfg.n_layers % p


def specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    n_super, n_tail = _layout(cfg)
    tree: dict[str, Any] = {
        "embed": dense((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), dt, scale=0.02),
        "superblocks": stacked(
            n_super,
            {
                "rec1": rec_specs(cfg, dt),
                "rec2": rec_specs(cfg, dt),
                "attn": attn_block_specs(cfg, dt),
            },
        ),
        "ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "lm_head": dense((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt),
    }
    if n_tail:
        tree["tail"] = stacked(n_tail, rec_specs(cfg, dt))
    return tree


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _block_diag(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """u (..., dr) @ block-diagonal w (nb, bd, bd) + b."""
    nb, bd, _ = w.shape
    ub = u.reshape(u.shape[:-1] + (nb, bd))
    out = jnp.einsum("...kd,kde->...ke", ub, w)
    return out.reshape(u.shape) + b


def _lru_gates(p: dict, u: jax.Array):
    """Returns (log_a (..., dr) f32, gated_input (..., dr) f32)."""
    r = jax.nn.sigmoid(_block_diag(u, p["w_rec_gate"], p["b_rec_gate"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, p["w_in_gate"], p["b_in_gate"]).astype(jnp.float32))
    log_a = -LRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))  # sqrt(1 - a^2), stable
    return log_a, beta * i * u.astype(jnp.float32)


def rglru_seq(p: dict, u: jax.Array, h0=None, use_pallas: bool = False):
    """RG-LRU over a full sequence.  u (B, L, dr) -> (y, h_last (B, dr) f32)."""
    log_a, gx = _lru_gates(p, u)
    if use_pallas:
        from repro.kernels import ops as kops

        y, h_last = kops.rglru_scan(log_a, gx, h0)
        return y.astype(u.dtype), h_last
    a = jnp.exp(log_a)
    if h0 is not None:
        gx = gx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_step(p: dict, u_t: jax.Array, h: jax.Array):
    """One decode step.  u_t (B, dr); h (B, dr) f32."""
    log_a, gx = _lru_gates(p, u_t)
    h_new = jnp.exp(log_a) * h + gx
    return h_new.astype(u_t.dtype), h_new


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def rec_block(cfg: ArchConfig, x, p, h0=None):
    """Full-seq recurrent block.  Returns (x, (h_last, conv_tail))."""
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    u_pre = jnp.einsum("bld,de->ble", h_in, p["w_x"])
    g = jax.nn.gelu(jnp.einsum("bld,de->ble", h_in, p["w_gate"]))
    u_pre = shard_x(u_pre, "batch", "seq", "rnn_act")
    u = causal_conv1d(u_pre, p["conv_w"], p["conv_b"])
    y, h_last = rglru_seq(p, u, h0)
    out = jnp.einsum("ble,ed->bld", y * g, p["w_out"])
    x = x + out
    h2 = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + geglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    conv_tail = u_pre[:, -3:, :]
    return shard_x(x, "batch", "seq", "embed_act"), (h_last, conv_tail)


def attn_block(cfg: ArchConfig, x, p, pos):
    """Local-window MQA block.  Returns (x, (k, v))."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    a = attn.attention(q, k, v, causal=True, window=cfg.local_window)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + geglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return shard_x(x, "batch", "seq", "embed_act"), (k, v)


# ---------------------------------------------------------------------------
# Model passes
# ---------------------------------------------------------------------------


def backbone(cfg: ArchConfig, params, tokens, extras=None):
    B, L = tokens.shape
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def super_body(c, p):
        c, _ = rec_block(cfg, c, p["rec1"])
        c, _ = rec_block(cfg, c, p["rec2"])
        c, _ = attn_block(cfg, c, p["attn"], pos)
        return c

    x = scan_layers(super_body, x, params["superblocks"], remat=cfg.remat)
    if "tail" in params:
        x = scan_layers(
            lambda c, p: rec_block(cfg, c, p)[0], x, params["tail"], remat=cfg.remat
        )
    return x


def forward(cfg: ArchConfig, params, tokens, extras=None):
    return _head(cfg, params, backbone(cfg, params, tokens, extras))


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    """LRU states + conv windows + ring-buffer attention caches."""
    n_super, n_tail = _layout(cfg)
    W = min(cfg.local_window, cache_len)
    dr, KV, hd = cfg.rnn_dim, cfg.n_kv_heads, cfg.hd
    ct = cfg.compute_dtype
    sb = {
        "rec1_h": ParamSpec((n_super, batch, dr), ("layers", "cache_batch", "rnn_act"), "float32", "zeros"),
        "rec1_conv": ParamSpec((n_super, batch, 3, dr), ("layers", "cache_batch", None, "rnn_act"), ct, "zeros"),
        "rec2_h": ParamSpec((n_super, batch, dr), ("layers", "cache_batch", "rnn_act"), "float32", "zeros"),
        "rec2_conv": ParamSpec((n_super, batch, 3, dr), ("layers", "cache_batch", None, "rnn_act"), ct, "zeros"),
        "k": ParamSpec(
            (n_super, batch, W, KV, hd), ("layers", "cache_batch", "cache_seq", "kv_heads_act", None), ct, "zeros"
        ),
        "v": ParamSpec(
            (n_super, batch, W, KV, hd), ("layers", "cache_batch", "cache_seq", "kv_heads_act", None), ct, "zeros"
        ),
    }
    tree = {"superblocks": sb}
    if n_tail:
        tree["tail"] = {
            "h": ParamSpec((n_tail, batch, dr), ("layers", "cache_batch", "rnn_act"), "float32", "zeros"),
            "conv": ParamSpec((n_tail, batch, 3, dr), ("layers", "cache_batch", None, "rnn_act"), ct, "zeros"),
        }
    return tree


def ring_positions(pos: jax.Array, window: int) -> jax.Array:
    """Absolute position stored at each ring-buffer slot given current pos (B,).

    Slot j holds the largest p <= pos with p % W == j (negative => empty).
    """
    j = jnp.arange(window)[None, :]
    p = pos[:, None] - ((pos[:, None] - j) % window)
    return p


def _rec_step(cfg, x, p, h, conv_state):
    """x (B, 1, D) decode step of a recurrent block."""
    h_in = rms_norm(x[:, 0], p["ln"], cfg.norm_eps)
    u_pre = h_in @ p["w_x"]
    g = jax.nn.gelu(h_in @ p["w_gate"])
    u, conv_state = conv1d_step(u_pre, conv_state, p["conv_w"], p["conv_b"])
    y, h_new = rglru_step(p, u, h)
    out = (y * g) @ p["w_out"]
    x = x + out[:, None, :]
    h2 = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + geglu(h2, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x, h_new, conv_state


def _attn_step(cfg, x, p, k_cache, v_cache, pos):
    W = k_cache.shape[1]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k_t, v_t = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_t = apply_rope(k_t, pos[:, None], cfg.rope_theta)
    ck, cv = write_cache(k_cache, v_cache, k_t, v_t, pos % W)
    cpos = ring_positions(pos, W)
    a = attn.decode_attention(q, ck, cv, pos, cache_positions=cpos, window=cfg.local_window)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + geglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x, ck, cv


def prefill(cfg: ArchConfig, params, tokens, extras=None, cache_len=None):
    B, L = tokens.shape
    cache_len = cache_len or L
    W = min(cfg.local_window, cache_len)
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def ring_from_seq(k):  # (B, L, KV, hd) -> ring (B, W, KV, hd)
        if L >= W:
            tail = k[:, -W:]
            # place token t at slot t % W
            slots = (jnp.arange(L - W, L)) % W
            ring = jnp.zeros((B, W) + k.shape[2:], k.dtype)
            return ring.at[:, slots].set(tail)
        pad = ((0, 0), (0, W - L), (0, 0), (0, 0))
        return jnp.pad(k, pad)

    def super_body(c, p):
        c, (h1, cv1) = rec_block(cfg, c, p["rec1"])
        c, (h2, cv2) = rec_block(cfg, c, p["rec2"])
        c, (k, v) = attn_block(cfg, c, p["attn"], pos)
        cache = {
            "rec1_h": h1, "rec1_conv": cv1,
            "rec2_h": h2, "rec2_conv": cv2,
            "k": ring_from_seq(k), "v": ring_from_seq(v),
        }
        return c, cache

    x, sb_cache = scan_layers_carry(super_body, x, params["superblocks"], remat=cfg.remat)
    cache = {"superblocks": sb_cache}
    if "tail" in params:
        def tail_body(c, p):
            c, (h, cv) = rec_block(cfg, c, p)
            return c, {"h": h, "conv": cv}

        x, tail_cache = scan_layers_carry(tail_body, x, params["tail"], remat=cfg.remat)
        cache["tail"] = tail_cache
    return _head(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, extras=None):
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def super_body(c, scanned):
        p, lc = scanned
        c, h1, cv1 = _rec_step(cfg, c, p["rec1"], lc["rec1_h"], lc["rec1_conv"])
        c, h2, cv2 = _rec_step(cfg, c, p["rec2"], lc["rec2_h"], lc["rec2_conv"])
        c, ck, cvv = _attn_step(cfg, c, p["attn"], lc["k"], lc["v"], pos)
        return c, {
            "rec1_h": h1, "rec1_conv": cv1,
            "rec2_h": h2, "rec2_conv": cv2,
            "k": ck, "v": cvv,
        }

    x, sb_cache = scan_layers_carry(
        super_body, x, (params["superblocks"], cache["superblocks"]), remat="none"
    )
    new_cache = {"superblocks": sb_cache}
    if "tail" in params:
        def tail_body(c, scanned):
            p, lc = scanned
            c, h, cv = _rec_step(cfg, c, p, lc["h"], lc["conv"])
            return c, {"h": h, "conv": cv}

        x, tail_cache = scan_layers_carry(
            tail_body, x, (params["tail"], cache["tail"]), remat="none"
        )
        new_cache["tail"] = tail_cache
    return _head(cfg, params, x), new_cache
