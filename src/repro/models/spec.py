"""Parameter specs: shapes + logical axes, used for init AND abstract lowering.

Every model family declares its parameters as a pytree of ``ParamSpec``.  From
the same spec tree we derive:
  * real initialized arrays (smoke tests, examples, training),
  * ``jax.ShapeDtypeStruct`` stand-ins (multi-pod dry-run - no allocation),
  * ``PartitionSpec`` shardings (via ``repro.parallel.sharding`` rules).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    dtype: str = "bfloat16"
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02  # stddev for normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def is_spec_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_sds(specs):
    """Spec tree -> ShapeDtypeStruct tree (for .lower())."""
    return jax.tree.map(lambda s: s.sds(), specs, is_leaf=is_spec_leaf)


def tree_size(specs) -> int:
    return sum(s.size for s in jax.tree.leaves(specs, is_leaf=is_spec_leaf))


def init_params(specs, rng: jax.Array):
    """Materialize a spec tree into initialized arrays (host-side, per-leaf rng)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec_leaf)
    rngs = jax.random.split(rng, len(leaves))

    def one(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        if spec.init == "normal":
            return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(spec.dtype)
        if spec.init == "ssm_a_log":
            # mamba1: A initialised to -[1..N] broadcast over d_inner; stored as log
            n = spec.shape[-1]
            a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), spec.shape[:-1] + (1,))
            return jnp.log(a).astype(spec.dtype)
        if spec.init == "ssm_dt_bias":
            # softplus^-1 of dt ~ U(1e-3, 1e-1)
            u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(spec.dtype)
        if spec.init == "rglru_lambda":
            # a = sigmoid(Lambda)^(c) with a in [0.9, 0.999]: Lambda = logit(a^(1/c))
            c = 8.0
            a = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
            ac = a ** (1.0 / c)
            return jnp.log(ac / (1 - ac)).astype(spec.dtype)
        raise ValueError(spec.init)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, rngs)])


# ---------------------------------------------------------------------------
# Spec construction helpers
# ---------------------------------------------------------------------------


def dense(shape, axes, dtype, scale=None, init="normal") -> ParamSpec:
    if scale is None:
        # lecun-ish: 1/sqrt(fan_in) with fan_in = prod of all but last axis
        fan_in = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale)


def stacked(n_layers: int, spec_tree):
    """Prefix every spec in the tree with a leading ('layers', n) axis."""

    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n_layers,) + s.shape, ("layers",) + s.axes, s.dtype, s.init, s.scale)

    return jax.tree.map(one, spec_tree, is_leaf=is_spec_leaf)
