"""Mamba1 selective-SSM stack (falcon-mamba-7b) — attention-free.

Training uses a chunked linear-recurrence: an outer ``lax.scan`` over sequence
chunks carries the (B, d_inner, N) state; within a chunk the diagonal
recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
``lax.associative_scan``.  The (B, chunk, d_inner, N) discretized tensors only
ever exist per-chunk (never for the full sequence).  Decode is a single-token
recurrence with O(1) state — this is why falcon-mamba runs the long_500k cell.

The TPU hot-spot (per-chunk scan) has a Pallas kernel in
kernels/selective_scan.py; this module is the XLA lowering / oracle path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import causal_conv1d, conv1d_step, embed_tokens, rms_norm, scan_layers, scan_layers_carry
from repro.models.spec import ParamSpec, dense, stacked
from repro.models.transformer import _head
from repro.parallel.sharding import shard_x


def block_specs(cfg: ArchConfig, dt: str) -> dict:
    D, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    return {
        "ln": ParamSpec((D,), ("norm",), dt, "zeros"),
        "w_in_x": dense((D, di), ("embed", "ssm_inner"), dt),
        "w_in_z": dense((D, di), ("embed", "ssm_inner"), dt),
        "conv_w": dense((di, K), ("ssm_inner", "conv"), dt, scale=0.5),
        "conv_b": ParamSpec((di,), ("ssm_inner",), dt, "zeros"),
        "w_x_dt": dense((di, R), ("ssm_inner", "dt_rank"), dt),
        "w_x_b": dense((di, N), ("ssm_inner", "ssm_state"), dt),
        "w_x_c": dense((di, N), ("ssm_inner", "ssm_state"), dt),
        "w_dt": dense((R, di), ("dt_rank", "ssm_inner"), dt),
        "b_dt": ParamSpec((di,), ("ssm_inner",), "float32", "ssm_dt_bias"),
        "a_log": ParamSpec((di, N), ("ssm_inner", "ssm_state"), "float32", "ssm_a_log"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), "float32", "ones"),
        "w_out": dense((di, D), ("ssm_inner", "embed"), dt),
    }


def specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    tree: dict[str, Any] = {
        "embed": dense((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), dt, scale=0.02),
        "blocks": stacked(cfg.n_layers, block_specs(cfg, dt)),
        "ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = dense((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt)
    return tree


# ---------------------------------------------------------------------------
# Selective scan (chunked)
# ---------------------------------------------------------------------------


def _ssm_inputs(cfg: ArchConfig, p: dict, xb: jax.Array):
    """xb (B, L, di) post-conv -> dt (B,L,di) f32, Bm/Cm (B,L,N) f32."""
    dt_low = jnp.einsum("bld,dr->blr", xb, p["w_x_dt"])
    dt = jnp.einsum("blr,rd->bld", dt_low, p["w_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["b_dt"].astype(jnp.float32))
    bm = jnp.einsum("bld,dn->bln", xb, p["w_x_b"]).astype(jnp.float32)
    cm = jnp.einsum("bld,dn->bln", xb, p["w_x_c"]).astype(jnp.float32)
    return dt, bm, cm


def selective_scan_chunked(cfg: ArchConfig, p, xb, dt, bm, cm, h0=None, use_pallas: bool = False):
    """Evaluate the selective scan over the full sequence in chunks.

    xb (B, L, di); dt (B, L, di); bm, cm (B, L, N).
    Returns (y (B, L, di), h_last (B, di, N) float32).
    """
    B, L, di = xb.shape
    N = bm.shape[-1]
    ck = min(cfg.ssm_chunk, L)
    while L % ck:  # fall back to the largest divisor of L (odd test lengths)
        ck -= 1
    n_chunks = L // ck
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)

    def to_chunks(t):
        return t.reshape(B, n_chunks, ck, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(xb), to_chunks(dt), to_chunks(bm), to_chunks(cm))
    h_init = jnp.zeros((B, di, N), jnp.float32) if h0 is None else h0

    if use_pallas:
        from repro.kernels import ops as kops

        def chunk_body(h, chunk):
            xc, dtc, bc, cc = chunk
            y, h_new = kops.selective_scan_chunk(xc, dtc, bc, cc, a, h)
            return h_new, y
    elif cfg.ssm_scan == "seq":

        def chunk_body(h, chunk):
            # §Perf strip-mined path: walk the chunk sequentially (unroll=16)
            # so the (B, ck, di, N) discretized tensors NEVER materialize in
            # HBM - only the (B, di, N) state is carried.  ~10x less traffic
            # than the associative-scan tree at the cost of serial latency
            # the VPU hides (the recurrence is elementwise).
            xc, dtc, bc, cc = chunk

            def step(h, xs):
                x_t, dt_t, b_t, c_t = xs  # (B, di), (B, di), (B, N), (B, N)
                da = jnp.exp(dt_t[..., None] * a)
                h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
                y_t = jnp.einsum("bdn,bn->bd", h, c_t)
                return h, y_t

            from repro.models.layers import scan_unroll

            ts = jax.tree.map(lambda t: t.swapaxes(0, 1), (xc, dtc, bc, cc))
            h, ys = jax.lax.scan(step, h, ts, unroll=True if scan_unroll() else 16)
            return h, ys.swapaxes(0, 1)
    else:

        def chunk_body(h, chunk):
            xc, dtc, bc, cc = chunk  # (B, ck, di), (B, ck, di), (B, ck, N) x2
            da = jnp.exp(dtc[..., None] * a)  # (B, ck, di, N)
            db = (dtc * xc.astype(jnp.float32))[..., None] * bc[:, :, None, :]

            def combine(u, v):
                a1, b1 = u
                a2, b2 = v
                return a2 * a1, a2 * b1 + b2

            cum_a, cum_b = jax.lax.associative_scan(combine, (da, db), axis=1)
            hs = cum_b + cum_a * h[:, None]  # (B, ck, di, N)
            y = jnp.einsum("bldn,bln->bld", hs, cc)
            return hs[:, -1], y

    from repro.models.layers import scan_unroll

    h_last, ys = jax.lax.scan(chunk_body, h_init, xs, unroll=scan_unroll())
    y = ys.swapaxes(0, 1).reshape(B, L, di)
    return y, h_last


def mamba_block(cfg: ArchConfig, x, p, *, use_pallas: bool = False):
    """One Mamba block (full-sequence). x (B, L, D)."""
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = jnp.einsum("bld,de->ble", h_in, p["w_in_x"])
    z = jnp.einsum("bld,de->ble", h_in, p["w_in_z"])
    xb = shard_x(xb, "batch", "seq", "ssm_inner_act")
    xb = jax.nn.silu(causal_conv1d(xb, p["conv_w"], p["conv_b"]))
    dt, bm, cm = _ssm_inputs(cfg, p, xb)
    y, _ = selective_scan_chunked(cfg, p, xb, dt, bm, cm, use_pallas=use_pallas)
    y = (y + p["d_skip"].astype(jnp.float32) * xb.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, p["w_out"])
    return shard_x(x + out, "batch", "seq", "embed_act")


def backbone(cfg: ArchConfig, params, tokens, extras=None):
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    return scan_layers(
        lambda c, p: mamba_block(cfg, c, p), x, params["blocks"], remat=cfg.remat
    )


def forward(cfg: ArchConfig, params, tokens, extras=None):
    return _head(cfg, params, backbone(cfg, params, tokens, extras))


# ---------------------------------------------------------------------------
# Decode (recurrent state; O(1) in sequence length)
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    """Recurrent state: SSM state + conv window per layer.  cache_len unused."""
    di, N, K, L = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv, cfg.n_layers
    return {
        "layers": {
            "h": ParamSpec((L, batch, di, N), ("layers", "cache_batch", "ssm_inner_act", None), "float32", "zeros"),
            "conv": ParamSpec(
                (L, batch, K - 1, di), ("layers", "cache_batch", None, "ssm_inner_act"), cfg.compute_dtype, "zeros"
            ),
        }
    }


def mamba_decode_block(cfg: ArchConfig, x, p, layer_cache):
    """x (B, 1, D) one token."""
    h_in = rms_norm(x[:, 0], p["ln"], cfg.norm_eps)  # (B, D)
    xb = h_in @ p["w_in_x"]
    z = h_in @ p["w_in_z"]
    xb, conv_state = conv1d_step(xb, layer_cache["conv"], p["conv_w"], p["conv_b"])
    xb = jax.nn.silu(xb)
    dt = jax.nn.softplus(
        ((xb @ p["w_x_dt"]) @ p["w_dt"]).astype(jnp.float32) + p["b_dt"].astype(jnp.float32)
    )  # (B, di)
    bm = (xb @ p["w_x_b"]).astype(jnp.float32)  # (B, N)
    cm = (xb @ p["w_x_c"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)
    da = jnp.exp(dt[..., None] * a)  # (B, di, N)
    db = (dt * xb.astype(jnp.float32))[..., None] * bm[:, None, :]
    h = da * layer_cache["h"] + db  # (B, di, N)
    y = jnp.einsum("bdn,bn->bd", h, cm)
    y = y + p["d_skip"].astype(jnp.float32) * xb.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return x + y[:, None, :], {"h": h, "conv": conv_state}


def prefill(cfg: ArchConfig, params, tokens, extras=None, cache_len=None):
    """Full forward, returning the recurrent state after the last token."""
    B, L = tokens.shape
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def body(c, p):
        h_in = rms_norm(c, p["ln"], cfg.norm_eps)
        xb_pre = jnp.einsum("bld,de->ble", h_in, p["w_in_x"])
        z = jnp.einsum("bld,de->ble", h_in, p["w_in_z"])
        xb = jax.nn.silu(causal_conv1d(xb_pre, p["conv_w"], p["conv_b"]))
        dt, bm, cm = _ssm_inputs(cfg, p, xb)
        y, h_last = selective_scan_chunked(cfg, p, xb, dt, bm, cm)
        y = (y + p["d_skip"].astype(jnp.float32) * xb.astype(jnp.float32)).astype(c.dtype)
        y = y * jax.nn.silu(z)
        out = jnp.einsum("ble,ed->bld", y, p["w_out"])
        conv_tail = xb_pre[:, -(cfg.ssm_conv - 1):, :]  # last K-1 *pre-conv* inputs
        return c + out, (h_last, conv_tail)

    x, (h, conv) = scan_layers_carry(body, x, params["blocks"], remat=cfg.remat)
    logits = _head(cfg, params, x[:, -1:, :])
    return logits, {"layers": {"h": h, "conv": conv}}


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, extras=None):
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    x, new_cache = scan_layers_carry(
        lambda c, scanned: mamba_decode_block(cfg, c, scanned[0], scanned[1]),
        x,
        (params["blocks"], cache["layers"]),
        remat="none",
    )
    return _head(cfg, params, x), {"layers": new_cache}
