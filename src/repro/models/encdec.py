"""Encoder-decoder transformer backbone (seamless-m4t-medium).

The multimodal frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, enc_len, d_model).  The backbone is
a bidirectional encoder + causal decoder with cross-attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import apply_rope, embed_tokens, rms_norm, scan_layers, scan_layers_carry, swiglu
from repro.models.spec import ParamSpec, dense, stacked
from repro.models.transformer import _head, attn_specs, mlp_specs, write_cache
from repro.parallel.sharding import shard_x


def enc_block_specs(cfg: ArchConfig, dt: str) -> dict:
    return {
        "ln_attn": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "attn": attn_specs(cfg, dt),
        "ln_mlp": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "mlp": mlp_specs(cfg, dt),
    }


def dec_block_specs(cfg: ArchConfig, dt: str) -> dict:
    return {
        "ln_attn": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "attn": attn_specs(cfg, dt),
        "ln_cross": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "cross": attn_specs(cfg, dt),
        "ln_mlp": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "mlp": mlp_specs(cfg, dt),
    }


def specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    return {
        "embed": dense((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), dt, scale=0.02),
        "enc_blocks": stacked(cfg.n_enc_layers, enc_block_specs(cfg, dt)),
        "enc_ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "dec_blocks": stacked(cfg.n_layers, dec_block_specs(cfg, dt)),
        "ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "lm_head": dense((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(cfg: ArchConfig, params, frames: jax.Array) -> jax.Array:
    """frames (B, Le, D) stub embeddings -> encoder output (B, Le, D)."""
    x = frames.astype(cfg.compute_dtype)
    x = shard_x(x, "batch", "seq", "embed_act")
    Le = x.shape[1]
    pos = jnp.arange(Le)[None, :]

    def body(c, p):
        h = rms_norm(c, p["ln_attn"], cfg.norm_eps)
        q, k, v = attn.qkv_proj(h, p["attn"])
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        a = attn.attention(q, k, v, causal=False)
        c = c + attn.out_proj(a, p["attn"]["wo"])
        h = rms_norm(c, p["ln_mlp"], cfg.norm_eps)
        c = c + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        return shard_x(c, "batch", "seq", "embed_act")

    x = scan_layers(body, x, params["enc_blocks"], remat=cfg.remat)
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _cross_attn(cfg, x, p, enc_out):
    h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
    q = jnp.einsum("bld,dhk->blhk", h, p["cross"]["wq"])
    k = jnp.einsum("bld,dhk->blhk", enc_out, p["cross"]["wk"])
    v = jnp.einsum("bld,dhk->blhk", enc_out, p["cross"]["wv"])
    a = attn.attention(q, k, v, causal=False)
    return x + attn.out_proj(a, p["cross"]["wo"])


def _cross_attn_cached(cfg, x, p, ck, cv):
    """Decode-time cross attention against precomputed encoder K/V."""
    h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
    q = jnp.einsum("bld,dhk->blhk", h, p["cross"]["wq"])
    Le = ck.shape[1]
    pos_full = jnp.full((x.shape[0],), Le - 1, jnp.int32)  # all enc positions valid
    a = attn.decode_attention(q, ck, cv, pos_full)
    return x + attn.out_proj(a, p["cross"]["wo"])


def dec_block(cfg, x, p, pos, enc_out):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    a = attn.attention(q, k, v, causal=True)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    x = _cross_attn(cfg, x, p, enc_out)
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return shard_x(x, "batch", "seq", "embed_act"), (k, v)


def backbone(cfg: ArchConfig, params, tokens, extras=None):
    """Decoder hidden states: extras["enc_frames"] (B, Le, D) stub embeddings."""
    enc_out = encode(cfg, params, extras["enc_frames"])
    B, L = tokens.shape
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]
    return scan_layers(
        lambda c, p: dec_block(cfg, c, p, pos, enc_out)[0],
        x,
        params["dec_blocks"],
        remat=cfg.remat,
    )


def forward(cfg: ArchConfig, params, tokens, extras=None):
    return _head(cfg, params, backbone(cfg, params, tokens, extras))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    KV, hd, L, Le = cfg.n_kv_heads, cfg.hd, cfg.n_layers, cfg.enc_len_serve
    ct = cfg.compute_dtype
    ax = ("layers", "cache_batch", "cache_seq", "kv_heads_act", None)
    return {
        "layers": {
            "k": ParamSpec((L, batch, cache_len, KV, hd), ax, ct, "zeros"),
            "v": ParamSpec((L, batch, cache_len, KV, hd), ax, ct, "zeros"),
            "cross_k": ParamSpec((L, batch, Le, KV, hd), ax, ct, "zeros"),
            "cross_v": ParamSpec((L, batch, Le, KV, hd), ax, ct, "zeros"),
        }
    }


def prefill(cfg: ArchConfig, params, tokens, extras=None, cache_len: Optional[int] = None):
    enc_out = encode(cfg, params, extras["enc_frames"])
    B, L = tokens.shape
    cache_len = cache_len or L
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def body(c, p):
        c, (k, v) = dec_block(cfg, c, p, pos, enc_out)
        xk = jnp.einsum("bld,dhk->blhk", enc_out, p["cross"]["wk"])
        xv = jnp.einsum("bld,dhk->blhk", enc_out, p["cross"]["wv"])
        return c, (k, v, xk, xv)

    x, (k, v, xk, xv) = scan_layers_carry(body, x, params["dec_blocks"], remat=cfg.remat)
    if cache_len > L:
        padw = ((0, 0), (0, 0), (0, cache_len - L), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    cache = {"layers": {"k": k, "v": v, "cross_k": xk, "cross_v": xv}}
    return _head(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, extras=None):
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def body(c, scanned):
        p, lc = scanned
        h = rms_norm(c, p["ln_attn"], cfg.norm_eps)
        q, k_t, v_t = attn.qkv_proj(h, p["attn"])
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_t = apply_rope(k_t, pos[:, None], cfg.rope_theta)
        ck, cv = write_cache(lc["k"], lc["v"], k_t, v_t, pos)
        a = attn.decode_attention(q, ck, cv, pos)
        c = c + attn.out_proj(a, p["attn"]["wo"])
        c = _cross_attn_cached(cfg, c, p, lc["cross_k"], lc["cross_v"])
        h = rms_norm(c, p["ln_mlp"], cfg.norm_eps)
        c = c + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        return c, {"k": ck, "v": cv, "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}

    x, new_layers = scan_layers_carry(
        body, x, (params["dec_blocks"], cache["layers"]), remat="none"
    )
    return _head(cfg, params, x), {"layers": new_layers}
