"""Mixture-of-Experts FFN with capacity-based grouped dispatch (Switch/MaxText
style) + optional parallel dense residual (arctic).

Tokens are processed in groups of ``moe_group_size``; each group computes a
local top-k dispatch with capacity C = ceil(g * k * cf / E).  Expert weights
are stacked (E, D, F) and sharded over the "model" axis when E divides the
axis (EP, arctic) or expert-internally (grok, 8 experts on a 16-way axis).
The dispatch einsums keep cost linear in tokens (quadratic only in the small
group size).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import apply_rope, embed_tokens, rms_norm, scan_layers, scan_layers_carry, swiglu
from repro.models.spec import ParamSpec, dense, stacked
from repro.models.transformer import (
    _head,
    attn_specs,
    cache_specs as dense_cache_specs,
    write_cache,
)
from repro.parallel.sharding import shard_x

AUX_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-3


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def moe_specs(cfg: ArchConfig, dt: str) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    tree = {
        "router": dense((D, E), ("embed", None), dt, scale=0.02),
        "w_gate": dense((E, D, F), ("experts", "embed", "mlp"), dt),
        "w_up": dense((E, D, F), ("experts", "embed", "mlp"), dt),
        "w_down": dense((E, F, D), ("experts", "mlp", "embed"), dt),
    }
    if cfg.moe_dense_residual:
        tree["dense"] = {
            "w_gate": dense((D, F), ("embed", "mlp"), dt),
            "w_up": dense((D, F), ("embed", "mlp"), dt),
            "w_down": dense((F, D), ("mlp", "embed"), dt),
        }
    return tree


def block_specs(cfg: ArchConfig, dt: str) -> dict:
    return {
        "ln_attn": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "attn": attn_specs(cfg, dt),
        "ln_mlp": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "moe": moe_specs(cfg, dt),
    }


def specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    return {
        "embed": dense((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), dt, scale=0.02),
        "blocks": stacked(cfg.n_layers, block_specs(cfg, dt)),
        "ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "lm_head": dense((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt),
    }


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def capacity(cfg: ArchConfig, group: int) -> int:
    return max(1, math.ceil(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts))


def route(cfg: ArchConfig, logits: jax.Array):
    """logits (G, g, E) -> (dispatch (G,g,E,C) bool-ish, combine (G,g,E,C), aux, z).

    First-choice slots get capacity priority over second choices (Switch).
    """
    G, g, E = logits.shape
    C = capacity(cfg, g)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_v, top_i = jax.lax.top_k(probs, cfg.top_k)  # (G, g, k)
    top_v = top_v / jnp.maximum(jnp.sum(top_v, -1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (G, g, k, E)
    # priority order: all 1st choices before any 2nd choice within the group
    oh = onehot.swapaxes(1, 2).reshape(G, cfg.top_k * g, E)
    pos = jnp.cumsum(oh, axis=1) - oh  # position of each request in its expert queue
    keep = (pos < C).astype(jnp.float32) * oh
    slot = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]  # (G, k*g, E, C)
    slot = slot.reshape(G, cfg.top_k, g, E, C).swapaxes(1, 2)  # (G, g, k, E, C)
    dispatch = jnp.sum(slot, axis=2)  # (G, g, E, C)
    combine = jnp.sum(slot * top_v[..., None, None], axis=2)  # (G, g, E, C)

    # load-balancing aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    frac = jnp.mean(onehot[:, :, 0, :], axis=1)  # first-choice fraction (G, E)
    mean_p = jnp.mean(probs, axis=1)  # (G, E)
    aux = E * jnp.mean(jnp.sum(frac * mean_p, axis=-1))
    z = jnp.mean(jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return dispatch, combine, aux, z


def moe_ffn(cfg: ArchConfig, x: jax.Array, p: dict):
    """x (B, L, D) -> (y (B, L, D), aux_metrics dict)."""
    B, L, D = x.shape
    T = B * L
    g = min(cfg.moe_group_size, T)
    while T % g:  # fall back to the largest divisor of T (odd test lengths)
        g -= 1
    G = T // g
    xg = x.reshape(G, g, D)
    xg = shard_x(xg, "group_act", None, None)

    logits = jnp.einsum("Ggd,de->Gge", xg, p["router"].astype(jnp.float32))
    dispatch, combine, aux, z = route(cfg, logits)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(jnp.float32)

    xe = jnp.einsum("Ggd,Ggec->Gecd", xg, dispatch)  # (G, E, C, D)
    xe = shard_x(xe, "group_act", "experts_act", None, None)
    h = jax.nn.silu(jnp.einsum("Gecd,edf->Gecf", xe, p["w_gate"])) * jnp.einsum(
        "Gecd,edf->Gecf", xe, p["w_up"]
    )
    h = shard_x(h, "group_act", "experts_act", None, "mlp_act")
    ye = jnp.einsum("Gecf,efd->Gecd", h, p["w_down"])  # (G, E, C, D)
    y = jnp.einsum("Gecd,Ggec->Ggd", ye.astype(jnp.float32), combine)
    y = y.reshape(B, L, D).astype(x.dtype)
    if "dense" in p:  # arctic: parallel dense residual MLP
        y = y + swiglu(x, p["dense"]["w_gate"], p["dense"]["w_up"], p["dense"]["w_down"])
    return shard_x(y, "batch", "seq", "embed_act"), {"aux_loss": aux, "z_loss": z}


# ---------------------------------------------------------------------------
# Blocks / model passes
# ---------------------------------------------------------------------------


def moe_block(cfg: ArchConfig, x, p, pos):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    a = attn.attention(q, k, v, causal=True)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    y, aux = moe_ffn(cfg, h, p["moe"])
    return x + y, aux


def forward(cfg: ArchConfig, params, tokens, extras=None):
    """Returns (logits, moe_metrics)."""
    B, L = tokens.shape
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def body(carry, p):
        x, aux_sum, z_sum = carry
        x, aux = moe_block(cfg, x, p, pos)
        return (x, aux_sum + aux["aux_loss"], z_sum + aux["z_loss"]), None

    (x, aux_sum, z_sum) = scan_layers(
        lambda c, p: body(c, p)[0], (x, 0.0, 0.0), params["blocks"], remat=cfg.remat
    )
    logits = _head(cfg, params, x)
    n = cfg.n_layers
    return logits, {"aux_loss": aux_sum / n, "z_loss": z_sum / n}


def aux_loss(metrics: dict) -> jax.Array:
    return AUX_LOSS_WEIGHT * metrics["aux_loss"] + Z_LOSS_WEIGHT * metrics["z_loss"]


cache_specs = dense_cache_specs


def _decode_block(cfg, x, p, layer_cache, pos):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k_t, v_t = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_t = apply_rope(k_t, pos[:, None], cfg.rope_theta)
    ck, cv = write_cache(layer_cache["k"], layer_cache["v"], k_t, v_t, pos)
    a = attn.decode_attention(q, ck, cv, pos)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    y, _ = moe_ffn(cfg, h, p["moe"])
    return x + y, {"k": ck, "v": cv}


def prefill(cfg: ArchConfig, params, tokens, extras=None, cache_len=None):
    B, L = tokens.shape
    cache_len = cache_len or L
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def body(c, p):
        h = rms_norm(c, p["ln_attn"], cfg.norm_eps)
        q, k, v = attn.qkv_proj(h, p["attn"])
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        a = attn.attention(q, k, v, causal=True)
        c = c + attn.out_proj(a, p["attn"]["wo"])
        h = rms_norm(c, p["ln_mlp"], cfg.norm_eps)
        y, _ = moe_ffn(cfg, h, p["moe"])
        return c + y, (k, v)

    x, (k, v) = scan_layers_carry(body, x, params["blocks"], remat=cfg.remat)
    if cache_len > L:
        padw = ((0, 0), (0, 0), (0, cache_len - L), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    return _head(cfg, params, x[:, -1:, :]), {"layers": {"k": k, "v": v}}


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, extras=None):
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    x, new_cache = scan_layers_carry(
        lambda c, scanned: _decode_block(cfg, c, scanned[0], scanned[1], pos),
        x,
        (params["blocks"], cache["layers"]),
        remat="none",
    )
    return _head(cfg, params, x), {"layers": new_cache}
