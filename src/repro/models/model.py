"""Unified model facade: one API over every architecture family.

    model = Model(cfg)
    params = model.init(rng)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, pos)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, moe, rglru, ssm, transformer, vision
from repro.models.spec import init_params, tree_sds, tree_size

_FAMILY = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "audio": encdec,
    "vlm": vision,
}


def _extras(batch: dict) -> Optional[dict]:
    ex = {k: v for k, v in batch.items() if k in ("enc_frames", "img_embeds")}
    return ex or None


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.mod = _FAMILY[cfg.family]

    # -- parameters ----------------------------------------------------
    def specs(self):
        return self.mod.specs(self.cfg)

    def init(self, rng: jax.Array):
        return init_params(self.specs(), rng)

    def abstract_params(self):
        return tree_sds(self.specs())

    def param_count(self) -> int:
        return tree_size(self.specs())

    # -- training ------------------------------------------------------
    def logits(self, params, batch: dict) -> jax.Array:
        out = self.mod.forward(self.cfg, params, batch["tokens"], _extras(batch))
        if isinstance(out, tuple):  # moe returns (logits, aux)
            return out[0]
        return out

    def loss(self, params, batch: dict):
        """Next-token cross entropy (+ MoE aux losses).  Returns (loss, metrics)."""
        if self.cfg.logit_chunk and self.cfg.family in ("dense", "ssm", "hybrid", "vlm", "audio"):
            return self._loss_chunked_head(params, batch)
        out = self.mod.forward(self.cfg, params, batch["tokens"], _extras(batch))
        moe_metrics = None
        if isinstance(out, tuple):
            logits, moe_metrics = out
        else:
            logits = out
        ce, metrics = cross_entropy(logits, batch["labels"])
        loss = ce
        if moe_metrics is not None:
            loss = loss + moe.aux_loss(moe_metrics)
            metrics.update({k: v for k, v in moe_metrics.items()})
        metrics["loss"] = loss
        return loss, metrics

    def _loss_chunked_head(self, params, batch: dict):
        """§Perf: chunked LM head + CE - the (B, L, V) fp32 logits tensor is
        never materialized; the head matmul + logsumexp run per sequence
        chunk under jax.checkpoint (recomputed in backward).  Cuts the
        dominant head HBM traffic for 128k-vocab models ~8x at logit_chunk
        = seq/8."""
        from repro.models.transformer import _head

        cfg = self.cfg
        hidden = self.mod.backbone(cfg, params, batch["tokens"], _extras(batch))
        B, L, D = hidden.shape
        ck = min(cfg.logit_chunk, L)
        while L % ck:
            ck -= 1
        n = L // ck
        hc = hidden.reshape(B, n, ck, D).swapaxes(0, 1)
        lc = batch["labels"].reshape(B, n, ck).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_nll(h_chunk, l_chunk):
            logits = _head(cfg, params, h_chunk)
            ce, _ = cross_entropy(logits, l_chunk)
            return ce * l_chunk.size  # sum, renormalized below

        def body(acc, xs):
            h_chunk, l_chunk = xs
            return acc + chunk_nll(h_chunk, l_chunk), None

        total, _ = jax.lax.scan(body, 0.0, (hc, lc))
        loss = total / batch["labels"].size
        return loss, {
            "ce": loss,
            "tokens": jnp.asarray(batch["labels"].size, jnp.float32),
            "loss": loss,
        }

    # -- serving -------------------------------------------------------
    def prefill(self, params, batch: dict, cache_len: Optional[int] = None):
        return self.mod.prefill(
            self.cfg, params, batch["tokens"], _extras(batch), cache_len=cache_len
        )

    def decode_step(self, params, cache, tokens, pos, extras=None):
        return self.mod.decode_step(self.cfg, params, cache, tokens, pos, extras)

    def cache_specs(self, batch: int, cache_len: int):
        return self.mod.cache_specs(self.cfg, batch, cache_len)

    def abstract_cache(self, batch: int, cache_len: int):
        return tree_sds(self.cache_specs(batch, cache_len))


def cross_entropy(logits: jax.Array, labels: jax.Array):
    """GSPMD-friendly CE: per-shard label pick + logsumexp (handles a
    vocab-sharded logits tensor without gathers)."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], logits32, 0.0), axis=-1)
    nll = lse - picked
    loss = jnp.mean(nll)
    return loss, {"ce": loss, "tokens": jnp.asarray(labels.size, jnp.float32)}
