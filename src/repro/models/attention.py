"""GQA attention: blockwise (online-softmax) XLA path + decode-step path.

The blockwise formulation never materializes the full (Lq, Lk) score matrix:
it scans over KV chunks carrying the running (max, denom, acc) triple.  This
is the same algorithm the Pallas flash kernel (kernels/flash_attention.py)
implements with explicit VMEM tiling on TPU; here it serves as the XLA
lowering used by the dry-run and as a memory-safe default on any backend.

Causal note: the scan visits every KV chunk for every query (masked), so HLO
FLOPs are ~2x the causal ideal; the TPU kernel skips fully-masked blocks.
This is accounted for in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_x

_NEG = -1e30


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, L, KV, hd) -> (B, L, H, hd).  Under TP the repeat is local: each
    chip materializes only its own query heads' K/V copies (tiny)."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    k = jnp.repeat(k, n_heads // n_kv, axis=2)
    return shard_x(k, "batch", "seq", "heads_act", None)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Blockwise attention.  q (B,Lq,H,hd); k,v (B,Lk,KV,hd) -> (B,Lq,H,hd)."""
    b, lq, h, hd = q.shape
    lk = k.shape[1]
    ck = min(kv_chunk, lk)
    while lk % ck:  # fall back to the largest divisor of lk (odd test lengths)
        ck -= 1
    n_chunks = lk // ck

    k, v = repeat_kv(k, h), repeat_kv(v, h)  # per-head layout, head-sharded
    scale = 1.0 / (hd**0.5)
    q_pos = q_offset + jnp.arange(lq)

    kc = k.reshape(b, n_chunks, ck, h, hd).swapaxes(0, 1)  # (n, B, ck, H, hd)
    vc = v.reshape(b, n_chunks, ck, h, hd).swapaxes(0, 1)

    acc0 = jnp.zeros((b, lq, h, hd), jnp.float32)
    m0 = jnp.full((b, lq, h), _NEG, jnp.float32)
    l0 = jnp.zeros((b, lq, h), jnp.float32)

    def step(carry, xs):
        acc, m, l, idx = carry
        k_i, v_i = xs
        s = jnp.einsum("blhd,bchd->blhc", q, k_i, preferred_element_type=jnp.float32)
        s = s * scale  # (B, Lq, H, ck)
        k_pos = idx * ck + jnp.arange(ck)
        mask = jnp.ones((lq, ck), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask_b = mask[None, :, None, :]
        s = jnp.where(mask_b, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * mask_b  # zero out masked cols
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        # §Perf: p cast to the value dtype for the PV matmul - halves the
        # score-chain HBM traffic; the accumulator stays fp32 (flash-standard)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "blhc,bchd->blhd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new, idx + 1), None

    from repro.models.layers import scan_unroll

    (acc, m, l, _), _ = jax.lax.scan(
        step, (acc0, m0, l0, 0), (kc, vc), unroll=scan_unroll()
    )
    out = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(q.dtype)
    return shard_x(out, "batch", "seq", "heads_act", None)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    cache_positions: Optional[jax.Array] = None,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token attention against a cache.

    q (B, 1, H, hd); k_cache/v_cache (B, Lc, KV, hd); pos (B,) current position.
    ``cache_positions`` (B, Lc): absolute position stored at each cache slot
    (ring buffers for windowed attention); defaults to arange for linear caches.

    When the active strategy enables flash_decode and the cache is
    sequence-sharded over "model", dispatches to the distributed flash-decode
    path (each shard attends to its local cache slice; partial softmax states
    combine with an LSE-rescaled psum - no cache all-gather).
    """
    from repro.parallel.sharding import flash_decode_enabled

    if flash_decode_enabled():
        return _decode_attention_distributed(
            q, k_cache, v_cache, pos, cache_positions=cache_positions, window=window
        )
    b, _, h, hd = q.shape
    lc = k_cache.shape[1]
    kr = repeat_kv(k_cache, h)  # (B, Lc, H, hd); local repeat per shard
    vr = repeat_kv(v_cache, h)
    scale = 1.0 / (hd**0.5)

    s = jnp.einsum("bhd,blhd->bhl", q[:, 0], kr, preferred_element_type=jnp.float32)
    s = s * scale  # (B, H, Lc)
    if cache_positions is None:
        cache_positions = jnp.broadcast_to(jnp.arange(lc)[None, :], (b, lc))
    valid = cache_positions <= pos[:, None]
    if window is not None:
        valid &= cache_positions > (pos[:, None] - window)
    valid &= cache_positions >= 0
    s = jnp.where(valid[:, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhl,blhd->bhd", p, vr, preferred_element_type=jnp.float32)
    return out[:, None].astype(q.dtype)


def _decode_attention_distributed(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    cache_positions: Optional[jax.Array] = None,
    window: Optional[int] = None,
) -> jax.Array:
    """Distributed flash-decode (§Perf): the KV cache stays sequence-sharded
    over "model"; each shard computes partial (m, l, acc) over its slice and
    the full softmax is reconstructed with an LSE-rescaled psum.  Wire cost
    per layer: O(B*H*hd) instead of O(B*Lc*KV*hd) (the cache all-gather GSPMD
    otherwise inserts - measured 2.1 GB/layer for llama3-405b decode_32k)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import _CTX, dp_axes

    mesh = _CTX.mesh
    b, _, h, hd = q.shape
    lc = k_cache.shape[1]
    if cache_positions is None:
        cache_positions = jnp.broadcast_to(jnp.arange(lc)[None, :], (b, lc))
    dp = dp_axes(mesh.axis_names)
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)

    # shard_map needs even shards: pad the cache seq dim; padded slots carry
    # cache_position = -1 and are masked out by the validity test below
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    pad = (-lc) % n_model
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, padw)
        v_cache = jnp.pad(v_cache, padw)
        cache_positions = jnp.pad(cache_positions, ((0, 0), (0, pad)), constant_values=-1)

    def local(q, k, v, cpos, pos):
        # q (b', 1, H, hd) replicated over model; k/v (b', lc', KV, hd) local slice
        hh, dd = q.shape[2], q.shape[3]
        kr = jnp.repeat(k, hh // k.shape[2], axis=2)
        vr = jnp.repeat(v, hh // v.shape[2], axis=2)
        s = jnp.einsum("bhd,blhd->bhl", q[:, 0].astype(jnp.float32), kr.astype(jnp.float32))
        s = s / (dd**0.5)
        valid = cpos <= pos[:, None]
        if window is not None:
            valid &= cpos > (pos[:, None] - window)
        valid &= cpos >= 0
        s = jnp.where(valid[:, None, :], s, _NEG)
        m = jnp.max(s, axis=-1)  # (b', H)
        p = jnp.exp(s - m[..., None]) * valid[:, None, :]
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhl,blhd->bhd", p, vr.astype(jnp.float32))
        # combine partial softmax states across cache shards
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        out = acc_g / jnp.maximum(l_g, 1e-37)[..., None]
        return out[:, None].astype(q.dtype)

    from repro.compat import compat_shard_map

    fn = compat_shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None, None),  # q: heads gathered (tiny)
            P(bspec, "model", None, None),  # cache slices stay put
            P(bspec, "model", None, None),
            P(bspec, "model"),
            P(bspec),
        ),
        out_specs=P(bspec, None, None, None),
    )
    return fn(q, k_cache, v_cache, cache_positions, pos)


# ---------------------------------------------------------------------------
# Projections (shared by all attention layers)
# ---------------------------------------------------------------------------


def qkv_proj(x: jax.Array, p: dict) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (B,L,D) -> q (B,L,H,hd), k/v (B,L,KV,hd) using 3D weights."""
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    q = shard_x(q, "batch", "seq", "heads_act", None)
    k = shard_x(k, "batch", "seq", "kv_heads_act", None)
    v = shard_x(v, "batch", "seq", "kv_heads_act", None)
    return q, k, v


def out_proj(attn_out: jax.Array, wo: jax.Array) -> jax.Array:
    return jnp.einsum("blhk,hkd->bld", attn_out, wo)
