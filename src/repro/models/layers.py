"""Core layer primitives shared by every model family (pure JAX)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard_x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x @ gate) * (x @ up) )."""
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    h = shard_x(h, "batch", "seq", "mlp_act")
    return jnp.einsum("...f,fd->...d", h, w_down)


def geglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.gelu(g) * u
    h = shard_x(h, "batch", "seq", "mlp_act")
    return jnp.einsum("...f,fd->...d", h, w_down)


def embed_tokens(tokens: jax.Array, table: jax.Array, compute_dtype) -> jax.Array:
    out = jnp.take(table, tokens, axis=0).astype(compute_dtype)
    return shard_x(out, "batch", "seq", "embed_act")


def lm_logits(x: jax.Array, head: jax.Array) -> jax.Array:
    """x (..., D) @ head (D, V) -> (..., V)."""
    logits = jnp.einsum("...d,dv->...v", x, head)
    return shard_x(logits, "batch", "seq", "vocab_act")


def causal_conv1d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over the seq dim.  x (B, L, C), w (C, K)."""
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # gather K shifted views and contract - small K (4), stays fused.
    out = jnp.zeros_like(x)
    L = x.shape[1]
    for i in range(k):
        out = out + pad[:, i : i + L, :] * w[:, i]
    if bias is not None:
        out = out + bias
    return out


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, bias=None):
    """One decode step of causal depthwise conv.
    x_t (B, C); conv_state (B, K-1, C) holds the previous K-1 inputs."""
    k = w.shape[-1]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,ck->bc", window, w)
    if bias is not None:
        out = out + bias
    new_state = window[:, 1:, :]
    return out, new_state


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x (..., L, n_heads, head_dim) (or L==1 decode), pos broadcastable (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., L, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Scan-over-layers helper
# ---------------------------------------------------------------------------

# Cost-extrapolation mode (dry-run only): XLA's cost_analysis counts a while
# loop's body ONCE regardless of trip count, so the dry-run lowers small-depth
# variants with every scan fully unrolled and extrapolates F = alpha + L*beta.
_UNROLL = {"on": False}


class unroll_all_scans:
    """Context manager: every scan_layers / attention / ssm chunk scan lowers
    fully unrolled (trace-time flag; never use for real execution)."""

    def __enter__(self):
        _UNROLL["on"] = True
        return self

    def __exit__(self, *exc):
        _UNROLL["on"] = False
        return False


def scan_unroll() -> bool:
    return _UNROLL["on"]


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "collectives":
        # §Perf: save exactly the post-all-reduce activations (named below),
        # so the backward pass re-runs the cheap elementwise/matmul work but
        # never re-issues TP collectives (remat="dots"/"full" re-run them).
        return jax.checkpoint_policies.save_only_these_names("post_collective")
    raise ValueError(name)


def post_collective(x: jax.Array) -> jax.Array:
    """Tag an activation produced right after a TP collective (see
    remat_policy('collectives'))."""
    from jax.ad_checkpoint import checkpoint_name

    return checkpoint_name(x, "post_collective")


def scan_layers(body, carry, stacked_params, remat: str = "dots", **static_kw):
    """Run ``body(carry, layer_params) -> carry`` over a stacked param tree.

    The body is rematerialized per-layer according to the policy so that the
    backward pass does not keep every layer's activations live.
    """
    fn = lambda c, p: (body(c, p, **static_kw), None)
    policy = remat_policy(remat)
    if policy is not None or remat == "full":
        fn = jax.checkpoint(fn, policy=policy, prevent_cse=False)
    carry, _ = jax.lax.scan(fn, carry, stacked_params, unroll=_UNROLL["on"])
    return carry


def scan_layers_carry(body, carry, stacked_params, remat: str = "dots", **static_kw):
    """Like scan_layers but the body also emits a per-layer output
    (used for cache/state collection): body(carry, p) -> (carry, out)."""
    fn = lambda c, p: body(c, p, **static_kw)
    policy = remat_policy(remat)
    if policy is not None or remat == "full":
        fn = jax.checkpoint(fn, policy=policy, prevent_cse=False)
    return jax.lax.scan(fn, carry, stacked_params, unroll=_UNROLL["on"])
