"""VLM backbone (llama-3.2-vision-11b): dense GQA decoder with gated
cross-attention image layers every ``cross_attn_period`` layers.

The vision frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, n_img_tokens, d_model).  Layers are scanned
in superblocks of ``period`` (period-1 self layers + 1 gated cross layer) so
the lowered HLO stays O(1) in depth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import embed_tokens, rms_norm, scan_layers, scan_layers_carry, swiglu
from repro.models.spec import ParamSpec, dense, stacked
from repro.models.transformer import (
    _head,
    attn_specs,
    block_specs as dense_block_specs,
    mlp_specs,
    self_attn_block,
    self_attn_block_decode,
    self_attn_block_prefill,
)
from repro.parallel.sharding import shard_x


def xattn_block_specs(cfg: ArchConfig, dt: str) -> dict:
    return {
        "ln": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "cross": attn_specs(cfg, dt),
        "gate_attn": ParamSpec((), (), "float32", "zeros"),  # tanh-gated, starts closed
        "ln_mlp": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "mlp": mlp_specs(cfg, dt),
        "gate_mlp": ParamSpec((), (), "float32", "zeros"),
    }


def _layout(cfg: ArchConfig) -> tuple[int, int]:
    period = cfg.cross_attn_period
    assert period >= 2 and cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period, period


def specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    n_super, period = _layout(cfg)
    return {
        "embed": dense((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), dt, scale=0.02),
        "superblocks": stacked(
            n_super,
            {
                "self": stacked(period - 1, dense_block_specs(cfg, dt)),
                "xattn": xattn_block_specs(cfg, dt),
            },
        ),
        "ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "lm_head": dense((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt),
    }


def xattn_block(cfg: ArchConfig, x, p, img: jax.Array):
    """Gated cross-attention to image embeddings (B, n_img, D)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bld,dhk->blhk", h, p["cross"]["wq"])
    k = jnp.einsum("bld,dhk->blhk", img, p["cross"]["wk"])
    v = jnp.einsum("bld,dhk->blhk", img, p["cross"]["wv"])
    a = attn.attention(q, k, v, causal=False)
    x = x + jnp.tanh(p["gate_attn"]) * attn.out_proj(a, p["cross"]["wo"]).astype(jnp.float32)
    x = x.astype(h.dtype)
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    m = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    x = (x + jnp.tanh(p["gate_mlp"]) * m.astype(jnp.float32)).astype(h.dtype)
    return shard_x(x, "batch", "seq", "embed_act")


def _xattn_block_cached(cfg, x, p, ck, cv):
    """Decode-time gated cross attention against cached image K/V."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bld,dhk->blhk", h, p["cross"]["wq"])
    n_img = ck.shape[1]
    pos_full = jnp.full((x.shape[0],), n_img - 1, jnp.int32)
    a = attn.decode_attention(q, ck, cv, pos_full)
    x = x + jnp.tanh(p["gate_attn"]) * attn.out_proj(a, p["cross"]["wo"]).astype(jnp.float32)
    x = x.astype(h.dtype)
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    m = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    x = (x + jnp.tanh(p["gate_mlp"]) * m.astype(jnp.float32)).astype(h.dtype)
    return x


def backbone(cfg: ArchConfig, params, tokens, extras=None):
    img = extras["img_embeds"].astype(cfg.compute_dtype)
    img = shard_x(img, "batch", "seq", "embed_act")
    B, L = tokens.shape
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def super_body(c, p):
        c = scan_layers(
            lambda cc, pp: self_attn_block(cfg, cc, pp, pos), c, p["self"], remat="none"
        )
        return xattn_block(cfg, c, p["xattn"], img)

    return scan_layers(super_body, x, params["superblocks"], remat=cfg.remat)


def forward(cfg: ArchConfig, params, tokens, extras=None):
    return _head(cfg, params, backbone(cfg, params, tokens, extras))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    n_super, period = _layout(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    ct = cfg.compute_dtype
    ax5 = ("layers", None, "cache_batch", "cache_seq", "kv_heads_act", None)
    ax4 = ("layers", "cache_batch", "cache_seq", "kv_heads_act", None)
    return {
        "superblocks": {
            "k": ParamSpec((n_super, period - 1, batch, cache_len, KV, hd), ax5, ct, "zeros"),
            "v": ParamSpec((n_super, period - 1, batch, cache_len, KV, hd), ax5, ct, "zeros"),
            "img_k": ParamSpec((n_super, batch, cfg.n_img_tokens, KV, hd), ax4, ct, "zeros"),
            "img_v": ParamSpec((n_super, batch, cfg.n_img_tokens, KV, hd), ax4, ct, "zeros"),
        }
    }


def prefill(cfg: ArchConfig, params, tokens, extras=None, cache_len: Optional[int] = None):
    img = extras["img_embeds"].astype(cfg.compute_dtype)
    B, L = tokens.shape
    cache_len = cache_len or L
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]

    def super_body(c, p):
        def self_body(cc, pp):
            return self_attn_block_prefill(cfg, cc, pp, pos)

        c, (k, v) = scan_layers_carry(self_body, c, p["self"], remat="none")
        c = xattn_block(cfg, c, p["xattn"], img)
        ik = jnp.einsum("bld,dhk->blhk", img, p["xattn"]["cross"]["wk"])
        iv = jnp.einsum("bld,dhk->blhk", img, p["xattn"]["cross"]["wv"])
        return c, (k, v, ik, iv)

    x, (k, v, ik, iv) = scan_layers_carry(super_body, x, params["superblocks"], remat=cfg.remat)
    if cache_len > L:
        padw = ((0, 0), (0, 0), (0, 0), (0, cache_len - L), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    cache = {"superblocks": {"k": k, "v": v, "img_k": ik, "img_v": iv}}
    return _head(cfg, params, x[:, -1:, :]), cache


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, extras=None):
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def super_body(c, scanned):
        p, lc = scanned

        def self_body(cc, s):
            pp, kc, vc = s
            cc, new_cache = self_attn_block_decode(cfg, cc, pp, {"k": kc, "v": vc}, pos)
            return cc, (new_cache["k"], new_cache["v"])

        c, (k, v) = scan_layers_carry(self_body, c, (p["self"], lc["k"], lc["v"]), remat="none")
        c = _xattn_block_cached(cfg, c, p["xattn"], lc["img_k"], lc["img_v"])
        return c, {"k": k, "v": v, "img_k": lc["img_k"], "img_v": lc["img_v"]}

    x, sb = scan_layers_carry(
        super_body, x, (params["superblocks"], cache["superblocks"]), remat="none"
    )
    return _head(cfg, params, x), {"superblocks": sb}
