"""Dense decoder-only GQA transformer (llama3 / internlm2 / granite family).

Layer stacking uses ``lax.scan`` over stacked parameters so the lowered HLO is
O(1) in depth (critical for 126-layer 405B dry-run compile times on CPU).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    apply_rope,
    embed_tokens,
    lm_logits,
    rms_norm,
    scan_layers,
    scan_layers_carry,
    swiglu,
)
from repro.models.spec import ParamSpec, dense, stacked
from repro.parallel.sharding import shard_x


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ArchConfig, dt: str) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": dense((D, H, hd), ("embed", "heads", None), dt),
        "wk": dense((D, KV, hd), ("embed", "kv_heads", None), dt),
        "wv": dense((D, KV, hd), ("embed", "kv_heads", None), dt),
        "wo": dense((H, hd, D), ("heads", None, "embed"), dt),
    }


def mlp_specs(cfg: ArchConfig, dt: str) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": dense((D, F), ("embed", "mlp"), dt),
        "w_up": dense((D, F), ("embed", "mlp"), dt),
        "w_down": dense((F, D), ("mlp", "embed"), dt),
    }


def block_specs(cfg: ArchConfig, dt: str) -> dict:
    return {
        "ln_attn": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "attn": attn_specs(cfg, dt),
        "ln_mlp": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
        "mlp": mlp_specs(cfg, dt),
    }


def specs(cfg: ArchConfig) -> dict:
    dt = cfg.param_dtype
    tree: dict[str, Any] = {
        "embed": dense((cfg.vocab_size, cfg.d_model), ("vocab", "embed_table"), dt, scale=0.02),
        "blocks": stacked(cfg.n_layers, block_specs(cfg, dt)),
        "ln_f": ParamSpec((cfg.d_model,), ("norm",), dt, "zeros"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = dense((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt)
    return tree


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def self_attn_block(cfg: ArchConfig, x, p, pos, *, window=None):
    from repro.models.layers import post_collective

    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    a = attn.attention(q, k, v, causal=True, window=window)
    x = x + post_collective(attn.out_proj(a, p["attn"]["wo"]))
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + post_collective(swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"]))
    return shard_x(x, "batch", "seq", "embed_act")


def self_attn_block_prefill(cfg: ArchConfig, x, p, pos, *, window=None):
    """Like self_attn_block but also emits the (k, v) cache for this layer."""
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k, v = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    a = attn.attention(q, k, v, causal=True, window=window)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return shard_x(x, "batch", "seq", "embed_act"), (k, v)


def write_cache(cache_k, cache_v, k_t, v_t, pos):
    """Write one token's k/v into the cache at per-batch positions."""

    def upd(c, t, p):
        return jax.lax.dynamic_update_slice(c, t, (p, 0, 0))

    cache_k = jax.vmap(upd)(cache_k, k_t, pos)
    cache_v = jax.vmap(upd)(cache_v, v_t, pos)
    return cache_k, cache_v


def self_attn_block_decode(cfg: ArchConfig, x, p, layer_cache, pos, *, window=None, cache_positions=None):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k_t, v_t = attn.qkv_proj(h, p["attn"])
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_t = apply_rope(k_t, pos[:, None], cfg.rope_theta)
    write_pos = pos if window is None else pos % layer_cache["k"].shape[1]
    ck, cv = write_cache(layer_cache["k"], layer_cache["v"], k_t, v_t, write_pos)
    cpos = cache_positions
    a = attn.decode_attention(q, ck, cv, pos, cache_positions=cpos, window=window)
    x = x + attn.out_proj(a, p["attn"]["wo"])
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Full model passes
# ---------------------------------------------------------------------------


def _head(cfg: ArchConfig, params, x):
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return lm_logits(x, head.astype(x.dtype))


def backbone(cfg: ArchConfig, params, tokens, extras=None):
    """Hidden states before the LM head (used by the chunked-CE path)."""
    B, L = tokens.shape
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]
    return scan_layers(
        lambda c, p: self_attn_block(cfg, c, p, pos),
        x,
        params["blocks"],
        remat=cfg.remat,
    )


def forward(cfg: ArchConfig, params, tokens, extras=None):
    """Teacher-forced full-sequence forward -> logits (B, L, V)."""
    return _head(cfg, params, backbone(cfg, params, tokens, extras))


def cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    KV, hd, L = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    dt = cfg.compute_dtype
    return {
        "layers": {
            "k": ParamSpec(
                (L, batch, cache_len, KV, hd), ("layers", "cache_batch", "cache_seq", "kv_heads_act", None), dt, "zeros"
            ),
            "v": ParamSpec(
                (L, batch, cache_len, KV, hd), ("layers", "cache_batch", "cache_seq", "kv_heads_act", None), dt, "zeros"
            ),
        }
    }


def prefill(cfg: ArchConfig, params, tokens, extras=None, cache_len: Optional[int] = None):
    """Full-sequence forward that also returns the KV cache.

    Returns (last-token logits (B, 1, V), cache).
    """
    B, L = tokens.shape
    cache_len = cache_len or L
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)
    pos = jnp.arange(L)[None, :]
    x, kv = scan_layers_carry(
        lambda c, p: self_attn_block_prefill(cfg, c, p, pos),
        x,
        params["blocks"],
        remat=cfg.remat,
    )
    k, v = kv  # (n_layers, B, L, KV, hd)
    if cache_len > L:
        padw = ((0, 0), (0, 0), (0, cache_len - L), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    logits = _head(cfg, params, x[:, -1:, :])
    return logits, {"layers": {"k": k, "v": v}}


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, extras=None):
    """One decode step.  tokens (B, 1), pos (B,).  Returns (logits, cache)."""
    x = embed_tokens(tokens, params["embed"], cfg.compute_dtype)

    def body(c, scanned):
        p, layer_cache = scanned
        return self_attn_block_decode(cfg, c, p, layer_cache, pos)

    x, new_cache = scan_layers_carry(
        body, x, (params["blocks"], cache["layers"]), remat="none"
    )
    return _head(cfg, params, x), {"layers": new_cache}
