"""Fault tolerance: retry/re-bind on task failure, provider blacklisting on
outage, straggler mitigation via speculative duplicate dispatch, and the
per-member circuit breaker used by provider groups (core/group.py).

The paper's Hydra ensures graceful teardown on failure; at 1000+ node scale
the broker additionally has to *survive* provider loss.  Policy here:

  task failure     -> reset FAILED -> BOUND, re-bind to another healthy
                      provider (never the one that just failed it), resubmit;
                      give up after task.max_retries and surface the error.
  provider outage  -> blacklist the provider, fail-fast its in-flight tasks,
                      re-bind + resubmit everything non-final it owned.
  grouped member   -> the member's circuit breaker opens (immediately on
                      ProviderDown, after `failure_threshold` consecutive
                      errors otherwise); orphans fail over to surviving group
                      members without touching the caller's binding policy;
                      after `reset_timeout_s` a single half-open probe is let
                      through and either closes or re-opens the breaker.
  straggler        -> a watchdog compares running tasks against
                      factor * median(completed runtimes); slow tasks get a
                      speculative clone on another provider; first completion
                      wins (the Task state machine makes the loser a no-op).
                      A straggler on a grouped member also counts as a soft
                      failure against that member's breaker.
"""
from __future__ import annotations

import statistics
import threading
from enum import Enum
from typing import Callable, Optional

from repro.core.task import Task
from repro.runtime.clock import get_clock
from repro.runtime.tracing import now


class BreakerState(str, Enum):
    CLOSED = "CLOSED"  # healthy: traffic flows
    OPEN = "OPEN"  # tripped: no traffic until reset timeout elapses
    HALF_OPEN = "HALF_OPEN"  # probing: exactly one request allowed through


class CircuitBreaker:
    """Consecutive-failure circuit breaker with a timed half-open probe.

    State machine:
      CLOSED   --(failures >= failure_threshold, or trip())-->  OPEN
      OPEN     --(reset_timeout_s elapsed; next allow())----->  HALF_OPEN
      HALF_OPEN --(record_success x success_threshold)------->  CLOSED
      HALF_OPEN --(record_failure)-------------------------->  OPEN

    ``allow()`` is the dispatch gate: it returns True when traffic may be
    sent, and performs the OPEN -> HALF_OPEN transition itself so that the
    caller that wins the race becomes the probe.  While HALF_OPEN, only the
    probe is in flight; everyone else is rejected until it resolves.
    """

    def __init__(
        self,
        failure_threshold: int = 3,
        reset_timeout_s: float = 30.0,
        success_threshold: int = 1,
    ):
        self.failure_threshold = failure_threshold
        self.reset_timeout_s = reset_timeout_s
        self.success_threshold = success_threshold
        self.state = BreakerState.CLOSED
        self.consecutive_failures = 0
        self.half_open_successes = 0
        self.opened_at: Optional[float] = None
        self.trips = 0  # times the breaker opened (metrics)
        self._probe_inflight = False
        self._lock = threading.Lock()
        # state-transition listener (core/group.py wires this to the
        # broker's CapacityLedger): every transition — including the timed
        # OPEN -> HALF_OPEN reopening, which happens inside allow(), never by
        # mere passage of time — is thereby an O(1) capacity event.  Called
        # under the breaker lock; listeners must not re-enter the breaker.
        self.on_transition: Optional[Callable[[BreakerState, BreakerState], None]] = None

    def _set_state(self, new: BreakerState) -> None:
        # callers hold self._lock
        old, self.state = self.state, new
        if old != new and self.on_transition is not None:
            self.on_transition(old, new)

    # -- gates -----------------------------------------------------------
    def allow(self) -> bool:
        """May traffic be dispatched right now?  (Mutates OPEN -> HALF_OPEN.)"""
        with self._lock:
            if self.state == BreakerState.CLOSED:
                return True
            if self.state == BreakerState.OPEN:
                if self.opened_at is not None and now() - self.opened_at >= self.reset_timeout_s:
                    self._set_state(BreakerState.HALF_OPEN)
                    self.half_open_successes = 0
                    self._probe_inflight = True
                    return True  # this caller is the probe
                return False
            # HALF_OPEN: single probe at a time
            if not self._probe_inflight:
                self._probe_inflight = True
                return True
            return False

    def available(self) -> bool:
        """Non-mutating peek: would allow() plausibly return True?"""
        with self._lock:
            if self.state == BreakerState.CLOSED:
                return True
            if self.state == BreakerState.OPEN:
                return self.opened_at is not None and now() - self.opened_at >= self.reset_timeout_s
            return not self._probe_inflight

    # -- outcome feedback ------------------------------------------------
    def record_success(self) -> None:
        with self._lock:
            self.consecutive_failures = 0
            if self.state == BreakerState.HALF_OPEN:
                self._probe_inflight = False
                self.half_open_successes += 1
                if self.half_open_successes >= self.success_threshold:
                    self._set_state(BreakerState.CLOSED)

    def record_failure(self) -> None:
        with self._lock:
            self.consecutive_failures += 1
            if self.state == BreakerState.HALF_OPEN:
                self._reopen()
            elif self.state == BreakerState.CLOSED and self.consecutive_failures >= self.failure_threshold:
                self._reopen()

    def release_probe(self) -> None:
        """The dispatched probe never actually ran (its task finished
        elsewhere first): return the ticket so the next allow() can probe,
        instead of stranding the breaker HALF_OPEN forever."""
        with self._lock:
            if self.state == BreakerState.HALF_OPEN:
                self._probe_inflight = False

    def trip(self) -> None:
        """Open immediately (hard signal: ProviderDown / watchdog verdict)."""
        with self._lock:
            if self.state != BreakerState.OPEN:
                self._reopen()
            else:
                self.opened_at = now()  # re-stamp: extend the open window

    def _reopen(self) -> None:
        # callers hold self._lock
        self._set_state(BreakerState.OPEN)
        self.opened_at = now()
        self.trips += 1
        self._probe_inflight = False
        self.half_open_successes = 0


class StragglerWatchdog:
    def __init__(
        self,
        running: Callable[[], list[Task]],
        duplicate: Callable[[Task], None],
        factor: float = 3.0,
        min_samples: int = 5,
        interval_s: float = 0.05,
        min_runtime_s: float = 0.02,
    ):
        self.running = running
        self.duplicate = duplicate
        self.factor = factor
        self.min_samples = min_samples
        self.interval_s = interval_s
        self.min_runtime_s = min_runtime_s
        self.completed_runtimes: list[float] = []
        self.duplicated: set[str] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True, name="straggler-watchdog")
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    def observe_completion(self, runtime_s: float):
        with self._lock:
            self.completed_runtimes.append(runtime_s)

    def _loop(self):
        # clock-aware tick: under a VirtualClock the watchdog scans on
        # virtual intervals, so straggler thresholds fire deterministically
        while not get_clock().wait_event(self._stop, self.interval_s):
            with self._lock:
                if len(self.completed_runtimes) < self.min_samples:
                    continue
                med = statistics.median(self.completed_runtimes)
            threshold = max(self.factor * med, self.min_runtime_s)
            t_now = now()
            for task in self.running():
                if task.uid in self.duplicated or task.final:
                    continue
                t0 = task.trace.first("exec_start")
                if t0 is not None and (t_now - t0) > threshold:
                    with self._lock:
                        if task.uid in self.duplicated:
                            continue
                        self.duplicated.add(task.uid)
                    task.trace.add("straggler_detected")
                    self.duplicate(task)


def clone_for_speculation(task: Task) -> Task:
    """A shadow task whose completion completes the original."""
    shadow = Task(
        kind=task.kind,
        fn=task.fn,
        resources=task.resources,
        arch=task.arch,
        shape=task.shape,
        step_kind=task.step_kind,
        duration=0.0,  # re-execution of a straggling sleep is instant by design
        payload=task.payload,
        max_retries=0,
        # declared I/O rides along: when the shadow wins, the manager's
        # on_task_finishing hook must register the outputs (at the shadow's
        # site) BEFORE forward() resolves the original and unleashes its
        # dependents — the original's own stage-out may still be minutes out
        inputs=task.inputs,
        outputs=task.outputs,
    )
    shadow.trace.add("speculative_clone_of:" + task.uid)

    def forward(fut):
        if fut.cancelled():
            return
        exc = fut.exception()
        if exc is None and not task.final:
            task.trace.add("speculative_win")
            task.mark_done(fut.result())

    shadow.add_done_callback(forward)
    return shadow
