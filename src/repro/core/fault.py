"""Fault tolerance: retry/re-bind on task failure, provider blacklisting on
outage, and straggler mitigation via speculative duplicate dispatch.

The paper's Hydra ensures graceful teardown on failure; at 1000+ node scale
the broker additionally has to *survive* provider loss.  Policy here:

  task failure     -> reset FAILED -> BOUND, re-bind to another healthy
                      provider (never the one that just failed it), resubmit;
                      give up after task.max_retries and surface the error.
  provider outage  -> blacklist the provider, fail-fast its in-flight tasks,
                      re-bind + resubmit everything non-final it owned.
  straggler        -> a watchdog compares running tasks against
                      factor * median(completed runtimes); slow tasks get a
                      speculative clone on another provider; first completion
                      wins (the Task state machine makes the loser a no-op).
"""
from __future__ import annotations

import statistics
import threading
import time
from typing import Callable, Optional

from repro.core.task import Task, TaskState
from repro.runtime.tracing import now


class StragglerWatchdog:
    def __init__(
        self,
        running: Callable[[], list[Task]],
        duplicate: Callable[[Task], None],
        factor: float = 3.0,
        min_samples: int = 5,
        interval_s: float = 0.05,
        min_runtime_s: float = 0.02,
    ):
        self.running = running
        self.duplicate = duplicate
        self.factor = factor
        self.min_samples = min_samples
        self.interval_s = interval_s
        self.min_runtime_s = min_runtime_s
        self.completed_runtimes: list[float] = []
        self.duplicated: set[str] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True, name="straggler-watchdog")
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    def observe_completion(self, runtime_s: float):
        with self._lock:
            self.completed_runtimes.append(runtime_s)

    def _loop(self):
        while not self._stop.wait(self.interval_s):
            with self._lock:
                if len(self.completed_runtimes) < self.min_samples:
                    continue
                med = statistics.median(self.completed_runtimes)
            threshold = max(self.factor * med, self.min_runtime_s)
            t_now = now()
            for task in self.running():
                if task.uid in self.duplicated or task.final:
                    continue
                t0 = task.trace.first("exec_start")
                if t0 is not None and (t_now - t0) > threshold:
                    with self._lock:
                        if task.uid in self.duplicated:
                            continue
                        self.duplicated.add(task.uid)
                    task.trace.add("straggler_detected")
                    self.duplicate(task)


def clone_for_speculation(task: Task) -> Task:
    """A shadow task whose completion completes the original."""
    shadow = Task(
        kind=task.kind,
        fn=task.fn,
        resources=task.resources,
        arch=task.arch,
        shape=task.shape,
        step_kind=task.step_kind,
        duration=0.0,  # re-execution of a straggling sleep is instant by design
        payload=task.payload,
        max_retries=0,
    )
    shadow.trace.add("speculative_clone_of:" + task.uid)

    def forward(fut):
        if fut.cancelled():
            return
        exc = fut.exception()
        if exc is None and not task.final:
            task.trace.add("speculative_win")
            task.mark_done(fut.result())

    shadow.add_done_callback(forward)
    return shadow
