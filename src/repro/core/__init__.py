"""Hydra broker core: the paper's contribution as a composable module."""
from repro.core.admission import AdmissionController, AdmissionError, TenantSpec
from repro.core.autoscaler import (
    Autoscaler,
    LatencyModel,
    LaunchSpec,
    ProviderPool,
    cloud_startup,
    hpc_queue_wait,
)
from repro.core.broker import Hydra, Submission
from repro.core.chaos import (
    ChaosEngine,
    LinkWindow,
    PreemptKill,
    QuarantineStorm,
    SiteOutage,
)
from repro.core.dispatcher import StreamingDispatcher
from repro.core.fault import BreakerState, CircuitBreaker
from repro.core.group import GroupExhausted, GroupMember, ProviderGroup
from repro.core.managers.compute import Preempted, ProviderDown
from repro.core.market import MarketPlanner, PreemptionHazard
from repro.core.managers.workflow import Workflow, WorkflowManager
from repro.core.policy import NoEligibleProvider
from repro.core.provider import ProviderProxy, ProviderSpec
from repro.core.resource import ResourceRequest
from repro.core.staging import (
    DatasetRegistry,
    LinkModel,
    StagingError,
    StagingService,
    TransferEngine,
)
from repro.core.task import Resources, Task, TaskState

__all__ = [
    "AdmissionController",
    "AdmissionError",
    "TenantSpec",
    "Autoscaler",
    "BreakerState",
    "ChaosEngine",
    "CircuitBreaker",
    "LinkWindow",
    "PreemptKill",
    "Preempted",
    "ProviderDown",
    "QuarantineStorm",
    "SiteOutage",
    "LatencyModel",
    "LaunchSpec",
    "MarketPlanner",
    "PreemptionHazard",
    "ProviderPool",
    "cloud_startup",
    "hpc_queue_wait",
    "GroupExhausted",
    "GroupMember",
    "Hydra",
    "NoEligibleProvider",
    "ProviderGroup",
    "StreamingDispatcher",
    "Submission",
    "Workflow",
    "WorkflowManager",
    "ProviderProxy",
    "ProviderSpec",
    "DatasetRegistry",
    "LinkModel",
    "StagingError",
    "StagingService",
    "TransferEngine",
    "ResourceRequest",
    "Resources",
    "Task",
    "TaskState",
]
