"""Cost- and preemption-aware market planner (paper §1, §4: concurrent
brokering across commercial cloud, private cloud, and HPC).

The platforms Hydra brokers differ in more than acquisition latency (the
autoscaler's LatencyModel): they differ in *price* and *revocation risk*.
Spot instances are cheap but preemptible; on-demand VMs are expensive and
stable; HPC batch slots are free-ish but walltime-killed.  This module
turns the autoscaler's "fastest arrival first" acquisition policy into a
market: a bid/choose loop that, given the same demand signals the pressure
tick already computes, selects the cheapest *feasible* platform mix.

  PreemptionHazard  seeded revocation model for one platform tier: an
                    expected revocation rate per instance-hour.  Feeds both
                    planning (expected-preemption-loss discounts a spot
                    slot's effective throughput) and chaos-style storm
                    sampling (``sample_kills``).
  MarketPlanner     attached via ``Autoscaler(..., planner=...)`` (or
                    ``Hydra.autoscale(pool, planner=...)``).  Each pressure
                    tick it re-ranks the launchable templates by price per
                    *effective* slot-hour — a greedy knapsack over
                    effective throughput = slots x (1 - expected loss) —
                    and each acquisition takes the cheapest feasible bid.
                    Prices may move mid-run (``set_price``), and the ranking
                    re-forms on the next tick: the bid loop re-bids
                    continuously.  Per-instance spend settles on release /
                    loss / shutdown into ``market.spend`` events, making
                    dollars a first-class derived metric
                    (``hydra.cost_node_seconds``, ``hydra.cost_dollars``).

Feasibility is the SLO leg: with ``slo_target_s`` set, a template whose
expected acquisition latency would eat the makespan budget (an HPC queue
wait of minutes against a seconds-scale target) is excluded no matter how
cheap it is.  Determinism: ranking is a pure sort with a total tie-break
(template name last), prices/hazards change only via explicit calls, and
the bid log is stamped on the active Clock — same seed, same schedule.
"""
from __future__ import annotations

import math
import random
import threading
from dataclasses import dataclass
from typing import Optional

from repro.core.autoscaler import LaunchSpec
from repro.runtime.clock import get_clock


@dataclass(frozen=True)
class PreemptionHazard:
    """Revocation model for one platform tier.

    ``rate_per_hour`` is the expected number of revocations per
    instance-hour of occupancy (a Poisson intensity): ~0 for on-demand,
    O(1) for aggressive spot tiers, in between for HPC-within-walltime.
    """

    rate_per_hour: float = 0.0

    def expected_loss_frac(self, recovery_cost_s: float) -> float:
        """Fraction of an instance's throughput lost to revocations: each
        expected kill costs ``recovery_cost_s`` of re-execution + re-binding
        per hour of occupancy.  Capped below 1 so a hazardous-but-priced
        slot never ranks as literally worthless."""
        return min(0.9, max(0.0, self.rate_per_hour * recovery_cost_s / 3600.0))

    def survival_p(self, window_s: float) -> float:
        """P(an instance lives through ``window_s`` without revocation)."""
        return math.exp(-self.rate_per_hour * max(0.0, window_s) / 3600.0)

    def sample_kills(
        self, rng: random.Random, instances: list[str], window_s: float
    ) -> list[str]:
        """Seeded storm sampling: which of ``instances`` get revoked within
        ``window_s``.  Iterates in the given order, so the same rng state
        and instance list reproduce the same victim set."""
        p = 1.0 - self.survival_p(window_s)
        return [name for name in instances if rng.random() < p]


# Default tiers (spot >> HPC-within-walltime >> on-demand), used when a
# LaunchSpec carries a price but no explicit hazard.
SPOT_HAZARD = PreemptionHazard(rate_per_hour=6.0)
HPC_WALLTIME_HAZARD = PreemptionHazard(rate_per_hour=0.5)
ON_DEMAND_HAZARD = PreemptionHazard(rate_per_hour=0.05)

_DEFAULT_HAZARD = {"cloud": ON_DEMAND_HAZARD, "hpc": HPC_WALLTIME_HAZARD}


class MarketPlanner:
    """The bid/choose loop.  One per Autoscaler; see the module docstring.

    Legacy accumulators (``plans``, ``bids``, ``cost_dollars``, ...) are
    maintained adjacent to each ``market.*`` emit under the planner lock,
    so ``HYDRA_EVENTS_CHECK=1`` can cross-check the log-derived view
    bit-for-bit (floats sum in emit order on both sides).
    """

    def __init__(
        self,
        slo_target_s: Optional[float] = None,
        recovery_cost_s: float = 60.0,
        seed: int = 0,
    ):
        self.slo_target_s = slo_target_s
        self.recovery_cost_s = recovery_cost_s
        self.rng = random.Random(seed)  # reserved for stochastic bid policies
        self._lock = threading.RLock()
        self.scaler = None
        self._events = None
        self._prices: dict[str, float] = {}  # live overrides, template -> $/slot-hr
        self._settled: set[str] = set()
        self._last_plan: Optional[tuple] = None
        # (t, template, price, eff_slots): the reproducible bid schedule
        self.bid_log: list[tuple] = []
        # legacy accumulators (HYDRA_EVENTS_CHECK ground truth)
        self.plans = 0
        self.bids = 0
        self.bids_by_template: dict[str, int] = {}
        self.reprices = 0
        self.cost_node_seconds = 0.0
        self.cost_dollars = 0.0

    # -- wiring ----------------------------------------------------------
    def bind(self, scaler) -> None:
        """Called by Autoscaler.__init__ when attached via ``planner=``."""
        if self.scaler is not None and self.scaler is not scaler:
            raise RuntimeError("market planner is already bound to an autoscaler")
        self.scaler = scaler
        self._events = scaler.broker.events

    # -- pricing / hazards ----------------------------------------------
    def price_of(self, launch: LaunchSpec) -> float:
        with self._lock:
            return self._prices.get(
                launch.template.name, launch.price_per_slot_hour
            )

    def hazard_of(self, launch: LaunchSpec) -> PreemptionHazard:
        if launch.hazard is not None:
            return launch.hazard
        return _DEFAULT_HAZARD.get(launch.template.platform, ON_DEMAND_HAZARD)

    def set_price(self, template: str, price: float) -> None:
        """Spot market moved: the next tick's replan re-ranks around it."""
        if price < 0:
            raise ValueError(f"negative price {price} for template {template!r}")
        with self._lock:
            self._prices[template] = price
            if self._events is None:
                return  # pre-bind configuration, not market movement
            self.reprices += 1
            self._events.emit("market.price", template=template, price=price)

    # -- the knapsack ----------------------------------------------------
    def effective_slots(self, launch: LaunchSpec) -> float:
        """Slots discounted by expected preemption loss: what a knapsack
        over throughput actually buys."""
        loss = self.hazard_of(launch).expected_loss_frac(self.recovery_cost_s)
        return launch.slots_per_instance * (1.0 - loss)

    def feasible(self, launch: LaunchSpec) -> bool:
        """SLO leg: an acquisition whose expected latency eats the makespan
        budget is not a bid, however cheap."""
        return (
            self.slo_target_s is None
            or launch.latency.expected_s <= self.slo_target_s
        )

    def _rank(self, candidates: list[LaunchSpec]) -> list[LaunchSpec]:
        def key(launch: LaunchSpec):
            eff = max(self.effective_slots(launch), 1e-9)
            return (
                self.price_of(launch) / eff,  # $ per effective slot-hour
                self.hazard_of(launch).rate_per_hour,
                launch.latency.expected_s,
                launch.template.name,  # total order: deterministic schedule
            )

        return sorted((c for c in candidates if self.feasible(c)), key=key)

    def replan(self, demand_slots: float) -> None:
        """The per-tick bid loop: re-rank the pool's open templates and
        record a ``market.plan`` whenever the mix changes (including the
        first tick)."""
        if self.scaler is None:
            return
        ranked = self._rank(self.scaler.pool.candidates())
        chosen = tuple(launch.template.name for launch in ranked)
        with self._lock:
            if chosen == self._last_plan:
                return
            self._last_plan = chosen
            self.plans += 1
            self._events.emit(
                "market.plan", demand=float(demand_slots), chosen=",".join(chosen)
            )

    def choose(
        self, candidates: list[LaunchSpec], deficit: float
    ) -> Optional[LaunchSpec]:
        """One acquisition's bid: the cheapest feasible candidate, greedily
        (the scale-out loop calls again while the deficit persists, which
        is the knapsack fill).  None when nothing is feasible."""
        ranked = self._rank(candidates)
        if not ranked:
            return None
        launch = ranked[0]
        name = launch.template.name
        with self._lock:
            price = self._prices.get(name, launch.price_per_slot_hour)
            eff = self.effective_slots(launch)
            self.bids += 1
            self.bids_by_template[name] = self.bids_by_template.get(name, 0) + 1
            self.bid_log.append((get_clock().now(), name, price, eff))
            self._events.emit(
                "market.bid", template=name, price=price, eff_slots=eff
            )
        return launch

    # -- settlement ------------------------------------------------------
    def settle(self, launch: LaunchSpec, name: str, row: dict) -> None:
        """Fold one instance's occupancy into the cost ledger (idempotent:
        release, loss, and shutdown paths may all reach the same row)."""
        arrived = row.get("arrived_at")
        if arrived is None:
            return  # never lived: no occupancy, no spend
        end = row.get("released_at")
        if end is None:
            end = get_clock().now()
        node_s = max(0.0, end - arrived)
        with self._lock:
            if name in self._settled:
                return
            self._settled.add(name)
            dollars = (
                node_s / 3600.0 * self.price_of(launch) * launch.slots_per_instance
            )
            self.cost_node_seconds += node_s
            self.cost_dollars += dollars
            self._events.emit(
                "market.spend", instance=name, node_s=node_s, dollars=dollars
            )

    # -- reporting -------------------------------------------------------
    def cost_report(self) -> dict:
        """Settled spend + the bid schedule summary (exp13's cost tables).
        Deterministic for a seeded virtual-clock run."""
        with self._lock:
            return {
                "node_seconds": self.cost_node_seconds,
                "dollars": self.cost_dollars,
                "settled_instances": len(self._settled),
                "plans": self.plans,
                "bids": self.bids,
                "bids_by_template": dict(self.bids_by_template),
            }

    def stats(self) -> dict:
        """Log-derived view adapter (the legacy accumulators stay as
        HYDRA_EVENTS_CHECK ground truth)."""
        if self._events is None:
            return {"plans": 0, "bids": 0, "reprices": 0, "cost_dollars": 0.0}
        self._events.maybe_check()
        view = self._events.view
        return {
            "plans": int(view.get("hydra.market.plans")),
            "bids": int(view.get("hydra.market.bids")),
            "reprices": int(view.get("hydra.market.reprices")),
            "cost_node_seconds": view.get("hydra.cost_node_seconds"),
            "cost_dollars": view.get("hydra.cost_dollars"),
        }
