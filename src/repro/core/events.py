"""Event-sourced control plane: one append-only, clock-stamped event log.

Every observable state change in the broker — provider lifecycle, breaker
transitions, dispatch/completion/skip, elastic acquisition, staging
transfers, admission decisions, chaos injections — is emitted as a
structured :class:`Event` onto a single :class:`EventBus`.  The legacy
per-subsystem stats dicts (``stream_stats``, ``scale_stats``,
``staging_stats``, ``group_rows``, ``admission.stats``) are *derived
views* over this log: the bus folds each event into a
:class:`MetricsView` at emit time, and the dict-shaped accessors read
the view (or, during migration, the legacy accumulators that the view
must agree with).

Design rules, mirroring :mod:`repro.core.ledger`:

* **Append is O(1)** — one lock acquire, one timestamp, one list append,
  one reducer step.  The dispatch hot path emits per *batch*, never per
  task, so exp9/exp11 throughput is unaffected beyond noise.
* **Reduce-on-emit** — the view is folded under the bus lock in
  sequence order.  Replaying the serialized log folds the same values in
  the same order, so every float in the derived metrics reconstructs
  bit-for-bit (Python floats round-trip exactly through ``json``).
* **Strict mode** — ``HYDRA_EVENTS_CHECK=1`` (the events twin of
  ``HYDRA_LEDGER_CHECK``) cross-checks the derived view against the
  legacy accumulators with a short retry loop; a persistent mismatch
  raises :class:`EventsDivergence` and is re-raised from
  ``Hydra.shutdown()`` so CI cannot miss it.

Record and replay::

    HYDRA_EVENTS_LOG=/tmp/run.jsonl python -m benchmarks.exp10_scenario
    python -m repro.core.events replay /tmp/run.jsonl

The JSONL header line embeds the live derived-metrics snapshot taken at
dump time; ``replay`` recomputes the metrics from the event records and
verifies they match the header bit-for-bit.

Env knobs (see docs/OBSERVABILITY.md):

* ``HYDRA_EVENTS_CHECK`` — non-empty/non-zero enables strict cross-checks.
* ``HYDRA_EVENTS_LOG``   — path prefix: each broker dumps its stream at
  shutdown (first broker writes the path verbatim, later ones ``.2``,
  ``.3``, ...).
* ``HYDRA_EVENTS_BUFFER`` — max retained events (0 = unbounded).  Views
  stay exact either way (they are reduced incrementally); only the
  replayable tail is capped, and dumps of a truncated log say so.
"""

from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
import threading
import time
from dataclasses import dataclass
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    NamedTuple,
    Optional,
    TextIO,
    Tuple,
)

from repro.runtime.clock import get_clock

__all__ = [
    "EVENTS",
    "Event",
    "EventBus",
    "EventSpec",
    "EventsDivergence",
    "MetricsView",
    "replay_jsonl",
]

JSONL_VERSION = 1


class EventsDivergence(AssertionError):
    """Raised when the log-derived view disagrees with a legacy accumulator.

    Subclasses ``AssertionError`` so strict mode fails tests loudly, same
    as :class:`repro.core.ledger.LedgerDivergence`.
    """


# ---------------------------------------------------------------------------
# Taxonomy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EventSpec:
    """One row of the event taxonomy (mirrored in docs/OBSERVABILITY.md)."""

    name: str
    fields: Tuple[str, ...]
    site: str  # emitting call site, "module.func"
    metrics: Tuple[str, ...]  # OTel-style derived metric names
    doc: str


def _spec(name: str, fields: str, site: str, metrics: str, doc: str) -> EventSpec:
    return EventSpec(
        name=name,
        fields=tuple(f for f in fields.split() if f),
        site=site,
        metrics=tuple(m for m in metrics.split() if m),
        doc=doc,
    )


#: The full event taxonomy: name -> spec.  ``tools/docs_check.py`` keeps
#: this table and the one in docs/OBSERVABILITY.md in lockstep.
EVENTS: Dict[str, EventSpec] = {
    s.name: s
    for s in (
        # -- provider lifecycle (broker.py) --------------------------------
        _spec(
            "provider.register",
            "provider slots group",
            "broker.register_provider",
            "hydra.provider.registered",
            "A provider (direct or group member) became dispatchable.",
        ),
        _spec(
            "provider.deregister",
            "provider reason",
            "broker.remove_provider",
            "hydra.provider.deregistered",
            "A provider was removed (drain, outage, or registration rollback).",
        ),
        _spec(
            "provider.blacklist",
            "provider",
            "broker._handle_provider_down",
            "hydra.provider.blacklisted",
            "A provider was marked unhealthy and excluded from placement.",
        ),
        # -- circuit breaker (group.py) ------------------------------------
        _spec(
            "breaker.transition",
            "member old new",
            "group._wire_member",
            "hydra.breaker.transitions",
            "A member circuit breaker moved between closed/open/half_open.",
        ),
        # -- dispatch (dispatcher.py) --------------------------------------
        _spec(
            "dispatch.batch",
            "n",
            "dispatcher._dispatch",
            "hydra.dispatch.batches hydra.dispatch.tasks",
            "One placed batch left the dispatcher (n = tasks in the batch).",
        ),
        _spec(
            "dispatch.retry",
            "",
            "dispatcher._retry",
            "hydra.dispatch.retry_backoffs",
            "Dispatch found no eligible provider and backed off.",
        ),
        _spec(
            "dispatch.loop_error",
            "",
            "dispatcher._loop",
            "hydra.dispatch.loop_errors",
            "The dispatch loop swallowed an unexpected exception.",
        ),
        # -- task terminal states (broker.py / group.py) -------------------
        _spec(
            "task.complete",
            "provider failed",
            "broker._on_task_done",
            "hydra.tasks.completed hydra.tasks.failed",
            "An ungrouped task reached a terminal done/failed state.",
        ),
        _spec(
            "task.skip",
            "provider",
            "broker._on_task_skipped",
            "hydra.tasks.skipped",
            "An ungrouped task was skipped (dependency failure upstream).",
        ),
        _spec(
            "group.dispatch",
            "group member n",
            "group.note_dispatch",
            "hydra.group.dispatched",
            "A batch of n tasks was handed to a group member.",
        ),
        _spec(
            "group.complete",
            "group member failed",
            "group.record_success/record_failure",
            "hydra.group.completed hydra.group.failed "
            "hydra.tasks.completed hydra.tasks.failed",
            "A grouped task reached a terminal done/failed state.",
        ),
        _spec(
            "group.skip",
            "group member",
            "group.record_skip",
            "hydra.group.skips hydra.tasks.skipped",
            "A grouped task was skipped after dispatch.",
        ),
        _spec(
            "group.member_join",
            "group member slots",
            "group.add_member",
            "hydra.group.member_joins",
            "A member joined a provider group (registration or hot-add).",
        ),
        _spec(
            "group.member_leave",
            "group member",
            "group.remove_member",
            "hydra.group.member_leaves",
            "A member left a provider group.",
        ),
        # -- backlog (broker.py) -------------------------------------------
        _spec(
            "backlog.enter",
            "n",
            "broker._submit_pipeline",
            "hydra.tasks.entered",
            "n tasks entered the broker backlog (post-admission).",
        ),
        _spec(
            "backlog.resolve",
            "",
            "broker._on_task_resolved",
            "hydra.tasks.resolved",
            "One backlog task resolved (done, failed, or canceled).",
        ),
        # -- elastic acquisition (autoscaler.py) ---------------------------
        _spec(
            "scale.tick",
            "pressure",
            "autoscaler._tick",
            "hydra.scale.ticks",
            "One autoscaler control-loop evaluation.",
        ),
        _spec(
            "acquire.begin",
            "instance platform",
            "autoscaler._acquire",
            "hydra.scale.acquisitions",
            "An instance acquisition was requested from a platform.",
        ),
        _spec(
            "acquire.complete",
            "instance",
            "autoscaler._arrive",
            "hydra.scale.arrivals",
            "An acquired instance arrived and registered.",
        ),
        _spec(
            "acquire.abort",
            "instance",
            "autoscaler._abort",
            "hydra.scale.aborts",
            "An in-flight acquisition was aborted before arrival.",
        ),
        _spec(
            "scale.release",
            "instance",
            "autoscaler._release",
            "hydra.scale.releases",
            "An idle elastic instance was released back to its platform.",
        ),
        # -- admission (admission.py) --------------------------------------
        _spec(
            "admission.accept",
            "tenant n",
            "admission.admit",
            "hydra.admission.admitted",
            "n tasks from one submission cleared the front door.",
        ),
        _spec(
            "admission.reject",
            "tenant reason",
            "admission._reject",
            "hydra.admission.rejected",
            "A submission was rejected (keyed by tenant:reason).",
        ),
        # -- staging: service level (staging.py) ---------------------------
        _spec(
            "stage.in",
            "task site missing",
            "staging.stage_task",
            "hydra.staging.stage_ins",
            "A task needed inputs pulled to its execution site.",
        ),
        _spec(
            "stage.wait",
            "task wait_s",
            "staging.stage_task.finish",
            "hydra.staging.transfer_wait_s",
            "A staged task waited wait_s (virtual) for its inputs.",
        ),
        _spec(
            "stage.out",
            "dataset site mb",
            "staging.task_completed",
            "hydra.staging.stage_outs",
            "A produced output was registered at its site.",
        ),
        _spec(
            "stage.drop",
            "dataset site",
            "staging.task_completed",
            "hydra.staging.stage_out_drops",
            "A produced output was dropped (site lost before stage-out).",
        ),
        _spec(
            "stage.mirror",
            "dataset mb",
            "staging.task_completed",
            "hydra.staging.mirrored_mb",
            "An output was mirrored to the durable store.",
        ),
        _spec(
            "stage.evacuate",
            "site mb",
            "staging.evacuate",
            "hydra.staging.evacuated_mb",
            "Replicas were evacuated off a draining site.",
        ),
        # -- staging: transfer engine (staging.py) -------------------------
        _spec(
            "transfer.hit",
            "dataset site",
            "staging.TransferEngine.fetch",
            "hydra.staging.cache_hits",
            "A fetch was satisfied by an already-resident replica.",
        ),
        _spec(
            "transfer.cold",
            "dataset dst",
            "staging.TransferEngine.fetch",
            "hydra.staging.cold_reads",
            "A fetch fell back to the durable store (no warm replica).",
        ),
        _spec(
            "transfer.start",
            "dataset src dst wait_s",
            "staging.TransferEngine._start",
            "hydra.staging.queue_wait_s",
            "A transfer left the queue after waiting wait_s (virtual).",
        ),
        _spec(
            "transfer.done",
            "dataset src dst mb",
            "staging.TransferEngine._complete",
            "hydra.staging.transfers hydra.staging.mb_moved",
            "A transfer finished and the replica landed at dst.",
        ),
        _spec(
            "transfer.fail",
            "dataset dst",
            "staging.TransferEngine._complete/site_down",
            "hydra.staging.transfer_failures",
            "A transfer failed (link fault, lost site, or unknown dataset).",
        ),
        _spec(
            "transfer.reroute",
            "dataset src dst",
            "staging.TransferEngine.site_down",
            "hydra.staging.reroutes",
            "An in-flight transfer was rerouted around a dead endpoint.",
        ),
        _spec(
            "replica.evict",
            "dataset site",
            "staging.ReplicaRegistry.place_replica",
            "hydra.staging.evictions",
            "An LRU replica was evicted to make room at a site.",
        ),
        # -- market (market.py) --------------------------------------------
        _spec(
            "market.plan",
            "demand chosen",
            "market.MarketPlanner.plan",
            "hydra.market.plans",
            "The bid loop produced a platform mix for the current demand.",
        ),
        _spec(
            "market.bid",
            "template price eff_slots",
            "market.MarketPlanner.plan",
            "hydra.market.bids",
            "One template was selected in a plan (keyed by template).",
        ),
        _spec(
            "market.price",
            "template price",
            "market.MarketPlanner.set_price",
            "hydra.market.reprices",
            "A template was repriced mid-run (spot market movement).",
        ),
        _spec(
            "market.spend",
            "instance node_s dollars",
            "market.MarketPlanner.settle",
            "hydra.cost_node_seconds hydra.cost_dollars",
            "An instance's occupancy was settled into the cost ledger.",
        ),
        # -- checkpoint/restore (ckpt/checkpoint.py) -----------------------
        _spec(
            "ckpt.save",
            "task dataset progress",
            "checkpoint.TaskCheckpointer.on_preempt",
            "hydra.ckpt.saves",
            "A preempted task's progress was captured as a replicated dataset.",
        ),
        _spec(
            "ckpt.resume",
            "task progress lost_s done_s",
            "checkpoint.TaskCheckpointer.on_preempt",
            "hydra.ckpt.resumes hydra.ckpt.reexecuted_s hydra.ckpt.preempted_work_s",
            "A preempted task will resume from its checkpoint, not from zero.",
        ),
        # -- kernels (kernels/autotune.py + broker) ------------------------
        _spec(
            "kernel.tune",
            "kernel sig config swept exhaustive",
            "autotune.Autotuner.tune",
            "hydra.kernel.tunes hydra.kernel.swept_configs",
            "A cache-miss sweep chose a tuned config (cache hits never re-emit).",
        ),
        _spec(
            "kernel.exec",
            "kernel reps kernel_s",
            "broker.Hydra._on_task_done",
            "hydra.kernel.execs hydra.kernel.reps hydra.kernel.seconds",
            "A kernel-payload task completed real Pallas work (keyed by kernel).",
        ),
        # -- chaos (chaos.py) ----------------------------------------------
        _spec(
            "chaos.inject",
            "kind target",
            "chaos.ChaosEngine._record",
            "hydra.chaos.injected",
            "A chaos fault (or its restore twin) was injected (keyed by kind).",
        ),
    )
}


# ---------------------------------------------------------------------------
# Events and the derived view
# ---------------------------------------------------------------------------


class Event(NamedTuple):
    """One immutable log record: sequence number, virtual time, name, attrs.

    A NamedTuple, not a dataclass: ``emit`` sits adjacent to every hot-path
    counter increment, and tuple construction is ~3x cheaper than a frozen
    dataclass ``__init__`` (which pays ``object.__setattr__`` per field).
    """

    seq: int
    t: float
    name: str
    attrs: Dict[str, Any]

    def to_json(self) -> str:
        return json.dumps(
            {"seq": self.seq, "t": self.t, "name": self.name, "attrs": self.attrs},
            sort_keys=True,
            separators=(",", ":"),
        )


def _canonical_key(e: Event) -> Tuple[float, str, str]:
    return (e.t, e.name, json.dumps(e.attrs, sort_keys=True))


class MetricsView:
    """Derived metrics folded from the event log.

    Two shapes, both commutative in the integer case and order-exact in
    the float case (the bus folds in seq order, replay folds in the same
    order):

    * ``counters``: OTel metric name -> number.
    * ``keyed``:    OTel metric name -> {attribute key: number}, for
      metrics broken out by member / tenant:reason / chaos kind.
    """

    __slots__ = ("counters", "keyed", "unknown")

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.keyed: Dict[str, Dict[str, float]] = {}
        self.unknown = 0

    # -- folding -----------------------------------------------------------

    def _bump(self, metric: str, by: float = 1) -> None:
        self.counters[metric] = self.counters.get(metric, 0) + by

    def _bump_keyed(self, metric: str, key: str, by: float = 1) -> None:
        d = self.keyed.setdefault(metric, {})
        d[key] = d.get(key, 0) + by

    def apply(self, name: str, attrs: Dict[str, Any]) -> None:
        fn = _REDUCERS.get(name)
        if fn is None:
            self.unknown += 1
            return
        fn(self, attrs)

    # -- reading -----------------------------------------------------------

    def get(self, metric: str, default: float = 0) -> float:
        return self.counters.get(metric, default)

    def keyed_get(self, metric: str) -> Dict[str, float]:
        return dict(self.keyed.get(metric, {}))

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready snapshot of every derived metric."""
        return {
            "counters": dict(sorted(self.counters.items())),
            "keyed": {m: dict(sorted(d.items())) for m, d in sorted(self.keyed.items())},
        }

    def flat(self) -> Dict[str, float]:
        """Flattened ``metric`` / ``metric:key`` -> value mapping."""
        out: Dict[str, float] = dict(self.counters)
        for metric, d in self.keyed.items():
            for key, val in d.items():
                out[f"{metric}:{key}"] = val
        return out


def _r_provider_register(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.provider.registered")


def _r_provider_deregister(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.provider.deregistered")


def _r_provider_blacklist(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.provider.blacklisted")


def _r_breaker_transition(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.breaker.transitions")
    v._bump_keyed("hydra.breaker.transitions", f"{a['old']}->{a['new']}")


def _r_dispatch_batch(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.dispatch.batches")
    v._bump("hydra.dispatch.tasks", a["n"])


def _r_dispatch_retry(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.dispatch.retry_backoffs")


def _r_dispatch_loop_error(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.dispatch.loop_errors")


def _r_task_complete(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.tasks.failed" if a.get("failed") else "hydra.tasks.completed")


def _r_task_skip(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.tasks.skipped")


def _r_group_dispatch(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump_keyed("hydra.group.dispatched", a["member"], a["n"])


def _r_group_complete(v: MetricsView, a: Dict[str, Any]) -> None:
    if a.get("failed"):
        v._bump_keyed("hydra.group.failed", a["member"])
        v._bump("hydra.tasks.failed")
    else:
        v._bump_keyed("hydra.group.completed", a["member"])
        v._bump("hydra.tasks.completed")


def _r_group_skip(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump_keyed("hydra.group.skips", a["member"])
    v._bump("hydra.tasks.skipped")


def _r_group_member_join(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.group.member_joins")


def _r_group_member_leave(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.group.member_leaves")


def _r_backlog_enter(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.tasks.entered", a["n"])


def _r_backlog_resolve(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.tasks.resolved")


def _r_scale_tick(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.scale.ticks")


def _r_acquire_begin(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.scale.acquisitions")


def _r_acquire_complete(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.scale.arrivals")


def _r_acquire_abort(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.scale.aborts")


def _r_scale_release(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.scale.releases")


def _r_admission_accept(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.admission.admitted", a["n"])


def _r_admission_reject(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump_keyed("hydra.admission.rejected", f"{a['tenant']}:{a['reason']}")


def _r_stage_in(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.stage_ins")


def _r_stage_wait(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.transfer_wait_s", a["wait_s"])


def _r_stage_out(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.stage_outs")


def _r_stage_drop(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.stage_out_drops")


def _r_stage_mirror(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.mirrored_mb", a["mb"])


def _r_stage_evacuate(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.evacuated_mb", a["mb"])


def _r_transfer_hit(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.cache_hits")


def _r_transfer_cold(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.cold_reads")


def _r_transfer_start(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.queue_wait_s", a["wait_s"])


def _r_transfer_done(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.transfers")
    v._bump("hydra.staging.mb_moved", a["mb"])


def _r_transfer_fail(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.transfer_failures")


def _r_transfer_reroute(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.reroutes")


def _r_replica_evict(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.staging.evictions")


def _r_market_plan(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.market.plans")


def _r_market_bid(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.market.bids")
    v._bump_keyed("hydra.market.bids", a["template"])


def _r_market_price(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.market.reprices")


def _r_market_spend(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.cost_node_seconds", a["node_s"])
    v._bump("hydra.cost_dollars", a["dollars"])


def _r_ckpt_save(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.ckpt.saves")


def _r_ckpt_resume(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.ckpt.resumes")
    v._bump("hydra.ckpt.reexecuted_s", a["lost_s"])
    v._bump("hydra.ckpt.preempted_work_s", a["done_s"])


def _r_kernel_tune(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.kernel.tunes")
    v._bump("hydra.kernel.swept_configs", a["swept"])


def _r_kernel_exec(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump("hydra.kernel.execs")
    v._bump_keyed("hydra.kernel.execs", a["kernel"])
    v._bump("hydra.kernel.reps", a["reps"])
    v._bump("hydra.kernel.seconds", a["kernel_s"])


def _r_chaos_inject(v: MetricsView, a: Dict[str, Any]) -> None:
    v._bump_keyed("hydra.chaos.injected", a["kind"])


_REDUCERS: Dict[str, Callable[[MetricsView, Dict[str, Any]], None]] = {
    "provider.register": _r_provider_register,
    "provider.deregister": _r_provider_deregister,
    "provider.blacklist": _r_provider_blacklist,
    "breaker.transition": _r_breaker_transition,
    "dispatch.batch": _r_dispatch_batch,
    "dispatch.retry": _r_dispatch_retry,
    "dispatch.loop_error": _r_dispatch_loop_error,
    "task.complete": _r_task_complete,
    "task.skip": _r_task_skip,
    "group.dispatch": _r_group_dispatch,
    "group.complete": _r_group_complete,
    "group.skip": _r_group_skip,
    "group.member_join": _r_group_member_join,
    "group.member_leave": _r_group_member_leave,
    "backlog.enter": _r_backlog_enter,
    "backlog.resolve": _r_backlog_resolve,
    "scale.tick": _r_scale_tick,
    "acquire.begin": _r_acquire_begin,
    "acquire.complete": _r_acquire_complete,
    "acquire.abort": _r_acquire_abort,
    "scale.release": _r_scale_release,
    "admission.accept": _r_admission_accept,
    "admission.reject": _r_admission_reject,
    "stage.in": _r_stage_in,
    "stage.wait": _r_stage_wait,
    "stage.out": _r_stage_out,
    "stage.drop": _r_stage_drop,
    "stage.mirror": _r_stage_mirror,
    "stage.evacuate": _r_stage_evacuate,
    "transfer.hit": _r_transfer_hit,
    "transfer.cold": _r_transfer_cold,
    "transfer.start": _r_transfer_start,
    "transfer.done": _r_transfer_done,
    "transfer.fail": _r_transfer_fail,
    "transfer.reroute": _r_transfer_reroute,
    "replica.evict": _r_replica_evict,
    "market.plan": _r_market_plan,
    "market.bid": _r_market_bid,
    "market.price": _r_market_price,
    "market.spend": _r_market_spend,
    "ckpt.save": _r_ckpt_save,
    "ckpt.resume": _r_ckpt_resume,
    "kernel.tune": _r_kernel_tune,
    "kernel.exec": _r_kernel_exec,
    "chaos.inject": _r_chaos_inject,
}

assert set(_REDUCERS) == set(EVENTS), "taxonomy and reducers out of sync"


# ---------------------------------------------------------------------------
# The bus
# ---------------------------------------------------------------------------

_log_path_counter = itertools.count(1)


def next_log_path(base: str) -> str:
    """Resolve the dump path for the next broker under HYDRA_EVENTS_LOG.

    The first broker in the process writes ``base`` verbatim; later ones
    get ``base.2``, ``base.3``, ... so concurrent brokers (e.g. the
    chaos run and its fault-free twin) never clobber each other.
    """
    n = next(_log_path_counter)
    return base if n == 1 else f"{base}.{n}"


class EventBus:
    """Append-only broker event log with an incrementally-folded view.

    ``emit`` is the only write path: it stamps the event with the active
    clock (virtual under ``virtual_time()``), appends it, and folds it
    into :attr:`view` — all under one lock, so view state is always a
    prefix-fold of the log in sequence order.
    """

    def __init__(self, strict: Optional[bool] = None, buffer: Optional[int] = None):
        if strict is None:
            strict = os.environ.get("HYDRA_EVENTS_CHECK", "") not in ("", "0")
        if buffer is None:
            try:
                buffer = int(os.environ.get("HYDRA_EVENTS_BUFFER", "0"))
            except ValueError:
                buffer = 0
        self.strict = bool(strict)
        self.buffer = max(0, buffer)
        self.view = MetricsView()
        # raw (seq, t, name, attrs) tuples; rehydrated as Event on read
        self._events: List[Tuple[int, float, str, Dict[str, Any]]] = []
        self._seq = 0
        self._dropped = 0
        self._lock = threading.Lock()
        self._recompute: Optional[Callable[[], Dict[str, float]]] = None
        self.divergences = 0
        self.last_divergence: Optional[str] = None

    # -- write path --------------------------------------------------------

    def emit(self, name: str, **attrs: Any) -> None:
        """Append one event and fold it into the derived view. O(1).

        Hot path: this call sits adjacent to every instrumented counter
        increment (~3 emits per dispatched task on the staged fast path),
        so the reducer is resolved before the lock, the fold is inlined
        (skipping ``MetricsView.apply``'s extra dispatch hop), and records
        are appended as plain tuples — ``Event`` is a NamedTuple precisely
        so the read paths can rehydrate ``Event(*raw)`` for free while the
        write path skips NamedTuple ``__new__``.  Timestamps come from
        ``Clock.stamp()`` (lock-free) rather than ``now()``: three emits
        per task contending on the VirtualClock condition was the single
        largest bus cost on the dispatch hot path.
        """
        t = get_clock().stamp()
        fn = _REDUCERS.get(name)
        events = self._events
        view = self.view
        with self._lock:
            self._seq += 1
            events.append((self._seq, t, name, attrs))
            if self.buffer and len(events) > self.buffer:
                del events[0]
                self._dropped += 1
            if fn is None:
                view.unknown += 1
            else:
                fn(view, attrs)

    # -- read path ---------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        return self._dropped

    def events(self) -> List[Event]:
        with self._lock:
            raw = list(self._events)
        return [Event(*e) for e in raw]

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return self.view.snapshot()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "n_events": self._seq,
                "retained": len(self._events),
                "dropped": self._dropped,
                "strict": self.strict,
                "divergences": self.divergences,
            }

    # -- serialization -----------------------------------------------------

    def dump_jsonl(self, path_or_file) -> Dict[str, Any]:
        """Serialize the retained log (seq order) plus a header snapshot.

        The header line carries the derived-metrics snapshot taken
        atomically with the event copy, so ``replay`` can verify the
        reconstruction bit-for-bit.  Returns the header dict.
        """
        with self._lock:
            raw = list(self._events)
            header = {
                "hydra_events_version": JSONL_VERSION,
                "n_events": self._seq,
                "retained": len(raw),
                "dropped": self._dropped,
                "snapshot": self.view.snapshot(),
            }
        events = [Event(*e) for e in raw]
        if hasattr(path_or_file, "write"):
            self._write_stream(path_or_file, header, events)
        else:
            with open(path_or_file, "w", encoding="utf-8") as fh:
                self._write_stream(fh, header, events)
        return header

    @staticmethod
    def _write_stream(fh: TextIO, header: Dict[str, Any], events: List[Event]) -> None:
        fh.write(json.dumps(header, sort_keys=True, separators=(",", ":")) + "\n")
        for e in events:
            fh.write(e.to_json() + "\n")

    def canonical_jsonl(self) -> str:
        """Interleaving-independent serialization for cross-run comparison.

        Drops ``seq`` (assigned in arrival order, which thread scheduling
        may permute between identically-seeded runs) and sorts records by
        (t, name, attrs).  Two runs of a deterministic workload produce
        byte-identical canonical streams.
        """
        with self._lock:
            raw = list(self._events)
        rows = sorted((Event(*e) for e in raw), key=_canonical_key)
        return "".join(
            json.dumps(
                {"t": e.t, "name": e.name, "attrs": e.attrs},
                sort_keys=True,
                separators=(",", ":"),
            )
            + "\n"
            for e in rows
        )

    # -- strict cross-check (HYDRA_EVENTS_CHECK=1) -------------------------

    def attach(self, recompute: Callable[[], Dict[str, float]]) -> None:
        """Install the legacy-accumulator recompute used by :meth:`check`.

        ``recompute`` returns a flat mapping ``metric`` / ``metric:key``
        -> value built from the legacy counters; only keys it returns are
        compared, so subsystems that are not wired (no autoscaler, no
        groups) simply contribute nothing.
        """
        self._recompute = recompute

    def _diff(self) -> Dict[str, Tuple[float, float]]:
        if self._recompute is None:
            return {}
        legacy = self._recompute()  # outside the bus lock: lock-order discipline
        with self._lock:
            derived = self.view.flat()
        out = {}
        for key, want in legacy.items():
            got = derived.get(key, 0)
            if got != want:
                out[key] = (want, got)
        return out

    def check(self, retries: int = 30, retry_sleep_s: float = 0.002) -> None:
        """Compare the derived view against the legacy accumulators.

        Emission happens adjacent to (not atomically with) each legacy
        increment, so a reader can land between the two; the retry loop
        absorbs those transients exactly like the ledger's.  A mismatch
        that survives the retries is recorded and raised.
        """
        if self._recompute is None:
            return
        diff = self._diff()
        for _ in range(retries):
            if not diff:
                return
            time.sleep(retry_sleep_s)
            diff = self._diff()
        msg = "derived view diverged from legacy accumulators: " + ", ".join(
            f"{k}: legacy={want!r} derived={got!r}"
            for k, (want, got) in sorted(diff.items())
        )
        self.divergences += 1
        self.last_divergence = msg
        raise EventsDivergence(msg)

    def maybe_check(self) -> None:
        """Strict-mode hook for the stats accessors: check, record, re-raise."""
        if not self.strict:
            return
        self.check()


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


def replay_jsonl(lines: Iterable[str]) -> Tuple[MetricsView, Dict[str, Any]]:
    """Fold a serialized event stream back into a fresh MetricsView.

    Returns ``(view, header)`` where ``header`` is the dump-time metadata
    (empty dict if the stream has no header line).  Records are folded in
    file order, which ``dump_jsonl`` guarantees is sequence order, so
    every derived float reconstructs bit-for-bit.
    """
    view = MetricsView()
    header: Dict[str, Any] = {}
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        if i == 0 and "hydra_events_version" in rec:
            header = rec
            continue
        view.apply(rec["name"], rec.get("attrs", {}))
    return view, header


def verify_replay(path: str) -> Tuple[bool, Dict[str, Any], Dict[str, Any]]:
    """Replay ``path`` and compare against its embedded header snapshot.

    Returns ``(ok, replayed_snapshot, header)``.  ``ok`` is False when
    the recomputed metrics differ from the dump-time snapshot (stream
    mutated or truncated) or when the header is missing/incomplete.
    """
    with open(path, encoding="utf-8") as fh:
        view, header = replay_jsonl(fh)
    replayed = view.snapshot()
    want = header.get("snapshot")
    ok = bool(header) and not header.get("dropped") and replayed == want
    return ok, replayed, header


def _diff_snapshots(a: Dict[str, Any], b: Dict[str, Any]) -> List[str]:
    fa = dict(a.get("counters", {}))
    for m, d in a.get("keyed", {}).items():
        fa.update({f"{m}:{k}": val for k, val in d.items()})
    fb = dict(b.get("counters", {}))
    for m, d in b.get("keyed", {}).items():
        fb.update({f"{m}:{k}": val for k, val in d.items()})
    out = []
    for key in sorted(set(fa) | set(fb)):
        va, vb = fa.get(key, 0), fb.get(key, 0)
        if va != vb:
            out.append(f"{key}: {va!r} != {vb!r}")
    return out


# ---------------------------------------------------------------------------
# CLI: python -m repro.core.events {replay,diff,taxonomy}
# ---------------------------------------------------------------------------


def _cmd_replay(args: argparse.Namespace) -> int:
    ok, replayed, header = verify_replay(args.log)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(replayed, fh, indent=2, sort_keys=True)
            fh.write("\n")
    n = header.get("retained", "?")
    if not header:
        print(f"replay: {args.log}: no header line — cannot self-verify", file=sys.stderr)
        return 2
    if header.get("dropped"):
        print(
            f"replay: {args.log}: {header['dropped']} events dropped by "
            "HYDRA_EVENTS_BUFFER — log is partial, snapshot not reconstructible",
            file=sys.stderr,
        )
        return 2
    if ok:
        print(f"replay: {args.log}: {n} events -> derived metrics bit-identical to snapshot")
        return 0
    print(f"replay: {args.log}: DIVERGED from dump-time snapshot:", file=sys.stderr)
    for line in _diff_snapshots(header.get("snapshot", {}), replayed):
        print(f"  {line}", file=sys.stderr)
    return 1


def _cmd_diff(args: argparse.Namespace) -> int:
    snaps = []
    for path in (args.a, args.b):
        with open(path, encoding="utf-8") as fh:
            view, _header = replay_jsonl(fh)
        snaps.append(view.snapshot())
    lines = _diff_snapshots(snaps[0], snaps[1])
    if not lines:
        print(f"diff: {args.a} and {args.b} derive identical metrics")
        return 0
    print(f"diff: {len(lines)} metrics differ ({args.a} vs {args.b}):")
    for line in lines:
        print(f"  {line}")
    return 1


def _cmd_taxonomy(_args: argparse.Namespace) -> int:
    for name in sorted(EVENTS):
        spec = EVENTS[name]
        fields = " ".join(spec.fields) or "-"
        print(f"{name:22s} fields=[{fields}] site={spec.site} -> {' '.join(spec.metrics)}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.events",
        description="Replay and inspect Hydra broker event logs (JSONL).",
    )
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_replay = sub.add_parser("replay", help="replay a log; verify metrics vs its snapshot")
    p_replay.add_argument("log", help="JSONL event log (from HYDRA_EVENTS_LOG or dump_jsonl)")
    p_replay.add_argument("--json", help="write the replayed metrics snapshot to this path")
    p_replay.set_defaults(fn=_cmd_replay)
    p_diff = sub.add_parser("diff", help="diff the derived metrics of two logs")
    p_diff.add_argument("a")
    p_diff.add_argument("b")
    p_diff.set_defaults(fn=_cmd_diff)
    p_tax = sub.add_parser("taxonomy", help="print the event taxonomy")
    p_tax.set_defaults(fn=_cmd_taxonomy)
    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess in tests
    raise SystemExit(main())
