"""Hydra: the broker facade (paper §3).

    hydra = Hydra(policy="round_robin", pod_store="memory", partitioning="mcpp")
    hydra.register_provider(ProviderSpec(name="jet2", platform="cloud", ...))
    hydra.register_provider(ProviderSpec(name="bridges2", platform="hpc", connector="pilot"))
    sub = hydra.submit(tasks)
    sub.wait()
    print(sub.metrics().row())
    hydra.shutdown()

Responsibilities (mirroring the paper's Service Proxy):
  * bind tasks to providers via the configured policy,
  * partition per-provider workloads into pods (SCPP/MCPP/binpack),
  * serialize pods via the configured store (disk = faithful baseline,
    memory = the paper's named optimization),
  * bulk-submit pods to each provider's manager CONCURRENTLY,
  * monitor execution, drive retries / re-binding / blacklisting /
    speculative straggler copies, and
  * compute OVH / TH / TPT / TTX from the traces.
"""
from __future__ import annotations

import os
import tempfile
import threading
from concurrent.futures import ThreadPoolExecutor, wait as futures_wait
from typing import Optional

from repro.core.fault import StragglerWatchdog, clone_for_speculation
from repro.core.managers.compute import CaaSManager, ProviderDown
from repro.core.managers.data import DataManager
from repro.core.managers.pilot import PilotManager
from repro.core.partition import partition
from repro.core.pod import Pod, make_store
from repro.core.policy import Policy, make_policy
from repro.core.provider import ProviderHandle, ProviderProxy, ProviderSpec
from repro.core.task import Task, TaskState
from repro.runtime.tracing import Metrics, Trace, compute_metrics, now


class Submission:
    """Handle for one submit() call: tasks + pods + the broker run trace."""

    def __init__(self, tasks: list[Task], broker: "Hydra"):
        self.tasks = tasks
        self.pods: list[Pod] = []
        self.run_trace = Trace()
        self._broker = broker

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else now() + timeout
        for t in self.tasks:
            remaining = None if deadline is None else max(0.0, deadline - now())
            try:
                t.exception(timeout=remaining)
            except BaseException:  # TimeoutError / CancelledError / task error
                pass
            if deadline is not None and now() > deadline and not t.final:
                return False
        return True

    def metrics(self) -> Metrics:
        return compute_metrics(self.run_trace, self.tasks, self.pods)

    @property
    def states(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for t in self.tasks:
            out[t.tstate.value] = out.get(t.tstate.value, 0) + 1
        return out


class Hydra:
    def __init__(
        self,
        policy: str = "round_robin",
        pod_store: str = "memory",
        partitioning: str = "mcpp",
        tasks_per_pod: int = 64,
        workdir: Optional[str] = None,
        enable_straggler_mitigation: bool = False,
        straggler_factor: float = 3.0,
        fail_fast: bool = False,
    ):
        self.workdir = workdir or tempfile.mkdtemp(prefix="hydra_")
        os.makedirs(self.workdir, exist_ok=True)
        self.proxy = ProviderProxy()
        self.policy: Policy = make_policy(policy)
        self.store = make_store(pod_store, self.workdir)
        self.partitioning = partitioning
        self.tasks_per_pod = tasks_per_pod
        self.fail_fast = fail_fast
        self.data = DataManager(os.path.join(self.workdir, "data"))
        self._managers: dict[str, object] = {}
        self._lock = threading.RLock()
        self._fault_lock = threading.RLock()  # serializes orphan collection/rebind
        self._claimed: set[str] = set()  # task uids currently being re-bound
        self._dispatch = ThreadPoolExecutor(max_workers=8, thread_name_prefix="hydra-dispatch")
        self._submissions: list[Submission] = []
        self.watchdog: Optional[StragglerWatchdog] = None
        if enable_straggler_mitigation:
            self.watchdog = StragglerWatchdog(
                running=self._running_tasks,
                duplicate=self._speculate,
                factor=straggler_factor,
            )
            self.watchdog.start()

    def _running_tasks(self) -> list[Task]:
        with self._lock:
            return [
                t
                for sub in self._submissions
                for t in sub.tasks
                if t.tstate == TaskState.RUNNING
            ]

    # ------------------------------------------------------------------
    # Provider lifecycle (elastic: add/remove at runtime)
    # ------------------------------------------------------------------
    def register_provider(self, spec: ProviderSpec) -> ProviderHandle:
        handle = self.proxy.register(spec)
        mgr_cls = PilotManager if spec.connector == "pilot" else CaaSManager
        with self._lock:
            self._managers[spec.name] = mgr_cls(handle, on_task_done=self._on_task_done)
        self.data.register_site(spec.name)
        return handle

    def remove_provider(self, name: str, drain: bool = True):
        """Elastic scale-down: stop a provider; re-bind its unfinished tasks."""
        with self._lock:
            mgr = self._managers.pop(name)
            handle = self.proxy.get(name)
            handle.healthy = False
        mgr.fail()  # reject anything in flight
        with self._fault_lock:
            orphans = self._collect_orphans(name)
            self._rebind_and_resubmit(orphans, exclude=name)
        mgr.shutdown(wait=drain)

    def providers(self) -> list[str]:
        return [h.name for h in self.proxy.healthy()]

    def manager(self, name: str):
        return self._managers[name]

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(
        self,
        tasks: list[Task],
        partitioning: Optional[str] = None,
        tasks_per_pod: Optional[int] = None,
    ) -> Submission:
        model = partitioning or self.partitioning
        tpp = tasks_per_pod or self.tasks_per_pod
        sub = Submission(tasks, self)
        with self._lock:
            self._submissions.append(sub)
        rt = sub.run_trace

        # -- bind ----------------------------------------------------------
        rt.add("bind_start")
        healthy = self.proxy.healthy()
        if not healthy:
            raise RuntimeError("no healthy providers registered")
        by_provider: dict[str, list[Task]] = {}
        names = self.policy.bind_bulk(tasks, healthy)
        for t, name in zip(tasks, names):
            t.provider = name
            t.advance(TaskState.BOUND)
            by_provider.setdefault(name, []).append(t)
        rt.add("bind_done")

        # -- partition -------------------------------------------------------
        rt.add("partition_start")
        pods: list[Pod] = []
        for name, ts in by_provider.items():
            ppods = partition(ts, name, model=model, tasks_per_pod=tpp)
            for p in ppods:
                for t in p.tasks:
                    t.advance(TaskState.PARTITIONED)
            pods.extend(ppods)
        sub.pods.extend(pods)
        rt.add("partition_done")

        # -- serialize ---------------------------------------------------------
        rt.add("serialize_start")
        for p in pods:
            self.store.serialize(p)
        rt.add("serialize_done")

        # -- bulk submit (concurrently across providers) -----------------------
        rt.add("submit_start")
        per_provider: dict[str, list[Pod]] = {}
        for p in pods:
            per_provider.setdefault(p.provider, []).append(p)
        futs = [
            self._dispatch.submit(self._submit_to_provider, name, ppods)
            for name, ppods in per_provider.items()
        ]
        futures_wait(futs)
        for f in futs:
            exc = f.exception()
            if exc is not None and not isinstance(exc, ProviderDown):
                raise exc
        rt.add("submit_done")
        return sub

    def _submit_to_provider(self, name: str, pods: list[Pod]):
        try:
            self._managers[name].submit_pods(pods)
        except ProviderDown:
            self._handle_provider_down(name)
            raise

    # ------------------------------------------------------------------
    # Completion / fault handling
    # ------------------------------------------------------------------
    def _on_task_done(self, task: Task, provider: str, failed: bool):
        t0, t1 = task.trace.first("exec_start"), task.trace.last("exec_done")
        if t0 is not None and t1 is not None:
            self.policy.observe(provider, t1 - t0)
            if self.watchdog:
                self.watchdog.observe_completion(t1 - t0)
        else:
            self.policy.observe(provider, 1e-3)
        if not failed:
            return
        exc = getattr(task, "last_error", None)
        if isinstance(exc, ProviderDown):
            self._handle_provider_down(provider)
            return
        with self._fault_lock:
            if task.uid in self._claimed or task.tstate != TaskState.FAILED:
                return  # already claimed / re-bound / finished elsewhere
            if task.retries < task.max_retries:
                self._claimed.add(task.uid)
                task.reset_for_retry()
            else:
                if self.fail_fast:
                    self._cancel_all_pending()
                return
            self._rebind_and_resubmit([task], exclude=provider)

    def _handle_provider_down(self, name: str):
        with self._lock:
            handle = self.proxy.get(name)
            if handle.healthy:
                handle.healthy = False
                handle.trace.add("blacklisted")
        # always sweep for orphans: late ProviderDown failures arrive after
        # the initial blacklisting and still need re-binding
        with self._fault_lock:
            orphans = self._collect_orphans(name)
            self._rebind_and_resubmit(orphans, exclude=name)

    def _collect_orphans(self, provider: str) -> list[Task]:
        """Claim + reset every non-final task bound to a dead provider.
        Must be called under _fault_lock; claims prevent double re-binding."""
        with self._lock:
            orphans = [
                t
                for sub in self._submissions
                for t in sub.tasks
                if t.provider == provider
                and t.uid not in self._claimed
                # FAILED is a *final* state but retryable: include it here
                and (not t.final or t.tstate == TaskState.FAILED)
            ]
            self._claimed.update(t.uid for t in orphans)
        out = []
        for t in orphans:
            # force non-final tasks back to a BOUND-able state
            if t.tstate == TaskState.RUNNING:
                from repro.core.managers.compute import ProviderDown as PD

                t.mark_failed(PD(provider))
            if t.tstate == TaskState.FAILED:
                if t.retries >= t.max_retries:
                    self._release_claim(t)
                    continue
                t.reset_for_retry()
            elif t.tstate in (TaskState.SUBMITTED, TaskState.PARTITIONED):
                t.try_advance(TaskState.BOUND)
            elif t.tstate == TaskState.DONE:  # finished in the race window
                self._release_claim(t)
                continue
            out.append(t)
        return out

    def _release_claim(self, task: Task):
        with self._lock:
            self._claimed.discard(task.uid)

    def _rebind_and_resubmit(self, tasks: list[Task], exclude: Optional[str] = None):
        if not tasks:
            return
        healthy = [h for h in self.proxy.healthy() if h.name != exclude]
        if not healthy:
            for t in tasks:
                if not t.done():
                    t.set_exception(RuntimeError("no healthy providers for retry"))
            return
        by_provider: dict[str, list[Task]] = {}
        for t in tasks:
            name = self.policy.bind(t, healthy)
            t.provider = name
            t.trace.add(f"rebound:{name}")
            by_provider.setdefault(name, []).append(t)
        for name, ts in by_provider.items():
            pods = partition(ts, name, model="mcpp", tasks_per_pod=self.tasks_per_pod)
            for p in pods:
                for t in p.tasks:
                    # a task may have completed in the race window (authoritative
                    # completion); the pod runner skips final tasks
                    t.try_advance(TaskState.PARTITIONED)
                    self._release_claim(t)  # re-claimable if this provider dies too
                self.store.serialize(p)
            self._dispatch.submit(self._submit_to_provider, name, pods)

    def _speculate(self, task: Task):
        """Straggler: launch a speculative clone on a different provider."""
        healthy = [h for h in self.proxy.healthy() if h.name != task.provider]
        if not healthy:
            return
        shadow = clone_for_speculation(task)
        name = self.policy.bind(shadow, healthy)
        shadow.provider = name
        shadow.advance(TaskState.BOUND)
        pods = partition([shadow], name, model="scpp")
        for p in pods:
            shadow.advance(TaskState.PARTITIONED)
            self.store.serialize(p)
        self._dispatch.submit(self._submit_to_provider, name, pods)

    def _cancel_all_pending(self):
        with self._lock:
            for sub in self._submissions:
                for t in sub.tasks:
                    if not t.final:
                        t.mark_canceled()

    # ------------------------------------------------------------------
    def shutdown(self, wait: bool = True):
        """Graceful teardown of every instantiated resource (paper §3.2)."""
        if self.watchdog:
            self.watchdog.stop()
        with self._lock:
            managers = list(self._managers.values())
        for m in managers:
            m.shutdown(wait=wait)
        self._dispatch.shutdown(wait=wait)
        self.store.cleanup()
