"""Hydra: the broker facade (paper §3).

    hydra = Hydra(policy="round_robin", pod_store="memory", partitioning="mcpp")
    hydra.register_provider(ProviderSpec(name="jet2", platform="cloud", ...))
    hydra.register_provider(ProviderSpec(name="bridges2", platform="hpc", connector="pilot"))
    sub = hydra.submit(tasks)
    sub.wait()
    print(sub.metrics().row())
    hydra.shutdown()

Responsibilities (mirroring the paper's Service Proxy):
  * bind tasks to providers — or to ProviderGroups, logical load-balanced
    pools whose concrete member is resolved at dispatch time — via the
    configured policy,
  * partition per-provider workloads into pods (SCPP/MCPP/binpack),
  * serialize pods via the configured store (disk = faithful baseline,
    memory = the paper's named optimization),
  * bulk-submit pods to each provider's manager CONCURRENTLY,
  * monitor execution, drive retries / re-binding / blacklisting /
    per-member circuit breakers / transparent in-group failover /
    speculative straggler copies, and
  * compute OVH / TH / TPT / TTX from the traces (plus per-member group
    rows via ``group_rows()``).
"""
from __future__ import annotations

import os
import tempfile
import threading
from concurrent.futures import ThreadPoolExecutor, wait as futures_wait
from typing import Optional

from repro.core.admission import AdmissionController, TenantSpec
from repro.core.dispatcher import StreamingDispatcher
from repro.core.events import EventBus, EventsDivergence, next_log_path
from repro.core.fault import BreakerState, StragglerWatchdog, clone_for_speculation
from repro.core.group import GroupExhausted, ProviderGroup
from repro.core.ledger import CapacityLedger, LedgerDivergence
from repro.core.managers.compute import CaaSManager, ProviderDown
from repro.core.managers.data import DataManager
from repro.core.managers.pilot import PilotManager
from repro.core.partition import partition
from repro.core.pod import Pod, make_store
from repro.core.policy import Policy, make_policy
from repro.core.provider import ProviderHandle, ProviderProxy, ProviderSpec
from repro.core.staging import LinkModel, StagingService
from repro.core.task import Task, TaskState
from repro.runtime.clock import guard_wait
from repro.runtime.tracing import Metrics, Trace, compute_metrics, now


class Submission:
    """Handle for one submit() call: tasks + pods + the broker run trace."""

    def __init__(self, tasks: list[Task], broker: "Hydra"):
        self.tasks = tasks
        self.pods: list[Pod] = []
        self.run_trace = Trace()
        self.dispatch_started = False  # pods handed to providers (rollback gate)
        self.batch_id: Optional[str] = None  # set for dispatcher micro-batches
        self._broker = broker
        self._all_done: Optional[threading.Event] = None  # lazy, built once
        self._wait_lock = threading.Lock()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until every task's future resolves.  Completion callbacks
        count down into one event (registered ONCE per submission, so a
        polling ``while not sub.wait(1): ...`` loop does not accumulate
        callbacks); the timeout is a *guard* measured on both the active
        clock and real time (runtime/clock.guard_wait), so a virtual-clock
        run neither hangs forever on a frozen clock nor times out spuriously
        while real work is still executing."""
        with self._wait_lock:
            if self._all_done is None:
                self._all_done = threading.Event()
                unresolved = [t for t in self.tasks if not t.done()]
                if not unresolved:
                    self._all_done.set()
                else:
                    left = {"n": len(unresolved)}
                    lock = threading.Lock()
                    all_done = self._all_done

                    def _one_done(_fut):
                        with lock:
                            left["n"] -= 1
                            if left["n"] == 0:
                                all_done.set()

                    for t in unresolved:  # fires immediately if already resolved
                        t.add_done_callback(_one_done)

        def _in_flight() -> bool:
            # tasks on their way to / executing on a provider: the guard's
            # virtual-idle valve must stay closed while pure-CPU work (which
            # never touches the clock) is still running (runtime/clock.py)
            return any(
                t.tstate in (TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING)
                for t in self.tasks
            )

        return guard_wait(self._all_done, timeout, in_flight=_in_flight)

    def metrics(self) -> Metrics:
        return compute_metrics(self.run_trace, self.tasks, self.pods)

    @property
    def states(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for t in self.tasks:
            out[t.tstate.value] = out.get(t.tstate.value, 0) + 1
        return out


class Hydra:
    def __init__(
        self,
        policy: str = "round_robin",
        pod_store: str = "memory",
        partitioning: str = "mcpp",
        tasks_per_pod: int = 64,
        workdir: Optional[str] = None,
        enable_straggler_mitigation: bool = False,
        straggler_factor: float = 3.0,
        fail_fast: bool = False,
        streaming: bool = False,
        batch_window: float = 0.002,
        max_batch: int = 256,
        staging_seed: int = 0,
        site_capacity_mb: Optional[float] = None,
        staging_links: Optional[dict[tuple[str, str], LinkModel]] = None,
        staging_max_per_link: int = 2,
        staging_mirror_outputs: bool = False,
        tenants: Optional[list[TenantSpec]] = None,
    ):
        self.workdir = workdir or tempfile.mkdtemp(prefix="hydra_")
        os.makedirs(self.workdir, exist_ok=True)
        self.proxy = ProviderProxy()
        self.policy: Policy = make_policy(policy)
        self.policy.attach_proxy(self.proxy)  # O(1) eligibility index keying
        # the O(1) capacity counter set (core/ledger.py): every supply/demand
        # read the dispatcher and autoscaler make per tick used to be a scan
        # over bind targets / live submissions; now it is a counter read,
        # maintained by the events below (register/remove, breaker
        # transitions, dispatch/completion load deltas, acquisitions, task
        # entry/resolution).  HYDRA_LEDGER_CHECK=1 (tests/conftest.py) makes
        # every read cross-check against a from-scratch recompute.
        self.ledger = CapacityLedger(
            strict=os.environ.get("HYDRA_LEDGER_CHECK", "") not in ("", "0")
        )
        self.ledger.attach(
            recompute=self._ledger_recompute, on_capacity_gain=self._notify_capacity
        )
        # the event-sourced control plane (core/events.py): every counter the
        # legacy stats dicts accumulate is also emitted as a structured event
        # onto this bus, and the stats accessors are derived views over the
        # log.  HYDRA_EVENTS_CHECK=1 (tests/conftest.py) cross-checks view vs
        # legacy on every stats read and at shutdown; HYDRA_EVENTS_LOG dumps
        # the replayable JSONL stream at shutdown (docs/OBSERVABILITY.md).
        self.events = EventBus()
        self.events.attach(self._events_recompute)
        self.store = make_store(pod_store, self.workdir)
        self.partitioning = partitioning
        self.tasks_per_pod = tasks_per_pod
        self.fail_fast = fail_fast
        self.n_submits = 0  # full bind/partition/serialize/dispatch rounds
        self.n_pods_total = 0  # cumulative: survives submission pruning
        self.streaming = streaming
        self._batch_window = batch_window
        self._max_batch = max_batch
        # multi-tenant front door (core/admission.py): rate limits, bounded
        # queues, and the fair-share weights the dispatcher's lane drain
        # reads.  None (no tenant config) means NO admission anywhere — the
        # pre-front-door fast path, bit-identical behavior and cost.
        self.admission: Optional[AdmissionController] = (
            AdmissionController(tenants) if tenants else None
        )
        if self.admission is not None:
            self.admission.attach_events(self.events)
        self._dispatcher: Optional[StreamingDispatcher] = None
        self.data = DataManager(os.path.join(self.workdir, "data"))
        # data-aware staging (core/staging.py): dataset registry + modeled
        # transfer engine.  Physical DataManager verbs update the logical
        # replica map; binding policies read it for data-gravity placement.
        self.staging = StagingService(
            seed=staging_seed,
            default_capacity_mb=site_capacity_mb,
            links=staging_links,
            max_per_link=staging_max_per_link,
            mirror_outputs=staging_mirror_outputs,
        )
        self.data.attach_registry(self.staging.registry)
        self.staging.attach_events(self.events)
        self.policy.attach_staging(self.staging)
        self._managers: dict[str, object] = {}
        self._lock = threading.RLock()
        self._fault_lock = threading.RLock()  # serializes orphan collection/rebind
        self._claimed: set[str] = set()  # task uids currently being re-bound
        self._dispatch_workers = 8
        self._dispatch = ThreadPoolExecutor(
            max_workers=self._dispatch_workers, thread_name_prefix="hydra-dispatch"
        )
        self._submissions: list[Submission] = []
        # elastic acquisition state (core/autoscaler.py): providers that have
        # been *requested* but are still inside their modeled startup/queue
        # latency.  The dispatcher reads incoming_slots() so it neither fails
        # momentarily-unplaceable tasks nor under-sizes batches while
        # capacity is on its way.
        self._pending_acquisitions: dict[str, dict] = {}
        # metrics retired from pruned submissions (phase_totals): pruning
        # bounds broker memory by LIVE work, this keeps the run totals
        self._retired_phases: dict[str, float] = {}
        self._retired = {"n_submissions": 0, "n_tasks": 0, "ovh_s": 0.0}
        self.autoscaler = None  # attached via autoscale()
        self.checkpointer = None  # attached via enable_task_checkpoints()
        self.autotuner = None  # attached via enable_kernel_autotune()
        # kernel-payload legacy accumulators (HYDRA_EVENTS_CHECK ground
        # truth for kernel.exec): bumped under _kernel_lock adjacent to the
        # emit so the log fold replays float additions in the same order
        self._kernel_lock = threading.Lock()
        self.kernel_execs = 0
        self.kernel_execs_by: dict[str, int] = {}
        self.kernel_reps = 0
        self.kernel_seconds = 0.0
        self.watchdog: Optional[StragglerWatchdog] = None
        if enable_straggler_mitigation:
            self.watchdog = StragglerWatchdog(
                running=self._running_tasks,
                duplicate=self._speculate,
                factor=straggler_factor,
            )
            self.watchdog.start()
        if streaming:
            self.dispatcher()

    # ------------------------------------------------------------------
    # Streaming dispatch (core/dispatcher.py): the ready-queue loop that
    # micro-batches tasks across workflows and late-binds at dispatch time
    # ------------------------------------------------------------------
    def dispatcher(self) -> StreamingDispatcher:
        """The broker's long-lived streaming loop (started on first use).
        Lazy start does NOT flip ``self.streaming``: mode is an explicit
        constructor choice, so one caller using dispatch() cannot silently
        switch other WorkflowManagers sharing this broker into streaming."""
        with self._lock:
            if self._dispatcher is None:
                self._dispatcher = StreamingDispatcher(
                    self,
                    batch_window=self._batch_window,
                    max_batch=self._max_batch,
                ).start()
            return self._dispatcher

    def configure_tenants(self, tenants: list[TenantSpec]) -> AdmissionController:
        """Attach (or extend) the front door after construction.  Useful for
        tests and for brokers built by generic factories; prefer the
        ``tenants=`` constructor argument in application code."""
        if self.admission is None:
            self.admission = AdmissionController(tenants)
            self.admission.attach_events(self.events)
        else:
            for spec in tenants:
                self.admission.add_tenant(spec)
        return self.admission

    def enable_task_checkpoints(
        self, interval_s: float = 5.0, size_mb: float = 64.0
    ):
        """Attach a TaskCheckpointer (ckpt/checkpoint.py): preempt-killed
        tasks resume from their captured ``progress_frac`` on a surviving
        provider — through the staging gate, since the checkpoint is a
        replicated dataset — instead of restarting from zero, and resumes
        never charge ``max_retries``.  Lazy import: the ckpt module pulls
        numpy/jax, which the broker core must not pay for unconditionally."""
        from repro.ckpt.checkpoint import TaskCheckpointer

        if self.checkpointer is not None:
            raise RuntimeError("a task checkpointer is already attached")
        self.checkpointer = TaskCheckpointer(
            self.staging.registry, self.events, interval_s=interval_s, size_mb=size_mb
        )
        return self.checkpointer

    def enable_kernel_autotune(
        self, *, timer: str = "wall", reps: int = 3, seed: int = 0
    ):
        """Attach a Pallas Autotuner (kernels/autotune.py): sweeps land as
        pinned replicated datasets in this broker's staging registry and
        cache misses emit ``kernel.tune`` on this broker's bus.  The tuner
        is also installed process-global so kernels/ops.py entry points
        (and kernel-payload tasks) consult it under ``HYDRA_AUTOTUNE=1``.
        Lazy import: the kernels package pulls jax, which the broker core
        must not pay for unconditionally."""
        from repro.kernels.autotune import Autotuner, set_autotuner

        if self.autotuner is not None:
            raise RuntimeError("a kernel autotuner is already attached")
        self.autotuner = Autotuner(
            registry=self.staging.registry, events=self.events,
            timer=timer, reps=reps, seed=seed,
        )
        set_autotuner(self.autotuner)
        return self.autotuner

    def dispatch(self, tasks: list[Task]) -> None:
        """Feed ready tasks into the streaming dispatcher's queue, through
        the front door when one is configured: a rejected submission raises
        ``AdmissionError`` (typed backpressure) *before* anything enqueues —
        all-or-nothing, so a caller never has to hunt down a half-admitted
        batch.  Internal requeues (retries, staging re-gates, failover,
        speculation) carry ``task.admitted`` and are never re-charged."""
        if self.admission is not None:
            self.admission.admit(tasks)
        self.dispatcher().enqueue(tasks)

    def idle_slots(self) -> int:
        """Free execution slots across healthy bind targets: the streaming
        dispatcher's backfill hint.  Group members report slots minus
        outstanding load; ungrouped providers report slots minus the
        broker-tracked outstanding count, so a saturated provider genuinely
        reads as 0 free slots — which is what lets the elastic throttle hold
        work back for capacity that is still coming up instead of burying
        the busy provider's internal queue.  An O(1) CapacityLedger read:
        the per-call bind-target walk is gone (core/ledger.py)."""
        return self.ledger.idle_slots()

    def _provider_load(self, name: str, delta: int) -> None:
        """Outstanding-task accounting for ungrouped providers.  Serialized
        per handle, not broker-wide: this runs twice per task from every
        manager thread, and funneling it through self._lock was a measured
        contention hot spot (§Perf exp9)."""
        try:
            handle = self.proxy.get(name)
        except KeyError:  # elastically deregistered: nothing to track
            return
        with handle.load_lock:
            handle.outstanding = max(0, handle.outstanding + delta)
            grouped = handle.group is not None
        if not grouped:
            # grouped members account their load through the group's ledger
            # events; a late completion from a pre-join dispatch must not
            # double-touch the (re-based) member row
            self.ledger.load_delta(name, delta)

    def total_slots(self) -> int:
        """Live execution slots across healthy bind targets (for groups:
        members whose breaker is not OPEN — a tripped member's slots are
        *gone* from supply, which is exactly the signal that makes the
        autoscaler replace broken capacity).  O(1) ledger read."""
        return self.ledger.total_slots()

    def probe_slots(self) -> int:
        """Time-aware capacity peek for the dispatcher's STALL path only.
        A group member whose breaker reset window has elapsed is invisible
        to the event-driven ledger until something dispatches to it —
        ``allow()`` performs the OPEN -> HALF_OPEN transition, and allow()
        only runs when a pod is routed.  If the elastic throttle trusted
        the ledger alone, a fully-tripped fleet at pool max would never
        receive the probe that recovers it (livelock).  O(members), called
        only when the ledger reads zero idle supply."""
        return sum(g.idle_slots() for g in self.proxy.groups())

    def backlog(self) -> int:
        """Unfinished tasks the brokered providers still owe (dispatched or
        queued inside managers).  Queue *pressure* is backlog + ready-queue
        depth against live + incoming slots: the ready queue alone empties
        fast into manager-internal queues, so it under-reports sustained
        overload.

        Called every autoscaler tick: an O(1) ledger counter — incremented
        when a task first enters a submission, decremented when its future
        resolves — replacing the per-tick scan of every live submission and
        its 50 ms staleness cache."""
        return self.ledger.backlog()

    # ------------------------------------------------------------------
    # Dispatcher reads, None-safe: the public face of the streaming queue.
    # The autoscaler (and any other consumer) goes through these instead of
    # reaching into ``broker._dispatcher`` — a broker without a dispatcher
    # (frontier mode, or pre-first-use) reads as an empty queue, and stats
    # code cannot couple itself to dispatcher internals.
    # ------------------------------------------------------------------
    def queue_depth(self) -> int:
        """Ready-queue depth across every lane (0 without a dispatcher)."""
        d = self._dispatcher
        return d.pending() if d is not None else 0

    def queue_depth_by_class(self) -> dict[str, int]:
        """Ready-queue depth per SLO class (empty without a dispatcher)."""
        d = self._dispatcher
        return d.pending_by_class() if d is not None else {}

    def staging_stalled(self) -> int:
        """Tasks parked on stage-in transfers (0 without a dispatcher)."""
        d = self._dispatcher
        return d.stalled_on_staging() if d is not None else 0

    def staging_stalled_in_backlog(self) -> int:
        """The parked subset the backlog counter ALSO holds (re-gated
        retries): what the autoscaler subtracts to avoid double counting."""
        d = self._dispatcher
        return d.stalled_in_backlog() if d is not None else 0

    def deferred_demand(self) -> float:
        """Staging-parked tasks as decayed demand (core/dispatcher.py)."""
        d = self._dispatcher
        return d.deferred_demand() if d is not None else 0.0

    # ------------------------------------------------------------------
    # CapacityLedger plumbing (core/ledger.py)
    # ------------------------------------------------------------------
    def _notify_capacity(self) -> None:
        """Idle supply grew (completion / breaker close / arrival): wake the
        dispatcher NOW instead of letting it poll out a real-time timeout."""
        d = self._dispatcher
        if d is not None:
            d.notify_capacity()

    def _on_task_resolved(self, _fut) -> None:
        self.ledger.task_resolved()
        self.events.emit("backlog.resolve")

    def _ledger_recompute(self) -> dict:
        """From-scratch ground truth for the strict cross-check: the same
        counters the ledger maintains incrementally, rebuilt by scanning.
        Runs WITHOUT the ledger lock (it takes broker/proxy/group locks)."""
        idle = total = 0
        for handle in self.proxy.all():
            if handle.group is not None:
                continue  # counted through its group's member row
            if not handle.healthy:
                continue
            slots = max(1, handle.spec.concurrency * handle.spec.n_nodes)
            total += slots
            idle += max(0, slots - handle.outstanding)
        for group in self.proxy.groups():
            for row in group.stats():
                if row["breaker"] == BreakerState.OPEN.value:
                    continue
                total += row["slots"]
                idle += max(0, row["slots"] - row["outstanding"])
        with self._lock:
            incoming = sum(p["slots"] for p in self._pending_acquisitions.values())
            subs = list(self._submissions)
        backlog = len(
            {
                t.uid
                for sub in subs
                for t in sub.tasks
                if t.in_submission and not t.done()
            }
        )
        return {
            "idle_slots": idle,
            "total_slots": total,
            "incoming_slots": incoming,
            "backlog": backlog,
        }

    def _events_recompute(self) -> dict:
        """Legacy-accumulator ground truth for HYDRA_EVENTS_CHECK: the flat
        ``metric`` / ``metric:key`` mapping the log-derived view must agree
        with.  Only wired subsystems contribute keys (no autoscaler ⇒ no
        scale.* comparison), mirroring _ledger_recompute's lock discipline:
        runs WITHOUT the bus lock."""
        out: dict = {}
        d = self._dispatcher
        if d is not None:
            out["hydra.dispatch.batches"] = d.batches
            out["hydra.dispatch.tasks"] = d.tasks_dispatched
            out["hydra.dispatch.retry_backoffs"] = d.retry_backoffs
            out["hydra.dispatch.loop_errors"] = d.loop_errors
        a = self.autoscaler
        if a is not None:
            out["hydra.scale.ticks"] = a.ticks
            out["hydra.scale.acquisitions"] = a.acquisitions
            out["hydra.scale.arrivals"] = a.arrivals
            out["hydra.scale.releases"] = a.releases
            out["hydra.scale.aborts"] = a.aborts
            mp = a.planner
            if mp is not None:
                out["hydra.market.plans"] = mp.plans
                out["hydra.market.bids"] = mp.bids
                for tmpl, n in list(mp.bids_by_template.items()):
                    out[f"hydra.market.bids:{tmpl}"] = n
                out["hydra.market.reprices"] = mp.reprices
                out["hydra.cost_node_seconds"] = mp.cost_node_seconds
                out["hydra.cost_dollars"] = mp.cost_dollars
        ck = self.checkpointer
        if ck is not None:
            out["hydra.ckpt.saves"] = ck.saves
            out["hydra.ckpt.resumes"] = ck.resumes
            out["hydra.ckpt.reexecuted_s"] = ck.reexecuted_s
            out["hydra.ckpt.preempted_work_s"] = ck.preempted_work_s
        at = self.autotuner
        if at is not None:
            out["hydra.kernel.tunes"] = at.tunes
            out["hydra.kernel.swept_configs"] = at.swept_configs
        # unconditional: zero-valued keys match an absent view metric, and
        # any broker can receive kernel-payload tasks without opting in
        out["hydra.kernel.execs"] = self.kernel_execs
        for kname, n in list(self.kernel_execs_by.items()):
            out[f"hydra.kernel.execs:{kname}"] = n
        out["hydra.kernel.reps"] = self.kernel_reps
        out["hydra.kernel.seconds"] = self.kernel_seconds
        adm = self.admission
        if adm is not None:
            out["hydra.admission.admitted"] = adm.admitted
            for (tenant, reason), n in list(adm.rejected.items()):
                out[f"hydra.admission.rejected:{tenant}:{reason}"] = n
        st, eng = self.staging, self.staging.engine
        out["hydra.staging.stage_ins"] = st.stage_ins
        out["hydra.staging.stage_outs"] = st.stage_outs
        out["hydra.staging.stage_out_drops"] = st.stage_out_drops
        out["hydra.staging.evacuated_mb"] = st.evacuated_mb
        out["hydra.staging.mirrored_mb"] = st.mirrored_mb
        out["hydra.staging.transfer_wait_s"] = st.transfer_wait_s
        out["hydra.staging.transfers"] = eng.completed
        out["hydra.staging.mb_moved"] = eng.mb_moved
        out["hydra.staging.cache_hits"] = eng.cache_hits
        out["hydra.staging.cold_reads"] = eng.cold_reads
        out["hydra.staging.reroutes"] = eng.reroutes
        out["hydra.staging.transfer_failures"] = eng.failures
        out["hydra.staging.queue_wait_s"] = eng.queue_wait_s
        out["hydra.staging.evictions"] = st.registry.evictions
        for g in self.proxy.groups():
            for row in g.stats():
                member = row["member"]
                if row["dispatched"]:
                    out[f"hydra.group.dispatched:{member}"] = row["dispatched"]
                if row["completed"]:
                    out[f"hydra.group.completed:{member}"] = row["completed"]
                if row["failed"]:
                    out[f"hydra.group.failed:{member}"] = row["failed"]
        return out

    def stream_stats(self) -> dict:
        """Dispatcher-side metrics + total pipeline rounds (exp6).  A
        derived view over the event log; the dict shape is the legacy
        adapter, strict mode cross-checks it against the log fold."""
        self.events.maybe_check()
        stats = self._dispatcher.stats() if self._dispatcher else {}
        with self._lock:
            stats["n_submits"] = self.n_submits
            stats["n_pods"] = self.n_pods_total  # cumulative, prune-proof
        return stats

    def staging_stats(self) -> dict:
        """The data-movement story (core/staging.py): bytes moved, replica
        hits vs cold reads, eviction/re-route counts, transfer wait —
        benchmarks/exp8_staging.py compares these across placement arms."""
        self.events.maybe_check()
        stats = self.staging.stats()
        stats["staging_blocked"] = self.staging_stalled()
        return stats

    def tenant_stats(self) -> dict:
        """Front-door snapshot: per-tenant held counts, admit/reject
        totals, and the per-class queue depths (empty when no front door)."""
        if self.admission is None:
            return {}
        self.events.maybe_check()
        stats = self.admission.stats()
        stats["queue_by_class"] = self.queue_depth_by_class()
        return stats

    def events_stats(self) -> dict:
        """Bus-level snapshot: event count, retained/dropped, strict-mode
        divergence count, plus the full derived-metrics snapshot."""
        stats = self.events.stats()
        stats["metrics"] = self.events.snapshot()
        return stats

    # ------------------------------------------------------------------
    # Elastic acquisition (core/autoscaler.py drives these)
    # ------------------------------------------------------------------
    def autoscale(self, pool, **kw):
        """Attach an Autoscaler watching this broker's queue pressure and
        elastically acquiring/releasing providers from ``pool`` (a
        ProviderPool of launchable specs).  Returns the started Autoscaler;
        shutdown() stops it with the rest of the broker."""
        from repro.core.autoscaler import Autoscaler

        if self.autoscaler is not None:
            raise RuntimeError("an autoscaler is already attached")
        self.autoscaler = Autoscaler(self, pool, **kw).start()
        return self.autoscaler

    def begin_acquisition(self, spec: ProviderSpec, eta_s: float, group: Optional[str] = None):
        """Record a provider as in-flight (requested, not yet up)."""
        slots = max(1, spec.concurrency * spec.n_nodes)
        with self._lock:
            self._pending_acquisitions[spec.name] = {
                "platform": spec.platform,
                "slots": slots,
                "capacity": spec.capacity(),
                "eta_s": eta_s,
                "requested_at": now(),
                "group": group,
            }
            self.ledger.begin_incoming(spec.name, slots)

    def complete_acquisition(self, spec: ProviderSpec) -> Optional[ProviderHandle]:
        """The modeled acquisition latency elapsed: the provider is live.
        Registers it (joining its target group, if any) and clears the
        pending record.  A cancelled acquisition (record already gone) is a
        no-op so a release racing an arrival cannot register a zombie; a
        failed group join rolls the registration back entirely so a
        misconfigured launch spec cannot leak half-joined providers into
        the direct-binding pool."""
        with self._lock:
            info = self._pending_acquisitions.pop(spec.name, None)
            if info is not None:
                self.ledger.end_incoming(spec.name)
        if info is None:
            return None
        handle = self.register_provider(spec)
        group_name = info.get("group")
        if group_name is not None:
            try:
                group = self.proxy.get_group(group_name)
                group.add_member(handle)
                try:
                    self.proxy.attach_member(group_name, spec.name)
                except Exception:
                    group.remove_member(spec.name)
                    raise
            except Exception:
                self._rollback_registration(spec.name)
                raise
        return handle

    def _rollback_registration(self, name: str) -> None:
        with self._lock:
            mgr = self._managers.pop(name, None)
        if mgr is not None:
            mgr.shutdown(wait=False)
        self.ledger.remove(name)
        self.events.emit("provider.deregister", provider=name, reason="rollback")
        try:
            self.proxy.deregister(name)
        except KeyError:
            pass

    def abort_acquisition(self, name: str) -> bool:
        """Drop a pending acquisition (scale-in decided before arrival)."""
        with self._lock:
            dropped = self._pending_acquisitions.pop(name, None) is not None
            if dropped:
                self.ledger.end_incoming(name)
            return dropped

    def incoming_slots(self) -> int:
        """Execution slots currently inside their modeled acquisition
        latency: counted as supply by the dispatcher and the autoscaler so
        sustained pressure does not over-acquire.  O(1) ledger read."""
        return self.ledger.incoming_slots()

    def pending_acquisitions(self) -> list[dict]:
        with self._lock:
            return [dict(name=n, **p) for n, p in self._pending_acquisitions.items()]

    def incoming_could_fit(self, task: Task) -> bool:
        """Would any in-flight acquisition be able to run ``task``?  Gates
        the dispatcher's defer-instead-of-fail path: a task no arriving
        provider can fit must surface its NoEligibleProvider now, not after
        every acquisition has landed."""
        with self._lock:
            caps = [p["capacity"] for p in self._pending_acquisitions.values()]
        return any(task.resources.fits(cap) for cap in caps)

    def scale_stats(self) -> dict:
        """One snapshot of the elastic state: live/incoming capacity, queue
        pressure inputs, and the autoscaler's own counters when attached."""
        self.events.maybe_check()
        stats = {
            "n_providers": len(self.providers()),
            "idle_slots": self.idle_slots(),
            "incoming_slots": self.incoming_slots(),
            "pending_acquisitions": self.pending_acquisitions(),
            "queue_depth": self.queue_depth(),
        }
        if self.autoscaler is not None:
            stats["autoscaler"] = self.autoscaler.stats()
        return stats

    def _prune_finished_submissions(self) -> None:
        """Drop ANY submission whose tasks have all RESOLVED futures — after
        extracting its metrics row into the retired totals — so a long-lived
        broker's memory and every remaining full scan (orphan sweep, ledger
        cross-check) are bounded by LIVE work, not run history.  Resolution,
        not tstate-finality, is the gate: a retryable FAILED task is final
        by tstate but still owned by the orphan sweep (_collect_orphans),
        which scans these submissions to re-bind it.  Callers keep their own
        Submission handles (wait()/metrics() are self-contained), so pruning
        caller-created submissions is safe; run-level totals stay readable
        through phase_totals()."""
        retired: list[Submission] = []
        with self._lock:
            live = []
            for s in self._submissions:
                if all(t.done() for t in s.tasks):
                    retired.append(s)
                else:
                    live.append(s)
            self._submissions = live
        for s in retired:
            m = s.metrics()
            with self._lock:
                self._retired["n_submissions"] += 1
                self._retired["n_tasks"] += len(s.tasks)
                self._retired["ovh_s"] += m.ovh
                for k, v in m.phases.items():
                    self._retired_phases[k] = self._retired_phases.get(k, 0.0) + v

    def phase_totals(self) -> dict[str, float]:
        """Cumulative broker-side phase seconds (bind/partition/serialize/
        submit) across ALL submissions this broker ever ran — pruned ones
        contribute their retired totals, live ones are summed on the fly.
        The exp4 OVH instrumentation reads this instead of walking
        ``_submissions`` (which pruning now keeps bounded)."""
        with self._lock:
            totals = dict(self._retired_phases)
            subs = list(self._submissions)
        for s in subs:
            for k, v in s.metrics().phases.items():
                totals[k] = totals.get(k, 0.0) + v
        return totals

    def _running_tasks(self) -> list[Task]:
        with self._lock:
            return [
                t
                for sub in self._submissions
                for t in sub.tasks
                if t.tstate == TaskState.RUNNING
            ]

    # ------------------------------------------------------------------
    # Provider lifecycle (elastic: add/remove at runtime)
    # ------------------------------------------------------------------
    def register_provider(self, spec: ProviderSpec) -> ProviderHandle:
        handle = self.proxy.register(spec)
        mgr_cls = PilotManager if spec.connector == "pilot" else CaaSManager
        with self._lock:
            self._managers[spec.name] = mgr_cls(
                handle,
                on_task_done=self._on_task_done,
                on_task_skipped=self._on_task_skipped,
                on_task_finishing=self._on_task_finishing,
            )
        self.data.register_site(spec.name)
        self.staging.register_site(spec.name, platform=spec.platform)
        self.ledger.upsert_direct(spec.name, max(1, spec.concurrency * spec.n_nodes))
        self.events.emit(
            "provider.register",
            provider=spec.name,
            slots=max(1, spec.concurrency * spec.n_nodes),
            group=handle.group,
        )
        return handle

    def register_group(
        self,
        name: str,
        members: list,
        strategy: str = "round_robin",
        failure_threshold: int = 3,
        reset_timeout_s: float = 30.0,
        min_healthy: int = 1,
    ) -> ProviderGroup:
        """Pool providers behind one logical bind target (core/group.py).

        ``members`` mixes ProviderSpecs (registered on the fly) and names of
        already-registered providers.  Policies bind tasks to ``name``; the
        group resolves the concrete member at dispatch time and fails work
        over transparently when a member dies."""
        handles = []
        added: list[str] = []  # members registered here, for rollback
        try:
            for m in members:
                if isinstance(m, ProviderSpec):
                    handles.append(self.register_provider(m))
                    added.append(m.name)
                else:
                    handles.append(self.proxy.get(m))
            group = ProviderGroup(
                name,
                handles,
                strategy=strategy,
                failure_threshold=failure_threshold,
                reset_timeout_s=reset_timeout_s,
                min_healthy=min_healthy,
            )
            self.proxy.register_group(group)
            # capacity events flow through the group from here on: member
            # ledger rows replace the members' direct rows, and breaker
            # transitions invalidate the proxy's cached bind-target list
            group.attach_runtime(self.ledger, self.proxy.bump_version, events=self.events)
            # a group is ONE staging site: members share a group-local store
            # (the way the paper's platforms share a filesystem), so member
            # churn inside the group never moves bytes
            self.data.register_site(name)
            self.staging.register_site(name, platform=group.spec.platform)
            return group
        except Exception:
            # a failed group registration must not leak its on-the-fly
            # members into the direct-binding pool
            for member in added:
                with self._lock:
                    mgr = self._managers.pop(member, None)
                if mgr is not None:
                    mgr.shutdown(wait=False)
                self.ledger.remove(member)
                self.events.emit("provider.deregister", provider=member, reason="rollback")
                try:
                    self.proxy.deregister(member)
                except KeyError:
                    pass
            raise

    def remove_provider(self, name: str, drain: bool = True, deregister: bool = False):
        """Elastic scale-down: stop a provider; re-bind its unfinished tasks.
        ``deregister=True`` (the autoscaler's release path) also frees the
        name in the proxy and drops the policy's per-provider state, so a
        later acquisition may recycle the slot cleanly."""
        with self._lock:
            mgr = self._managers.pop(name)
            handle = self.proxy.get(name)
            handle.healthy = False
        with handle.load_lock:
            handle.outstanding = 0
        if handle.group is None:
            # grouped members leave supply via mark_down below (breaker trip
            # -> ledger set_counted), keeping the ledger keyed on the same
            # signal its cross-check recomputes from
            self.ledger.deactivate(name)
        self.proxy.bump_version()  # health flip: cached bind targets stale
        mgr.fail()  # reject anything in flight
        if drain:
            # graceful release: save any LAST-copy dataset to the shared
            # store before the scratch goes away — a routine scale-in must
            # never terminally fail downstream tasks over lost data
            self.staging.evacuate(name)
        # the site's scratch dies with the instance: drop its replicas,
        # re-route any transfer that was reading from (or writing to) it,
        # and close the physical namespace so the verbs can't strand data
        self.staging.site_down(name)
        self.data.deregister_site(name)
        if handle.group is not None:
            group = self.proxy.get_group(handle.group)
            group.mark_down(name)  # out of rotation before the orphan sweep
            with self._fault_lock:
                orphans = self._collect_orphans(name)
                self._redispatch_in_group(group, orphans, exclude=name)
            group.remove_member(name)  # permanent: no probes to a dead slot
            handle.group = None
        else:
            with self._fault_lock:
                orphans = self._collect_orphans(name)
                self._rebind_and_resubmit(orphans, exclude=name)
        mgr.shutdown(wait=drain)
        self.events.emit(
            "provider.deregister",
            provider=name,
            reason="release" if deregister else ("drain" if drain else "outage"),
        )
        if deregister:
            self.policy.forget(name)
            self.ledger.remove(name)
            try:
                self.proxy.deregister(name)
            except KeyError:
                pass

    def providers(self) -> list[str]:
        return [h.name for h in self.proxy.healthy()]

    def group(self, name: str) -> ProviderGroup:
        return self.proxy.get_group(name)

    def group_rows(self) -> list[dict]:
        """Group-aware metrics: one row per group member (breaker state,
        trips, dispatched/completed/failed/outstanding, weight)."""
        self.events.maybe_check()
        return [row for g in self.proxy.groups() for row in g.stats()]

    def manager(self, name: str):
        return self._managers[name]

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(
        self,
        tasks: list[Task],
        partitioning: Optional[str] = None,
        tasks_per_pod: Optional[int] = None,
        batch_id: Optional[str] = None,
    ) -> Submission:
        model = partitioning or self.partitioning
        tpp = tasks_per_pod or self.tasks_per_pod
        # classic (non-streaming) entry pays admission too; the streaming
        # dispatcher's micro-batches arrive already admitted (no-op here)
        if self.admission is not None:
            self.admission.admit(tasks)
        sub = Submission(tasks, self)
        with self._lock:
            self._submissions.append(sub)
            self.n_submits += 1
            prune_due = self.n_submits % 32 == 0
        if prune_due:
            self._prune_finished_submissions()
        try:
            return self._submit_pipeline(sub, tasks, model, tpp, batch_id)
        except BaseException:
            # a failed pipeline round (e.g. transient full outage seen by the
            # streaming dispatcher) must not leave a half-built submission in
            # the metrics/orphan-sweep lists: the caller owns the retry.
            # Once the dispatch phase started, pods may already be running on
            # providers — the submission must then STAY registered so the
            # orphan sweep can still find those tasks.
            with self._lock:
                if not sub.dispatch_started and sub in self._submissions:
                    self._submissions.remove(sub)
                    self.n_submits -= 1
            raise

    def _submit_pipeline(
        self,
        sub: Submission,
        tasks: list[Task],
        model: str,
        tpp: int,
        batch_id: Optional[str],
    ) -> Submission:
        rt = sub.run_trace
        sub.batch_id = batch_id

        # -- bind (late: provider/group health is read NOW, at dispatch) ---
        rt.add("bind_start")
        targets = self.proxy.bind_targets()
        if not targets:
            raise RuntimeError("no healthy providers registered")
        by_provider: dict[str, list[Task]] = {}
        names = self.policy.bind_bulk(tasks, targets)
        try:
            for t, name in zip(tasks, names):
                t.provider = name
                t.group = name if self.proxy.is_group(name) else None
                t.advance(TaskState.BOUND)
                by_provider.setdefault(name, []).append(t)
            rt.add("bind_done")

            # -- partition ---------------------------------------------------
            rt.add("partition_start")
            pods: list[Pod] = []
            for name, ts in by_provider.items():
                ppods = partition(ts, name, model=model, tasks_per_pod=tpp)
                for p in ppods:
                    p.batch_id = batch_id
                    for t in p.tasks:
                        t.advance(TaskState.PARTITIONED)
                pods.extend(ppods)
            sub.pods.extend(pods)
            with self._lock:
                self.n_pods_total += len(pods)
            rt.add("partition_done")

            # -- serialize ---------------------------------------------------
            rt.add("serialize_start")
            for p in pods:
                self.store.serialize(p)
            rt.add("serialize_done")
        except BaseException as e:
            # nothing reached a provider yet: fully reverse the batch's load
            # accounting (bind_bulk accounted for EVERY task, including ones
            # whose provider attribute was never updated) and mark the
            # exception so the dispatcher's retry does not release twice
            for t, name in zip(tasks, names):
                self.policy.unbind(t, name)
            try:
                e._hydra_load_released = True
            except AttributeError:  # exceptions with __slots__
                pass
            raise

        # -- bulk submit (concurrently across providers) -----------------------
        rt.add("submit_start")
        sub.dispatch_started = True
        entered = []
        for t in tasks:  # now visible to backlog() until resolution
            if not t.in_submission:
                t.in_submission = True
                entered.append(t)
        if entered:
            # count BEFORE registering the resolution callbacks: a task that
            # resolves instantly fires its callback inline, and the decrement
            # must never precede the increment.  Only first entries register
            # — a task re-entering through a later submission (rebind via the
            # staging gate) must not earn a second decrement.
            self.ledger.task_entered(len(entered))
            self.events.emit("backlog.enter", n=len(entered))
            for t in entered:
                t.add_done_callback(self._on_task_resolved)
        per_provider: dict[str, list[Pod]] = {}
        for p in pods:
            per_provider.setdefault(p.provider, []).append(p)
        # chunk the per-provider submissions over the dispatch workers: at
        # 256 providers one executor round-trip per provider dominated the
        # submit phase (§Perf exp9), and pod delivery inside a chunk is a
        # loop, not a hop
        items = list(per_provider.items())
        n_chunks = max(1, min(len(items), self._dispatch_workers))
        futs = [
            self._dispatch.submit(self._submit_chunk, items[i::n_chunks])
            for i in range(n_chunks)
        ]
        futures_wait(futs)
        for f in futs:
            exc = f.exception()
            if exc is not None and not isinstance(exc, ProviderDown):
                raise exc
        rt.add("submit_done")
        return sub

    def _submit_chunk(self, items: list[tuple[str, list[Pod]]]) -> None:
        """Deliver several providers' pods from one dispatch worker.  One
        provider's failure must not starve the rest of the chunk: ProviderDown
        is absorbed (the fault path already owns it, as before), the first
        unexpected error is re-raised after the chunk completes."""
        first_exc: Optional[BaseException] = None
        for name, pods in items:
            try:
                self._submit_to_provider(name, pods)
            except ProviderDown:
                continue
            except BaseException as e:
                if first_exc is None:
                    first_exc = e
        if first_exc is not None:
            raise first_exc

    def _submit_to_provider(self, name: str, pods: list[Pod]):
        if self.proxy.is_group(name):
            self._submit_to_group(self.proxy.get_group(name), pods)
            return
        self._provider_load(name, sum(len(p.tasks) for p in pods))
        try:
            self._managers[name].submit_pods(pods)
        except ProviderDown:
            self._handle_provider_down(name)
            raise

    # ------------------------------------------------------------------
    # Group dispatch: the group resolves the member per pod at dispatch
    # time; member loss is absorbed here (transparent failover) instead of
    # propagating to the caller's policy.
    # ------------------------------------------------------------------
    def _submit_to_group(self, group: ProviderGroup, pods: list[Pod], exclude: Optional[str] = None):
        # resolve the member per pod, then ONE bulk submit_pods per member:
        # per-pod submits would pay the modeled submit latency per pod
        # instead of per provider, inflating the group indirection cost
        by_member: dict[str, list[Pod]] = {}
        for pod in pods:
            try:
                member = group.select(exclude=exclude)
            except GroupExhausted:
                self._group_exhausted(group, pod.tasks)
                continue
            pod.provider = member
            for t in pod.tasks:
                t.provider = member
                t.group = group.name
                t.trace.add(f"dispatch:{group.name}->{member}")
            group.note_dispatch(member, len(pod.tasks))
            by_member.setdefault(member, []).append(pod)
        for member, member_pods in by_member.items():
            self._submit_member_pods(group, member, member_pods)

    def _submit_member_pods(self, group: ProviderGroup, member: str, pods: list[Pod]):
        mgr = self._managers.get(member)  # gone if elastically removed
        try:
            if mgr is None:
                raise ProviderDown(member)
            mgr.submit_pods(pods)
        except ProviderDown:
            self._handle_member_down(group, member)

    def _group_exhausted(self, group: ProviderGroup, tasks: list[Task]):
        """Every member breaker open: fall back to cross-provider re-bind."""
        with self._fault_lock:
            live = []
            with self._lock:
                for t in tasks:
                    if t.final or t.uid in self._claimed:
                        continue
                    self._claimed.add(t.uid)
                    live.append(t)
            for t in live:
                t.try_advance(TaskState.BOUND)
            self._rebind_and_resubmit(live, exclude=group.name)

    def _handle_member_down(self, group: ProviderGroup, member: str):
        """A group member died: open its breaker, fail its in-flight work
        over to surviving members without involving the binding policy."""
        group.mark_down(member)
        with self._lock:
            handle = self.proxy.get(member)
            handle.trace.add(f"breaker_open:{group.name}")
        with self._fault_lock:
            orphans = self._collect_orphans(member)
            self._redispatch_in_group(group, orphans, exclude=member)

    def _redispatch_in_group(self, group: ProviderGroup, tasks: list[Task], exclude: Optional[str] = None):
        """Re-bind claimed tasks to surviving group members; overflow (group
        exhausted) falls back to the policy re-bind path."""
        if not tasks:
            return
        by_member: dict[str, list[Task]] = {}
        fallback: list[Task] = []
        for t in tasks:
            try:
                member = group.select(exclude=exclude)
            except GroupExhausted:
                fallback.append(t)
                continue
            t.provider = member
            t.group = group.name
            t.trace.add(f"failover:{member}")
            by_member.setdefault(member, []).append(t)
        for member, ts in by_member.items():
            group.note_dispatch(member, len(ts))
            pods = partition(ts, member, model="mcpp", tasks_per_pod=self.tasks_per_pod)
            for p in pods:
                for t in p.tasks:
                    t.try_advance(TaskState.PARTITIONED)
                    self._release_claim(t)  # re-claimable if this member dies too
                self.store.serialize(p)
            self._dispatch.submit(self._submit_member_pods, group, member, pods)
        if fallback:
            self._rebind_and_resubmit(fallback, exclude=group.name)

    # ------------------------------------------------------------------
    # Completion / fault handling
    # ------------------------------------------------------------------
    def _on_task_done(self, task: Task, provider: str, failed: bool):
        # policies observe the *logical* bound name: member churn inside a
        # group must not leak into policy load/EWMA accounting
        logical = task.group or provider
        if task.group is None:
            self._provider_load(provider, -1)
        t0, t1 = task.trace.first("exec_start"), task.trace.last("exec_done")
        if t0 is not None and t1 is not None:
            self.policy.observe(logical, t1 - t0)
            if self.watchdog:
                self.watchdog.observe_completion(t1 - t0)
        else:
            self.policy.observe(logical, 1e-3)
        group: Optional[ProviderGroup] = None
        if task.group and self.proxy.is_group(task.group):
            group = self.proxy.get_group(task.group)
        exc = getattr(task, "last_error", None) if failed else None
        if group is not None:
            # grouped terminal states reach the bus via group.record_* so
            # the member-keyed view stays adjacent to the legacy counters
            if failed:
                group.record_failure(provider)
            else:
                group.record_success(provider)
        else:
            self.events.emit("task.complete", provider=provider, failed=failed)
        if not failed:
            if task.kind == "kernel" and task.kernel_stats is not None:
                ks = task.kernel_stats
                with self._kernel_lock:
                    self.kernel_execs += 1
                    self.kernel_execs_by[ks["kernel"]] = (
                        self.kernel_execs_by.get(ks["kernel"], 0) + 1
                    )
                    self.kernel_reps += ks["reps"]
                    self.kernel_seconds += ks["kernel_s"]
                    self.events.emit(
                        "kernel.exec",
                        kernel=ks["kernel"],
                        reps=ks["reps"],
                        kernel_s=ks["kernel_s"],
                    )
            return
        if isinstance(exc, ProviderDown):  # _handle_*_down owns the outage transition
            if group is not None:
                self._handle_member_down(group, provider)
            else:
                self._handle_provider_down(provider)
            return
        with self._fault_lock:
            if task.uid in self._claimed or task.tstate != TaskState.FAILED:
                return  # already claimed / re-bound / finished elsewhere
            if self._try_checkpoint_resume(task, exc):
                # preempt-kill on a checkpointable task: capture progress,
                # resume from progress_frac WITHOUT charging max_retries —
                # the re-entry goes through _rebind_and_resubmit, whose
                # staging gate stages the checkpoint dataset to the chosen
                # surviving site (checkpoints obey data gravity)
                self._rebind_and_resubmit([task], exclude=provider)
                return
            if task.retries < task.max_retries:
                self._claimed.add(task.uid)
                task.reset_for_retry()
            else:
                if self.fail_fast:
                    self._cancel_all_pending()
                return
            if group is not None:
                # transparent in-group retry, never the member that failed it
                self._redispatch_in_group(group, [task], exclude=provider)
            else:
                self._rebind_and_resubmit([task], exclude=provider)

    def _try_checkpoint_resume(self, task: Task, exc) -> bool:
        """If ``task`` was preempt-killed and a TaskCheckpointer is attached,
        capture its progress and reset it for resume (no retry charge).
        Caller holds _fault_lock; the task must be FAILED and unclaimed.
        Returns True iff the task is now claimed + BOUND for re-entry."""
        ck = self.checkpointer
        if ck is None or task.done():
            return False
        from repro.core.managers.compute import Preempted

        if not isinstance(exc, Preempted) or not ck.eligible(task):
            return False
        self._claimed.add(task.uid)
        ck.on_preempt(task)
        task.reset_for_resume()
        return True

    def _on_task_finishing(self, task: Task, provider: str):
        """Stage-out, on the manager thread BEFORE the task's future
        resolves: resolution synchronously enqueues dependents, so a child
        could reach the staging gate ahead of its input's registration if
        outputs were registered any later.  Group-bound tasks write the
        group-local store (the logical site)."""
        if not (task.outputs or task.inputs):
            return
        try:
            self.staging.task_completed(task, task.group or provider)
        except Exception:
            task.trace.add("stage_out_error")  # never break completion

    def _on_task_skipped(self, task: Task, provider: str):
        """A manager skipped a task that went final elsewhere (speculation /
        failover race): release the member's load slot."""
        if task.group and self.proxy.is_group(task.group):
            self.proxy.get_group(task.group).record_skip(provider)
        elif task.group is None:
            self._provider_load(provider, -1)
            self.events.emit("task.skip", provider=provider)

    def _handle_provider_down(self, name: str):
        with self._lock:
            handle = self.proxy.get(name)
            flipped = handle.healthy
            if handle.healthy:
                handle.healthy = False
                handle.trace.add("blacklisted")
        if flipped:
            self.events.emit("provider.blacklist", provider=name)
        with handle.load_lock:
            handle.outstanding = 0  # a dead provider owes nothing dispatchable
        self.ledger.deactivate(name)
        self.proxy.bump_version()  # health flip: cached bind targets stale
        self.staging.site_down(name)
        self.data.deregister_site(name)
        if self.autoscaler is not None:
            # a blacklisted elastic instance must stop occupying pool
            # headroom, or broken capacity could never be replaced
            self.autoscaler.note_provider_lost(name)
        # always sweep for orphans: late ProviderDown failures arrive after
        # the initial blacklisting and still need re-binding
        with self._fault_lock:
            orphans = self._collect_orphans(name)
            self._rebind_and_resubmit(orphans, exclude=name)

    def _collect_orphans(self, provider: str) -> list[Task]:
        """Claim + reset every non-final task bound to a dead provider.
        Must be called under _fault_lock; claims prevent double re-binding."""
        with self._lock:
            orphans = [
                t
                for sub in self._submissions
                for t in sub.tasks
                if t.provider == provider
                and t.uid not in self._claimed
                # FAILED is a *final* state but retryable: include it here
                and (not t.final or t.tstate == TaskState.FAILED)
            ]
            self._claimed.update(t.uid for t in orphans)
        out = []
        ck = self.checkpointer
        for t in orphans:
            # force non-final tasks back to a BOUND-able state
            if t.tstate == TaskState.RUNNING:
                from repro.core.managers.compute import Preempted, ProviderDown as PD

                if ck is not None and ck.eligible(t):
                    # the instance died under a RUNNING checkpointable task:
                    # that is a preemption, not the task's failure — capture
                    # progress and resume on a survivor without charging a
                    # retry (the shared-store checkpoint replica survives
                    # this site's death)
                    t.mark_failed(Preempted(provider))
                    if t.tstate == TaskState.FAILED and not t.done():
                        ck.on_preempt(t)
                        t.reset_for_resume()
                        out.append(t)
                        continue
                else:
                    t.mark_failed(PD(provider))
            if t.tstate == TaskState.FAILED:
                if t.retries >= t.max_retries:
                    self._release_claim(t)
                    continue
                t.reset_for_retry()
            elif t.tstate in (TaskState.SUBMITTED, TaskState.PARTITIONED):
                t.try_advance(TaskState.BOUND)
            elif t.tstate == TaskState.DONE:  # finished in the race window
                self._release_claim(t)
                continue
            out.append(t)
        return out

    def _release_claim(self, task: Task):
        with self._lock:
            self._claimed.discard(task.uid)

    def _rebind_and_resubmit(self, tasks: list[Task], exclude: Optional[str] = None):
        if not tasks:
            return
        if self._dispatcher is not None:
            # tasks with declared inputs must re-enter through the staging
            # gate: a direct resubmit would run them at a site their inputs
            # were never staged to (the dead site took its replicas down)
            gated = [t for t in tasks if t.inputs]
            if gated:
                for t in gated:
                    t.trace.add("rebind_via_gate")
                    self._release_claim(t)
                self._dispatcher.enqueue(gated)
                tasks = [t for t in tasks if not t.inputs]
                if not tasks:
                    return
        targets = [h for h in self.proxy.bind_targets() if h.name != exclude]
        if not targets:
            for t in tasks:
                if not t.done():
                    t.set_exception(RuntimeError("no healthy providers for retry"))
            return
        by_provider: dict[str, list[Task]] = {}
        for t in tasks:
            name = self.policy.bind(t, targets)
            t.provider = name
            t.group = name if self.proxy.is_group(name) else None
            t.trace.add(f"rebound:{name}")
            by_provider.setdefault(name, []).append(t)
        for name, ts in by_provider.items():
            pods = partition(ts, name, model="mcpp", tasks_per_pod=self.tasks_per_pod)
            for p in pods:
                for t in p.tasks:
                    # a task may have completed in the race window (authoritative
                    # completion); the pod runner skips final tasks
                    t.try_advance(TaskState.PARTITIONED)
                    self._release_claim(t)  # re-claimable if this provider dies too
                self.store.serialize(p)
            self._dispatch.submit(self._submit_to_provider, name, pods)

    def _speculate(self, task: Task):
        """Straggler: launch a speculative clone on a different provider.
        For group-bound tasks the clone stays inside the group (on another
        member) and the straggle counts against the member's breaker."""
        if task.group and self.proxy.is_group(task.group):
            group = self.proxy.get_group(task.group)
            group.record_straggler(task.provider)
            try:
                member = group.select(exclude=task.provider)
            except GroupExhausted:
                member = None
            if member is not None:
                shadow = clone_for_speculation(task)
                shadow.group = group.name
                shadow.provider = member
                shadow.advance(TaskState.BOUND)
                pods = partition([shadow], member, model="scpp")
                group.note_dispatch(member, 1)
                for p in pods:
                    shadow.advance(TaskState.PARTITIONED)
                    self.store.serialize(p)
                self._dispatch.submit(self._submit_member_pods, group, member, pods)
                return
        targets = [
            h
            for h in self.proxy.bind_targets()
            if h.name != task.provider and h.name != task.group
        ]
        if not targets:
            return
        shadow = clone_for_speculation(task)
        name = self.policy.bind(shadow, targets)
        shadow.provider = name
        shadow.group = name if self.proxy.is_group(name) else None
        if shadow.inputs and self._dispatcher is not None:
            # the clone carries the original's declared inputs, which live at
            # the straggling site — it must enter through the staging gate so
            # the bytes are staged (and charged) to the speculation target.
            # The reservation pins the gate to the exclude-aware choice made
            # above, or speculation could route right back to the straggler.
            shadow.reserved_provider = name
            shadow.trace.add("speculate_via_gate")
            self._dispatcher.enqueue([shadow])
            return
        shadow.advance(TaskState.BOUND)
        pods = partition([shadow], name, model="scpp")
        for p in pods:
            shadow.advance(TaskState.PARTITIONED)
            self.store.serialize(p)
        self._dispatch.submit(self._submit_to_provider, name, pods)

    def _cancel_all_pending(self):
        with self._lock:
            for sub in self._submissions:
                for t in sub.tasks:
                    if not t.final:
                        t.mark_canceled()

    # ------------------------------------------------------------------
    def shutdown(self, wait: bool = True):
        """Graceful teardown of every instantiated resource (paper §3.2)."""
        if self.autoscaler is not None:
            self.autoscaler.stop(wait=wait)
        if self._dispatcher is not None:
            self._dispatcher.stop(wait=wait)
        if self.watchdog:
            self.watchdog.stop()
        with self._lock:
            managers = list(self._managers.values())
        for m in managers:
            m.shutdown(wait=wait)
        self._dispatch.shutdown(wait=wait)
        self.staging.shutdown()
        self.store.cleanup()
        if self.autotuner is not None:
            # release the process-global slot iff it is still ours (a later
            # broker may have installed its own tuner in the meantime)
            from repro.kernels.autotune import unset_autotuner

            unset_autotuner(self.autotuner)
        log_base = os.environ.get("HYDRA_EVENTS_LOG", "")
        if log_base:
            self.events.dump_jsonl(next_log_path(log_base))
        if self.ledger.strict and self.ledger.divergences:
            # a strict-mode divergence may have fired inside a loop that
            # swallows exceptions (the dispatcher's lifeline handler):
            # re-surface it here so the test suite cannot pass over it
            raise LedgerDivergence(
                f"capacity ledger diverged {self.ledger.divergences}x "
                f"during this broker's lifetime: {self.ledger.last_divergence}"
            )
        if self.events.strict:
            # the authoritative events cross-check runs here, at quiescence:
            # every derived metric must equal its legacy accumulator, and any
            # divergence recorded mid-run re-surfaces the same way the
            # ledger's does
            if self.events.divergences:
                raise EventsDivergence(
                    f"event views diverged {self.events.divergences}x during "
                    f"this broker's lifetime: {self.events.last_divergence}"
                )
            self.events.check()
