"""Multi-tenant admission control: the broker's serving front door.

Everything upstream of the ready heap used to be unbounded: any caller could
``dispatch()`` 100k tasks and the dispatcher would happily heap them all,
starving every other submitter and hiding the overload until makespans blew
up.  This module puts a *front door* between submitters and the ready queue
(ROADMAP: "millions of users needs a tenant layer above the ready heap"):

  * **Token-bucket rate limits** — each tenant refills at ``rate`` tasks/s
    (measured on the active Clock, so virtual-time tests are deterministic)
    up to a ``burst`` cap; an admit that outruns the bucket is rejected with
    a typed ``AdmissionError(reason="rate_limited")``.
  * **Bounded queues** — each tenant may hold at most ``max_queued``
    admitted-but-unfinished tasks; beyond that the front door rejects with
    ``reason="queue_full"`` instead of growing the ready heap without bound.
    Backpressure is the submitter's signal to slow down, exactly like a
    429 from a serving stack.
  * **Weights** — the dispatcher's weighted-fair drain
    (core/dispatcher.py, policy.apportion_budget) reads each tenant's
    ``weight`` to split the batch budget among same-class lanes.

Admission is *per submission entry*, not per internal hop: retries, staging
re-gates, failovers and speculative clones all carry tasks that were already
admitted (``task.admitted``) and pass through untouched — the front door
meters what enters the system, never what the system is already obliged to
finish.  Release is idempotent and automatic: a held slot is freed when the
task's future resolves (the controller registers one done-callback at admit
time), so rejected-then-retried submitters see the queue drain as work
completes, whatever path the work took.

An unconfigured tenant gets ``DEFAULT_TENANT_SPEC`` semantics: unlimited
rate, unbounded queue, weight 1.0 — so a broker constructed without a
tenant map behaves exactly as before this module existed.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

from repro.core.task import Task
from repro.runtime.clock import get_clock


class AdmissionError(RuntimeError):
    """Typed backpressure: the front door rejected a submission.

    ``reason`` is ``"rate_limited"`` (token bucket empty) or ``"queue_full"``
    (per-tenant bound hit); ``retry_after_s`` is a refill-based hint for
    rate-limited rejections (None when the queue is the binding constraint —
    the submitter should wait for completions, not a timer).
    """

    def __init__(self, tenant: str, reason: str, detail: str, retry_after_s: Optional[float] = None):
        self.tenant = tenant
        self.reason = reason
        self.retry_after_s = retry_after_s
        super().__init__(f"tenant {tenant!r} {reason}: {detail}")


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's front-door contract.

    ``rate`` is tasks/second (None = unlimited), ``burst`` the bucket depth
    (defaults to ``rate`` when unset, min 1), ``max_queued`` the bound on
    admitted-but-unfinished tasks (None = unbounded), ``weight`` the share
    of the dispatcher's batch budget among same-class lanes.
    """

    name: str
    rate: Optional[float] = None
    burst: Optional[float] = None
    max_queued: Optional[int] = None
    weight: float = 1.0

    def __post_init__(self):
        if self.rate is not None and self.rate <= 0:
            raise ValueError(f"tenant {self.name!r}: rate must be > 0 or None")
        if self.max_queued is not None and self.max_queued <= 0:
            raise ValueError(f"tenant {self.name!r}: max_queued must be > 0 or None")
        if self.weight < 0:
            raise ValueError(f"tenant {self.name!r}: weight must be >= 0")


DEFAULT_TENANT_SPEC = TenantSpec(name="default")


class TokenBucket:
    """Clock-driven token bucket: ``rate`` tokens/s up to ``burst``.

    Refill is computed lazily from elapsed clock time at each take(), so the
    bucket needs no timer thread and is exact under VirtualClock.
    """

    def __init__(self, rate: float, burst: float):
        assert rate > 0 and burst >= 1
        self.rate = rate
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last = get_clock().now()
        self._lock = threading.Lock()

    def _refill(self, now: float) -> None:
        # callers hold self._lock.  A clock that jumped backward (fresh
        # VirtualClock after a wall-clock construction) must not freeze the
        # bucket, so negative elapsed re-bases instead of subtracting.
        elapsed = now - self._last
        if elapsed > 0:
            self._tokens = min(self.burst, self._tokens + elapsed * self.rate)
        self._last = now

    def take(self, n: int = 1) -> bool:
        """Consume ``n`` tokens if available; False (and no change) if not."""
        now = get_clock().now()
        with self._lock:
            self._refill(now)
            if self._tokens + 1e-9 < n:
                return False
            self._tokens -= n
            return True

    def put(self, n: int) -> None:
        """Refund ``n`` tokens (an admit rolled back), capped at burst."""
        with self._lock:
            self._tokens = min(self.burst, self._tokens + n)

    def available(self) -> float:
        now = get_clock().now()
        with self._lock:
            self._refill(now)
            return self._tokens

    def wait_hint_s(self, n: int = 1) -> float:
        """Seconds until ``n`` tokens will have refilled (retry-after)."""
        return max(0.0, (n - self.available()) / self.rate)


class AdmissionController:
    """Per-tenant token buckets + bounded queues + weight lookups.

    ``admit()`` is all-or-nothing across the whole call: a partially
    admitted workflow would deadlock on its rejected half, so either every
    task in the list enters or none do — a rejection refunds everything the
    same call already charged (tokens and queue slots alike).
    """

    def __init__(self, tenants: Optional[list[TenantSpec]] = None):
        self._lock = threading.Lock()
        self._specs: dict[str, TenantSpec] = {}
        self._buckets: dict[str, TokenBucket] = {}
        self._held: dict[str, int] = {}  # tenant -> admitted, unreleased tasks
        # counters for stats()/benchmarks: rejections by (tenant, reason).
        # With an event bus attached these become the strict-mode ground
        # truth; stats() itself reads the log-derived view (core/events.py)
        self.admitted = 0
        self.rejected: dict[tuple[str, str], int] = {}
        self._events = None  # broker-owned EventBus, via attach_events()
        for spec in tenants or []:
            self.add_tenant(spec)

    def attach_events(self, bus) -> None:
        """Wire the broker's event bus: admission decisions become
        admission.accept / admission.reject events and stats() turns into
        a derived view over the log."""
        self._events = bus

    def add_tenant(self, spec: TenantSpec) -> None:
        with self._lock:
            self._specs[spec.name] = spec
            if spec.rate is not None:
                burst = spec.burst if spec.burst is not None else spec.rate
                self._buckets[spec.name] = TokenBucket(spec.rate, max(1.0, burst))
            else:
                self._buckets.pop(spec.name, None)

    def spec(self, tenant: str) -> TenantSpec:
        with self._lock:
            return self._specs.get(tenant, DEFAULT_TENANT_SPEC)

    def weight(self, tenant: str) -> float:
        return self.spec(tenant).weight

    # -- the gate ---------------------------------------------------------
    def admit(self, tasks: list[Task]) -> None:
        """Charge each task against its tenant's bucket and queue bound.
        Raises AdmissionError on the first tenant that cannot take its whole
        group, refunding anything the call already charged.  Already-admitted
        tasks (internal requeues) pass through untouched."""
        fresh = [t for t in tasks if not t.admitted]
        if not fresh:
            return
        by_tenant: dict[str, list[Task]] = {}
        for t in fresh:
            by_tenant.setdefault(t.tenant, []).append(t)
        # phase 1 — charge every tenant's bucket and queue bound; a rejection
        # refunds the groups charged before it and raises with nothing held
        charged: list[tuple[str, int, Optional[TokenBucket]]] = []
        for tenant, group in by_tenant.items():
            n = len(group)
            with self._lock:
                spec = self._specs.get(tenant, DEFAULT_TENANT_SPEC)
                bucket = self._buckets.get(tenant)
                held = self._held.get(tenant, 0)
                queue_full = spec.max_queued is not None and held + n > spec.max_queued
                if not queue_full:
                    self._held[tenant] = held + n
            if not queue_full and bucket is not None and not bucket.take(n):
                with self._lock:
                    self._held[tenant] -= n
                self._reject(
                    charged,
                    tenant,
                    "rate_limited",
                    f"{n} task(s) exceed the available {bucket.available():.1f} tokens",
                    retry_after_s=bucket.wait_hint_s(n),
                )
            if queue_full:
                self._reject(
                    charged,
                    tenant,
                    "queue_full",
                    f"{held} queued + {n} submitted > max_queued {spec.max_queued}",
                )
            charged.append((tenant, n, bucket))
        # phase 2 — commit: nothing below can fail, so the release callback
        # is registered only for tasks that actually hold a slot
        with self._lock:
            self.admitted += len(fresh)
            if self._events is not None:
                for tenant, group in by_tenant.items():
                    self._events.emit("admission.accept", tenant=tenant, n=len(group))
        for tenant, group in by_tenant.items():
            for t in group:
                t.admitted = True
                t.admission_held = True
                # release on resolution, whatever path the task took to get
                # there (completion, retry exhaustion, cancel-while-queued)
                t.add_done_callback(self._release_cb)

    def _reject(
        self,
        charged: list[tuple[str, int, Optional["TokenBucket"]]],
        tenant: str,
        reason: str,
        detail: str,
        retry_after_s: Optional[float] = None,
    ) -> None:
        """Refund everything this admit() call charged, then raise."""
        with self._lock:
            for other, n, bucket in charged:
                self._held[other] = max(0, self._held.get(other, 0) - n)
            self.rejected[(tenant, reason)] = self.rejected.get((tenant, reason), 0) + 1
            if self._events is not None:
                self._events.emit("admission.reject", tenant=tenant, reason=reason)
        for _, n, bucket in charged:
            if bucket is not None:
                bucket.put(n)
        raise AdmissionError(tenant, reason, detail, retry_after_s=retry_after_s)

    def _release_cb(self, fut) -> None:
        self.release(fut)

    def release(self, task: Task) -> None:
        """Free the task's queue slot (idempotent: pop + done-callback may
        both fire; the flag flip under the lock picks exactly one winner)."""
        with self._lock:
            if not getattr(task, "admission_held", False):
                return
            task.admission_held = False
            tenant = task.tenant
            self._held[tenant] = max(0, self._held.get(tenant, 0) - 1)

    def held(self, tenant: str) -> int:
        with self._lock:
            return self._held.get(tenant, 0)

    def stats(self) -> dict:
        """Dict-shaped adapter.  The admit/reject totals are the log-derived
        view when a bus is attached (emission is adjacent to the legacy
        increments, under this controller's lock, so the two never drift);
        held/tenants are live gauges, not log folds."""
        if self._events is not None:
            view = self._events.view
            admitted = int(view.get("hydra.admission.admitted"))
            rejected = {
                k: int(v)
                for k, v in sorted(view.keyed_get("hydra.admission.rejected").items())
            }
        else:
            with self._lock:
                admitted = self.admitted
                rejected = {
                    f"{tenant}:{reason}": n
                    for (tenant, reason), n in sorted(self.rejected.items())
                }
        with self._lock:
            return {
                "tenants": sorted(self._specs),
                "held": dict(self._held),
                "admitted": admitted,
                "rejected": rejected,
            }
