"""Elastic provider autoscaler: queue-pressure-driven acquisition/release.

The paper's headline capability is *concurrently acquiring* resources from
cloud and HPC platforms (§1, §4): a cloud VM arrives after a startup latency
of seconds, an HPC allocation after a batch-queue wait of minutes, and the
broker exploits whatever shows up first.  Up to now every provider had to be
registered up front and was held for the whole run; this module turns the
static pool into the elastic broker the paper describes.

The control loop (see docs/ARCHITECTURE.md for the full diagram):

  pressure signals  ->  hysteresis  ->  acquire / release
  ----------------      ----------      -----------------
  ready-queue depth     warmup_ticks    sample the platform's acquisition
  (dispatcher), task    consecutive     latency model (cloud startup vs HPC
  backlog vs live +     pressured /     queue wait) on the active Clock via
  incoming slots,       cooldown_ticks  call_later; scale-in drains through
  per-group breaker     idle ticks      remove_provider(drain=True) and
  state (tripped                        deregisters so names recycle.
  members leave the
  supply side)

Determinism: latency samples come from one seeded ``random.Random`` owned by
the ProviderPool, and every wait (ticks, acquisition latencies, drains) goes
through the active Clock — under a VirtualClock the whole scale-out/scale-in
life cycle runs in real milliseconds and is exactly reproducible
(tests/test_autoscaler.py, benchmarks/exp7_elastic.py).

Launchable templates must model acquisition latency HERE (LatencyModel), not
via ``ProviderSpec.queue_delay_s``: the spec-level delay models per-submit
waits on an already-standing allocation, while the pool's latency model is
paid once, at acquisition time.
"""
from __future__ import annotations

import math
import random
import threading
from dataclasses import dataclass, field, replace
from typing import Optional

from repro.core.provider import ProviderSpec, ValidationError
from repro.runtime.clock import ScheduledCall, get_clock
from repro.runtime.tracing import Trace


# ---------------------------------------------------------------------------
# Per-platform acquisition latency models
# ---------------------------------------------------------------------------


@dataclass
class LatencyModel:
    """Acquisition latency distribution for one platform kind.

    ``lognormal`` is the literature default for both cloud VM startup and
    HPC queue waits (long right tail); ``mean_s`` parameterizes the mean of
    the distribution itself (mu is derived), so swapping sigma does not move
    the expected latency.
    """

    distribution: str = "lognormal"  # "lognormal" | "uniform" | "fixed"
    mean_s: float = 45.0
    sigma: float = 0.25  # lognormal shape
    lo_s: float = 0.0  # uniform bounds
    hi_s: float = 0.0

    def sample(self, rng: random.Random) -> float:
        if self.distribution == "fixed":
            return max(0.0, self.mean_s)
        if self.distribution == "uniform":
            return rng.uniform(self.lo_s, max(self.lo_s, self.hi_s))
        if self.distribution == "lognormal":
            mu = math.log(max(self.mean_s, 1e-9)) - self.sigma**2 / 2.0
            return rng.lognormvariate(mu, self.sigma)
        raise ValidationError(f"unknown latency distribution {self.distribution!r}")

    @property
    def expected_s(self) -> float:
        if self.distribution == "uniform":
            return (self.lo_s + max(self.lo_s, self.hi_s)) / 2.0
        return self.mean_s


def cloud_startup(mean_s: float = 45.0, sigma: float = 0.25) -> LatencyModel:
    """Cloud VM/container bring-up: tens of seconds, mild spread."""
    return LatencyModel(distribution="lognormal", mean_s=mean_s, sigma=sigma)


def hpc_queue_wait(mean_s: float = 300.0, sigma: float = 0.5) -> LatencyModel:
    """HPC batch-queue wait: minutes, heavy right tail."""
    return LatencyModel(distribution="lognormal", mean_s=mean_s, sigma=sigma)


DEFAULT_LATENCY = {"cloud": cloud_startup, "hpc": hpc_queue_wait}


# ---------------------------------------------------------------------------
# The declarative pool of launchable providers
# ---------------------------------------------------------------------------


@dataclass
class LaunchSpec:
    """One launchable provider template + its elasticity bounds.

    ``template.name`` is the instance-name prefix: acquired instances are
    ``{name}-1``, ``{name}-2``, ... with a monotone counter, so a released
    slot is never re-registered under a stale name.  ``group`` names a live
    ProviderGroup every instance joins on arrival (dynamic membership).
    """

    template: ProviderSpec
    min_instances: int = 0
    max_instances: int = 4
    latency: Optional[LatencyModel] = None  # default: per template.platform
    group: Optional[str] = None
    # market knobs (core/market.py): dollars per slot-hour of occupancy, and
    # an optional PreemptionHazard (revocation-rate model).  0.0 / None keep
    # pre-market pools free and non-preemptible.
    price_per_slot_hour: float = 0.0
    hazard: Optional["object"] = None  # market.PreemptionHazard (no import cycle)

    def __post_init__(self):
        if (
            self.min_instances < 0
            or self.max_instances < 0
            or self.max_instances < self.min_instances
        ):
            raise ValidationError(
                f"launch spec {self.template.name!r}: need 0 <= min <= max, "
                f"got [{self.min_instances}, {self.max_instances}]"
            )
        if self.price_per_slot_hour < 0:
            raise ValidationError(
                f"launch spec {self.template.name!r}: negative "
                f"price_per_slot_hour {self.price_per_slot_hour}"
            )
        if self.latency is None:
            make = DEFAULT_LATENCY.get(self.template.platform)
            if make is None:
                raise ValidationError(
                    f"launch spec {self.template.name!r}: no default latency "
                    f"model for platform {self.template.platform!r}"
                )
            self.latency = make()

    @property
    def slots_per_instance(self) -> int:
        return max(1, self.template.concurrency * self.template.n_nodes)


@dataclass
class _SpecState:
    """Pool-internal bookkeeping for one LaunchSpec."""

    launch: LaunchSpec
    counter: int = 0
    pending: set = field(default_factory=set)  # instance names in flight
    live: list = field(default_factory=list)  # arrival order (scale-in = LIFO)
    failures: int = 0  # consecutive failed arrivals (quarantine gate)


class ProviderPool:
    """Declarative pool of launchable specs + instance bookkeeping.

    The pool owns the seeded RNG every latency sample draws from, which is
    what makes a whole elastic run reproducible from one integer seed.

    A spec whose arrivals keep failing (e.g. a misconfigured group target)
    is quarantined after ``MAX_CONSECUTIVE_FAILURES``: it leaves both the
    scale-out candidate list and the min-fill set, so one broken template
    cannot buy providers in an unbounded loop.
    """

    MAX_CONSECUTIVE_FAILURES = 3

    def __init__(self, specs: list[LaunchSpec], seed: int = 0):
        if not specs:
            raise ValidationError("provider pool: needs at least one launch spec")
        names = [s.template.name for s in specs]
        if len(set(names)) != len(names):
            raise ValidationError(f"provider pool: duplicate templates {names}")
        self.rng = random.Random(seed)
        self._lock = threading.Lock()
        self._states = {s.template.name: _SpecState(launch=s) for s in specs}
        self._arrival_seq = 0
        self._arrival_order: dict[str, int] = {}  # instance -> global seq

    @property
    def specs(self) -> list[LaunchSpec]:
        return [st.launch for st in self._states.values()]

    # -- scale-out side --------------------------------------------------
    def candidates(self) -> list[LaunchSpec]:
        """Launch specs with headroom, fastest expected acquisition first —
        under pressure the broker grabs cloud capacity (seconds) before
        committing to an HPC queue wait (minutes)."""
        with self._lock:
            open_ = [
                st.launch
                for st in self._states.values()
                if len(st.pending) + len(st.live) < st.launch.max_instances
                and st.failures < self.MAX_CONSECUTIVE_FAILURES
            ]
        return sorted(open_, key=lambda s: s.latency.expected_s)

    def below_min(self) -> list[LaunchSpec]:
        with self._lock:
            return [
                st.launch
                for st in self._states.values()
                if len(st.pending) + len(st.live) < st.launch.min_instances
                and st.failures < self.MAX_CONSECUTIVE_FAILURES
            ]

    def request_instance(self, launch: LaunchSpec) -> ProviderSpec:
        """Mint the next instance spec and mark it pending."""
        with self._lock:
            st = self._states[launch.template.name]
            st.counter += 1
            name = f"{launch.template.name}-{st.counter}"
            st.pending.add(name)
        return replace(launch.template, name=name)

    def note_live(self, launch: LaunchSpec, name: str) -> None:
        with self._lock:
            st = self._states[launch.template.name]
            st.pending.discard(name)
            st.live.append(name)
            st.failures = 0
            self._arrival_seq += 1
            self._arrival_order[name] = self._arrival_seq

    def note_failed(self, launch: LaunchSpec, name: str) -> None:
        """An arrival failed to register: count toward quarantine."""
        with self._lock:
            self._states[launch.template.name].failures += 1
            self._forget(launch, name)

    def note_gone(self, launch: LaunchSpec, name: str) -> None:
        """Aborted acquisition or completed release."""
        with self._lock:
            self._forget(launch, name)

    # -- quarantine controls (chaos injection / operator override) -------
    def force_quarantine(self, template: str) -> None:
        """Declare a template's arrivals doomed (provisioning-API outage):
        push its consecutive-failure counter straight to the quarantine
        gate, so the scale-out loop stops buying it.  A later successful
        arrival (note_live) or an explicit rehabilitate() re-opens it."""
        with self._lock:
            self._states[template].failures = self.MAX_CONSECUTIVE_FAILURES

    def rehabilitate(self, template: str) -> None:
        """Lift a quarantine (the provisioning outage window closed)."""
        with self._lock:
            self._states[template].failures = 0

    def quarantined(self) -> list[str]:
        with self._lock:
            return sorted(
                name
                for name, st in self._states.items()
                if st.failures >= self.MAX_CONSECUTIVE_FAILURES
            )

    def _forget(self, launch: LaunchSpec, name: str) -> None:
        # callers hold self._lock
        st = self._states[launch.template.name]
        st.pending.discard(name)
        if name in st.live:
            st.live.remove(name)
        self._arrival_order.pop(name, None)

    # -- scale-in side ---------------------------------------------------
    def releasable(self) -> Optional[tuple[LaunchSpec, str]]:
        """Globally-youngest live instance above its spec's min bound (LIFO
        keeps the longest-warmed instances, which have the most policy/EWMA
        history — and never drains an old HPC allocation while a seconds-old
        cloud VM survives).  LIVE instances alone must exceed the min:
        pending acquisitions may still fail or be withdrawn, and min is a
        standing-capacity promise, not a bookkeeping one."""
        with self._lock:
            best: Optional[tuple[LaunchSpec, str]] = None
            best_seq = -1
            for st in self._states.values():
                if len(st.live) > st.launch.min_instances:
                    name = st.live[-1]
                    seq = self._arrival_order.get(name, 0)
                    if seq > best_seq:
                        best, best_seq = (st.launch, name), seq
            return best

    def abortable(self) -> Optional[tuple[LaunchSpec, str]]:
        """A pending acquisition that may be withdrawn (above min)."""
        with self._lock:
            for st in self._states.values():
                if len(st.live) + len(st.pending) > st.launch.min_instances and st.pending:
                    return (st.launch, next(iter(st.pending)))
            return None

    def counts(self) -> dict:
        with self._lock:
            return {
                name: {"live": len(st.live), "pending": len(st.pending)}
                for name, st in self._states.items()
            }

    def live_instances(self) -> list[str]:
        with self._lock:
            return [n for st in self._states.values() for n in st.live]


# ---------------------------------------------------------------------------
# The control loop
# ---------------------------------------------------------------------------


class Autoscaler:
    """Watches broker queue pressure through the Clock abstraction and
    elastically acquires/releases providers from a ProviderPool.

    Pressure := (ready-queue depth + task backlog) / (live + incoming slots).
    Hysteresis: ``warmup_ticks`` consecutive pressured ticks before an
    acquisition, ``cooldown_ticks`` consecutive idle ticks before a release —
    so a single bursty tick neither buys a VM nor kills one mid-drain.
    """

    def __init__(
        self,
        broker,
        pool: ProviderPool,
        tick_s: float = 1.0,
        scale_out_pressure: float = 1.5,
        scale_in_pressure: float = 0.05,
        warmup_ticks: int = 3,
        cooldown_ticks: int = 5,
        max_concurrent_acquisitions: int = 4,
        interactive_scale_out_pressure: Optional[float] = None,
        planner=None,
    ):
        self.broker = broker
        self.pool = pool
        # market planner (core/market.py): when attached, it picks WHICH
        # template to acquire (cheapest feasible mix instead of fastest
        # arrival) and settles per-instance spend on release/loss
        self.planner = planner
        if planner is not None:
            planner.bind(self)
        self.tick_s = tick_s
        self.scale_out_pressure = scale_out_pressure
        self.scale_in_pressure = scale_in_pressure
        # per-class scale-out (the front door's third leg): when set,
        # interactive-lane pressure ALONE can open the scale-out gate at
        # this (typically lower) threshold — so a throttled batch tenant
        # cannot mask interactive demand behind a small aggregate number,
        # and the fleet grows for the latency-sensitive class first
        self.interactive_scale_out_pressure = interactive_scale_out_pressure
        self.warmup_ticks = max(1, warmup_ticks)
        self.cooldown_ticks = max(1, cooldown_ticks)
        self.max_concurrent_acquisitions = max(1, max_concurrent_acquisitions)
        self.trace = Trace()
        self._lock = threading.Lock()
        self._timers: dict[str, ScheduledCall] = {}  # instance -> arrival timer
        self._instance_launch: dict[str, LaunchSpec] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # ledger: one row per instance life cycle (exp7's cost curves)
        self.ledger: dict[str, dict] = {}
        # metrics
        self.ticks = 0
        self.acquisitions = 0
        self.arrivals = 0
        self.releases = 0
        self.aborts = 0
        self.last_pressure = 0.0
        self._hot = 0  # consecutive pressured ticks
        self._cold = 0  # consecutive idle ticks

    # -- lifecycle -------------------------------------------------------
    def _validate_pool(self) -> None:
        """Fail fast on misconfigured launch specs: a group target that does
        not exist or spans platforms would otherwise only surface as rolled
        back arrivals, one modeled latency at a time."""
        for launch in self.pool.specs:
            if launch.group is None:
                continue
            group = self.broker.proxy.get_group(launch.group)  # KeyError if absent
            if group.spec.platform != launch.template.platform:
                raise ValidationError(
                    f"launch spec {launch.template.name!r}: platform "
                    f"{launch.template.platform!r} cannot join group "
                    f"{launch.group!r} ({group.spec.platform!r})"
                )

    def start(self) -> "Autoscaler":
        if self._thread is None:
            self._validate_pool()
            self._fill_to_min()
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="hydra-autoscaler"
            )
            self._thread.start()
            self.trace.add("autoscaler_started")
        return self

    def stop(self, wait: bool = True) -> None:
        # join the control thread FIRST: a tick in progress could otherwise
        # start a fresh acquisition after the sweep below, leaving an
        # orphaned pending record and an armed timer behind
        self._stop.set()
        if wait and self._thread is not None:
            self._thread.join(timeout=5.0)
        with self._lock:
            timers = list(self._timers.items())
            self._timers.clear()
        for name, call in timers:  # withdraw in-flight acquisitions
            call.cancel()
            if not self.broker.abort_acquisition(name):
                continue  # already arrived (LIVE): bookkeeping must stand
            launch = self._instance_launch.pop(name, None)
            if launch is not None:
                self.pool.note_gone(launch, name)
        if self.planner is not None:
            # close the books: still-live instances accrued spend up to now
            with self._lock:
                live = list(self._instance_launch.items())
            for name, launch in live:
                row = self.ledger.get(name)
                if row is not None and row.get("arrived_at") is not None:
                    self.planner.settle(launch, name, row)
        self.trace.add("autoscaler_stopped")

    def _loop(self) -> None:
        while not get_clock().wait_event(self._stop, self.tick_s):
            try:
                self._tick()
            except Exception:
                # the loop is the pool's lifeline: a raced removal or a
                # recovery-path error must never kill the control thread
                self.trace.add("tick_error")

    # -- the decision tick ------------------------------------------------
    def _demand(self) -> float:
        """Runnable demand: ready-queue depth + backlog, minus tasks stalled
        purely on staging (core/staging.py), PLUS a decayed count of tasks
        parked at the staging gate.  A task waiting on bytes is not a task a
        new provider could run *right now* — the dispatcher parks first-time
        stage-ins outside the ready heap (so queue_depth() never sees them),
        and ``staging_stalled_in_backlog()`` subtracts the re-gated retries
        the backlog counter still holds.  But those parked tasks WILL become
        runnable the moment their transfers land, and pretending they don't
        exist made a data-heavy burst invisible: the fleet stayed cold until
        the bytes arrived, then every transfer completed into an undersized
        pool.  ``deferred_demand()`` counts each parked task as
        exp(-age/tau) — full weight when freshly parked (transfer about to
        finish soon), decaying toward zero for tasks stuck behind slow or
        broken links that no amount of compute would help.  Every input here
        is O(1) or O(parked), so the tick stays cheap at 256 providers."""
        queued = self.broker.queue_depth()
        stalled = self.broker.staging_stalled_in_backlog()
        deferred = self.broker.deferred_demand()
        return queued + max(0, self.broker.backlog() - stalled) + deferred

    def pressure(self) -> float:
        """Demand per available slot.  Zero-supply semantics (see
        Dispatcher.queue_pressure): no demand -> 0.0 regardless of supply;
        demand with zero live+incoming slots first consults probe_slots()
        (capacity a probe could still reach, e.g. half-open breakers), and
        if there is truly nothing, returns +inf — an entirely tripped fleet
        facing a deep queue is the MOST pressured state, not the least.
        The old ``demand / max(supply, 1)`` degenerated to the raw pending
        count at supply==0, which merely *scaled* with the backlog instead
        of slamming the scale-out gate."""
        demand = self._demand()
        if demand <= 0:
            return 0.0
        supply = self.broker.total_slots() + self.broker.incoming_slots()
        if supply <= 0:
            supply = self.broker.probe_slots()
        if supply <= 0:
            return float("inf")
        return demand / supply

    def interactive_pressure(self) -> float:
        """Interactive-lane depth per available slot (same zero-supply
        semantics as pressure()).  Only meaningful with the multi-tenant
        front door attached; 0.0 otherwise."""
        depth = self.broker.queue_depth_by_class().get("interactive", 0)
        if depth <= 0:
            return 0.0
        supply = self.broker.total_slots() + self.broker.incoming_slots()
        if supply <= 0:
            supply = self.broker.probe_slots()
        if supply <= 0:
            return float("inf")
        return depth / supply

    def _tick(self) -> None:
        self.ticks += 1
        p = self.pressure()
        self.last_pressure = p
        self.broker.events.emit(
            "scale.tick", pressure=p if math.isfinite(p) else None
        )
        if self.planner is not None:
            # the bid loop: re-rank the platform mix every tick so price or
            # hazard movement re-routes the NEXT acquisition immediately
            self.planner.replan(self._demand())
        if self.interactive_scale_out_pressure is not None and p < self.scale_out_pressure:
            # the per-class gate: interactive depth alone can force the
            # scale-out path even when aggregate pressure looks tame
            if self.interactive_pressure() >= self.interactive_scale_out_pressure:
                p = self.scale_out_pressure
        if p >= self.scale_out_pressure:
            self._hot += 1
            self._cold = 0
            if self._hot >= self.warmup_ticks:
                self._scale_out()
                self._hot = 0
        elif p <= self.scale_in_pressure:
            self._cold += 1
            self._hot = 0
            if self._cold >= self.cooldown_ticks:
                self._scale_in()
                self._cold = 0
        else:
            self._hot = 0
            self._cold = 0
        self._fill_to_min()

    def _scale_out(self) -> None:
        """Acquire enough instances to absorb the current deficit, bounded
        by per-spec max and the concurrent-acquisition cap.  candidates()
        re-ranks each round, so the fastest-arriving platform with headroom
        keeps winning until the deficit is covered."""
        deficit = self._demand() - (
            self.broker.total_slots() + self.broker.incoming_slots()
        )
        while (
            deficit > 0
            and not self._stop.is_set()
            and len(self.broker.pending_acquisitions()) < self.max_concurrent_acquisitions
        ):
            candidates = self.pool.candidates()
            if not candidates:
                return
            if self.planner is not None:
                launch = self.planner.choose(candidates, deficit)
                if launch is None:  # nothing feasible under the SLO budget
                    return
            else:
                launch = candidates[0]
            self._acquire(launch)
            deficit -= launch.slots_per_instance

    def _scale_in(self) -> None:
        """Withdraw a not-yet-arrived acquisition first (free), else drain
        and release the youngest live instance above its min bound."""
        pending = self.pool.abortable()
        if pending is not None:
            launch, name = pending
            self._abort(launch, name)
            return
        live = self.pool.releasable()
        if live is not None:
            launch, name = live
            self._release(launch, name)

    # -- acquisition -------------------------------------------------------
    def _fill_to_min(self) -> None:
        for launch in self.pool.below_min():
            st_min = launch.min_instances
            while not self._stop.is_set():
                counts = self.pool.counts()[launch.template.name]
                if counts["live"] + counts["pending"] >= st_min:
                    break
                self._acquire(launch)

    def _acquire(self, launch: LaunchSpec) -> str:
        clock = get_clock()
        eta = launch.latency.sample(self.pool.rng)
        spec = self.pool.request_instance(launch)
        self.broker.begin_acquisition(spec, eta, group=launch.group)
        with self._lock:
            self._instance_launch[spec.name] = launch
            self.ledger[spec.name] = {
                "platform": spec.platform,
                "requested_at": clock.now(),
                "eta_s": eta,
                "arrived_at": None,
                "released_at": None,
            }
        self.acquisitions += 1
        self.broker.events.emit(
            "acquire.begin", instance=spec.name, platform=spec.platform
        )
        self.trace.add(f"acquire:{spec.name}:eta={eta:.1f}")
        call = clock.call_later(eta, lambda: self._arrive(launch, spec))
        with self._lock:
            if spec.name not in self._instance_launch:  # stopped mid-register
                call.cancel()
            elif call.active:
                # an already-fired call (eta ~0, or the clock jumped inside
                # call_later) must NOT be kept: stop()'s sweep would misread
                # the LIVE instance as a withdrawable pending acquisition
                self._timers[spec.name] = call
        return spec.name

    def _arrive(self, launch: LaunchSpec, spec: ProviderSpec) -> None:
        """Acquisition latency elapsed (runs on a clock thread)."""
        with self._lock:
            self._timers.pop(spec.name, None)
        try:
            handle = self.broker.complete_acquisition(spec)
        except Exception:
            self.trace.add(f"acquire_failed:{spec.name}")
            self.pool.note_failed(launch, spec.name)  # counts toward quarantine
            self.broker.abort_acquisition(spec.name)
            return
        if handle is None:  # aborted while the timer was in flight
            self.pool.note_gone(launch, spec.name)
            return
        self.pool.note_live(launch, spec.name)
        with self._lock:
            row = self.ledger.get(spec.name)
            if row is not None:
                row["arrived_at"] = get_clock().now()
        self.arrivals += 1
        self.broker.events.emit("acquire.complete", instance=spec.name)
        self.trace.add(f"arrived:{spec.name}")
        # new capacity: wake the dispatcher so backfill sees it NOW
        self.broker._notify_capacity()

    def note_provider_lost(self, name: str) -> None:
        """The broker blacklisted one of our instances (hard outage,
        Hydra._handle_provider_down).  Without this hook the dead name would
        occupy max_instances headroom forever and broken capacity could
        never be replaced under pressure.  Grouped members are NOT routed
        here: their breaker may half-open and recover."""
        with self._lock:
            launch = self._instance_launch.pop(name, None)
            call = self._timers.pop(name, None)
            row = self.ledger.get(name)
            if row is not None and row["released_at"] is None:
                row["released_at"] = get_clock().now()
        if launch is None:
            return
        if call is not None:
            call.cancel()
        self.broker.abort_acquisition(name)
        self.pool.note_gone(launch, name)
        if self.planner is not None and row is not None:
            self.planner.settle(launch, name, row)
        self.trace.add(f"lost:{name}")

    # -- release -----------------------------------------------------------
    def _abort(self, launch: LaunchSpec, name: str) -> None:
        with self._lock:
            call = self._timers.get(name)
        if call is not None:
            call.cancel()
        if not self.broker.abort_acquisition(name):
            return  # lost the race to _arrive: the instance is LIVE, keep it
        with self._lock:
            self._timers.pop(name, None)
            self._instance_launch.pop(name, None)
        self.aborts += 1
        self.broker.events.emit("acquire.abort", instance=name)
        self.trace.add(f"abort:{name}")
        self.pool.note_gone(launch, name)

    def _release(self, launch: LaunchSpec, name: str) -> None:
        """Scale-in through the drain path: unfinished tasks re-bind to the
        surviving pool before the manager shuts down."""
        with self._lock:
            self._instance_launch.pop(name, None)
        self.trace.add(f"release:{name}")
        try:
            self.broker.remove_provider(name, drain=True, deregister=True)
        except KeyError:
            pass  # raced with an outage-path removal: already gone
        self.pool.note_gone(launch, name)
        with self._lock:
            row = self.ledger.get(name)
            if row is not None:
                row["released_at"] = get_clock().now()
        if self.planner is not None and row is not None:
            self.planner.settle(launch, name, row)
        self.releases += 1
        self.broker.events.emit("scale.release", instance=name)

    # -- metrics -----------------------------------------------------------
    def node_seconds(self, until: Optional[float] = None) -> float:
        """Total provider-seconds held (the cost side of exp7's
        over-provisioning-vs-queue-wait curve)."""
        end = until if until is not None else get_clock().now()
        total = 0.0
        with self._lock:
            rows = list(self.ledger.values())
        for row in rows:
            if row["arrived_at"] is None:
                continue
            total += max(0.0, (row["released_at"] or end) - row["arrived_at"])
        return total

    def stats(self) -> dict:
        """Dict-shaped adapter: the decision counters are the log-derived
        view over scale.*/acquire.* events (core/events.py); the legacy
        accumulators stay as HYDRA_EVENTS_CHECK ground truth.  Pressure
        and pool state are live gauges."""
        view = self.broker.events.view
        return {
            "ticks": int(view.get("hydra.scale.ticks")),
            "acquisitions": int(view.get("hydra.scale.acquisitions")),
            "arrivals": int(view.get("hydra.scale.arrivals")),
            "releases": int(view.get("hydra.scale.releases")),
            "aborts": int(view.get("hydra.scale.aborts")),
            # JSON-safe: the +inf zero-supply sentinel serializes as null
            "last_pressure": (
                round(self.last_pressure, 3)
                if math.isfinite(self.last_pressure)
                else None
            ),
            "staging_stalled": self.broker.staging_stalled(),
            "deferred_demand": round(self.broker.deferred_demand(), 3),
            "hot_ticks": self._hot,
            "cold_ticks": self._cold,
            "pool": self.pool.counts(),
        }
