"""Task: the unit of brokered work (paper §3.2: "Task extends
concurrent.futures.Future").

A Task is a Future-like object holding the workload description, resource
requirements, provider binding, a strict state machine, and a trace of
timestamped events.  Kinds:

  noop      - zero-work task (the paper's overhead-isolation instrument)
  callable  - arbitrary python callable (the "executable" task type)
  compute   - a JAX workload: (arch, shape, step kind) executed via a
              compiled artifact (the "container" task type on TPU pools)
  sleep     - fixed-duration task (paper Exp 3B heterogeneous workloads)
  kernel    - real Pallas work: ``payload`` names a registered kernel plus
              problem shape/dtype/reps, resolved against kernels/registry.py
              and executed rep-by-rep (progress_frac advances per completed
              rep, so checkpoint/resume skips finished reps)
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass
from enum import Enum
from typing import Any, Callable, Optional

from repro.runtime.tracing import Counter, Trace

_ids = Counter("task")


class TaskState(str, Enum):
    NEW = "NEW"
    BOUND = "BOUND"  # assigned to a provider by the binding policy
    PARTITIONED = "PARTITIONED"  # placed into a pod
    SUBMITTED = "SUBMITTED"  # pod handed to the provider connector
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELED = "CANCELED"


FINAL_STATES = {TaskState.DONE, TaskState.FAILED, TaskState.CANCELED}

# SLO classes, in strict drain-priority order: the dispatcher empties every
# "interactive" lane before any "batch" lane sees budget (core/dispatcher.py)
SLO_CLASSES = ("interactive", "batch")

LEGAL = {
    TaskState.NEW: {TaskState.BOUND, TaskState.CANCELED},
    TaskState.BOUND: {TaskState.PARTITIONED, TaskState.BOUND, TaskState.CANCELED},
    TaskState.PARTITIONED: {TaskState.SUBMITTED, TaskState.BOUND, TaskState.CANCELED},
    TaskState.SUBMITTED: {TaskState.RUNNING, TaskState.BOUND, TaskState.FAILED, TaskState.CANCELED},
    TaskState.RUNNING: {TaskState.DONE, TaskState.FAILED, TaskState.CANCELED},
    TaskState.DONE: set(),
    TaskState.FAILED: {TaskState.BOUND},  # retry: re-bind
    TaskState.CANCELED: set(),
}


class IllegalTransition(RuntimeError):
    pass


@dataclass
class Resources:
    """Per-task resource requirements (the paper's cpu/gpu/memory triple)."""

    cpus: int = 1
    accels: int = 0  # GPUs in the paper; TPU chips here
    memory_mb: int = 256

    def fits(self, cap: "Resources") -> bool:
        return self.cpus <= cap.cpus and self.accels <= cap.accels and self.memory_mb <= cap.memory_mb


class Task(Future):
    def __init__(
        self,
        kind: str = "noop",
        fn: Optional[Callable[[], Any]] = None,
        *,
        resources: Optional[Resources] = None,
        provider: Optional[str] = None,  # user-pinned provider (paper: task provider)
        arch: Optional[str] = None,
        shape: Optional[str] = None,
        step_kind: Optional[str] = None,
        duration: float = 0.0,  # for kind="sleep"
        payload: Any = None,
        max_retries: int = 2,
        inputs: Optional[list[str]] = None,
        outputs: Optional[dict[str, float]] = None,
        tenant: str = "default",
        slo_class: str = "batch",
    ):
        super().__init__()
        assert kind in ("noop", "callable", "compute", "sleep", "kernel"), kind
        assert slo_class in SLO_CLASSES, slo_class
        self.uid = _ids.next()
        self.kind = kind
        self.fn = fn
        self.resources = resources or Resources()
        self.pinned_provider = provider
        self.arch, self.shape, self.step_kind = arch, shape, step_kind
        self.duration = duration
        self.payload = payload
        self.max_retries = max_retries
        self.retries = 0
        self.provider: Optional[str] = provider
        # logical group binding; provider holds the concrete member resolved
        # at dispatch time (core/group.py) and may change on failover
        self.group: Optional[str] = None
        self.pod_uid: Optional[str] = None
        # streaming-dispatcher scheduling hints (core/dispatcher.py): DAG
        # depth orders micro-batches so shallow (critical-path-upstream)
        # tasks bind first and deeper-workflow tasks backfill idle capacity
        self.depth: int = 0
        self.workflow: Optional[str] = None
        # declared data dependencies (core/staging.py): ``inputs`` names
        # datasets that must be resident at the executing site before the
        # task runs; ``outputs`` maps produced dataset name -> size_mb,
        # registered at the executing site on completion (stage-out).
        self.inputs: list[str] = list(inputs or [])
        self.outputs: dict[str, float] = dict(outputs or {})
        # placement reserved by the dispatcher's staging gate: the binding
        # policy already chose (and accounted for) this target, so dispatch
        # must honor it — inputs were staged to its site on that promise
        self.reserved_provider: Optional[str] = None
        self.staging_attempts: int = 0
        # True once a dispatch round registered the task in a Submission the
        # broker's backlog() scan can see: the autoscaler uses it to subtract
        # staging-stalled retries from demand without double-discounting
        # first-time tasks (which are in neither the ready heap nor backlog)
        self.in_submission: bool = False
        # multi-tenant front door (core/admission.py + the dispatcher's
        # per-tenant lanes): ``tenant`` keys rate limits / queue bounds /
        # fair-share weights, ``slo_class`` picks the priority lane
        # ("interactive" preempts queued "batch" backfill).  ``admitted``
        # flips once the task passes admission (or is exempt: internal
        # requeues re-enter without being re-charged); ``admission_held``
        # marks a held queue slot and is cleared exactly once on release.
        self.tenant = tenant
        self.slo_class = slo_class
        self.admitted: bool = False
        self.admission_held: bool = False
        # task-level checkpoint/restore (ckpt/checkpoint.py): fraction of the
        # work already captured in a checkpoint dataset.  A resumed sleep
        # task executes only the remaining (1 - progress_frac) of its
        # duration; ``ckpt_dataset`` names the replicated checkpoint in the
        # DatasetRegistry (also appended to ``inputs`` so the staging gate
        # places the resume next to its bytes); ``resumes`` counts
        # checkpoint resumes, which — unlike ``retries`` — never charge
        # ``max_retries``.
        self.progress_frac: float = 0.0
        self.ckpt_dataset: Optional[str] = None
        self.resumes: int = 0
        # kind="kernel" bookkeeping (managers/compute.py KernelRuntime):
        # ``kernel_done_s`` accumulates wall seconds of *completed* reps
        # (the durable-progress clock the checkpointer reads on preempt);
        # ``kernel_stats`` is the last execution's summary the broker folds
        # into the ``kernel.exec`` event on successful completion.
        self.kernel_done_s: float = 0.0
        self.kernel_stats: Optional[dict] = None
        self.trace = Trace()
        self._state_lock = threading.RLock()
        self._tstate = TaskState.NEW
        self.trace.add("created")

    # ------------------------------------------------------------------
    @property
    def tstate(self) -> TaskState:
        return self._tstate

    def advance(self, new: TaskState) -> None:
        with self._state_lock:
            if new not in LEGAL[self._tstate]:
                raise IllegalTransition(f"{self.uid}: {self._tstate.value} -> {new.value}")
            self._tstate = new
            self.trace.add(f"state:{new.value}")

    def try_advance(self, new: TaskState) -> bool:
        with self._state_lock:
            if new not in LEGAL[self._tstate]:
                return False
            self._tstate = new
            self.trace.add(f"state:{new.value}")
            return True

    @property
    def final(self) -> bool:
        return self._tstate in FINAL_STATES

    # ------------------------------------------------------------------
    def mark_done(self, result: Any = None) -> None:
        """Completion is authoritative and idempotent: with re-binding and
        speculative copies the same work may finish more than once (or finish
        on the 'old' provider after a re-bind) - first completion wins, any
        state.  At-least-once execution, exactly-once completion."""
        with self._state_lock:
            if self._tstate in FINAL_STATES:  # duplicate completion: no-op
                return
            self._tstate = TaskState.DONE
            self.trace.add("state:DONE")
        self.trace.add("exec_done")
        if not self.done():
            self.set_result(result)

    def mark_failed(self, exc: BaseException) -> bool:
        """Race-safe: a stale failure (e.g. from a provider the task was
        already re-bound away from) is ignored unless the task is actually
        in-flight.  Returns True iff this call performed the transition."""
        with self._state_lock:
            if self._tstate not in (TaskState.SUBMITTED, TaskState.RUNNING):
                return False
            self._tstate = TaskState.FAILED
            self.trace.add("state:FAILED")
        self.trace.add("exec_failed")
        self.last_error = exc
        if self.retries >= self.max_retries and not self.done():
            self.set_exception(exc)
        return True

    def mark_canceled(self) -> None:
        with self._state_lock:
            if self._tstate in FINAL_STATES:
                return
            self._tstate = TaskState.CANCELED
            self.trace.add("state:CANCELED")
        if not self.done():
            self.cancel()
            if not self.cancelled():  # running futures refuse cancel(); force it
                self.set_exception(CancelledError(self.uid))

    def reset_for_retry(self) -> None:
        """FAILED -> BOUND (fault tolerance re-binding)."""
        with self._state_lock:
            self.retries += 1
            self.advance(TaskState.BOUND)
            self.pod_uid = None

    def reset_for_resume(self) -> None:
        """FAILED -> BOUND after a checkpoint capture (ckpt/checkpoint.py):
        the resumed task re-executes only the work beyond ``progress_frac``,
        and — unlike ``reset_for_retry`` — never charges ``max_retries``:
        preemption is the platform's fault, not the task's."""
        with self._state_lock:
            self.advance(TaskState.BOUND)
            self.pod_uid = None
            self.trace.add("resumed")


class CancelledError(RuntimeError):
    pass


def describe(task: Task) -> dict:
    """JSON-serializable task description (what gets written into a pod)."""
    return {
        "uid": task.uid,
        "kind": task.kind,
        "resources": vars(task.resources),
        "provider": task.provider,
        "group": task.group,
        "arch": task.arch,
        "shape": task.shape,
        "step_kind": task.step_kind,
        "duration": task.duration,
        "retries": task.retries,
        "inputs": list(task.inputs),
        "outputs": dict(task.outputs),
        "tenant": task.tenant,
        "slo_class": task.slo_class,
    }
