"""Resource request API (paper §3.2: the ``Resource`` class).

Users describe *what they want from a provider* - service type, amount of
resources, provider-specific properties - without touching provider APIs.
The Service Proxy turns an accepted ResourceRequest into live services.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.task import Resources


@dataclass
class ResourceRequest:
    provider: str
    service: str = "caas"  # "caas" | "pilot"
    n_nodes: int = 1
    vm_cpus: int = 16
    vm_memory_mb: int = 1 << 16
    accels_per_node: int = 8
    walltime_s: float = 3600.0  # pilot lease length
    properties: dict = field(default_factory=dict)  # provider-specific extras

    def capacity(self) -> Resources:
        return Resources(
            cpus=self.vm_cpus * self.n_nodes,
            accels=self.accels_per_node * self.n_nodes,
            memory_mb=self.vm_memory_mb * self.n_nodes,
        )
