"""Provider Groups: N compatible providers behind one logical bind target.

The paper's broker binds each task to a single concrete provider.  At
multi-tenant scale the natural unit is a *pool* of equivalent providers
(e.g. four regional CaaS endpoints of the same cloud): the binding policy
should see ONE logical name, while the broker balances load across members,
tracks per-member health with a circuit breaker, and transparently fails
work over when a member dies (see docs/ARCHITECTURE.md for where the group
layer slots into the submit path, and EXPERIMENTS.md §Perf for measured
failover overhead).

Semantics:

  * A group aggregates registered providers of the SAME platform (cloud or
    hpc).  The group exposes a synthetic ``spec`` whose capacity is the
    element-wise max over members, so eligibility checks
    (``Policy._eligible``) work unchanged on groups.
  * Policies bind tasks to the group *name*; the member is resolved at
    dispatch time by the group's balancing strategy.  ``Task.group`` records
    the logical binding, ``Task.provider`` the concrete member.
  * Each member carries a ``CircuitBreaker`` (fault.py).  ``ProviderDown``
    trips it immediately; ordinary task failures open it after
    ``failure_threshold`` consecutive errors; a timed half-open probe closes
    it again once the member recovers.
  * When every member's breaker is open the group raises
    ``GroupExhausted`` (a ``ProviderDown`` subtype), and the broker falls
    back to its normal cross-provider re-binding.

Strategies (pluggable, mirroring POLICIES in policy.py):

  round_robin   - cycle through available members.
  least_loaded  - member with the fewest outstanding tasks.
  weighted      - capacity-proportional: argmin outstanding/weight, weight =
                  member cpu+accel capacity (bigger pools absorb more load).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

from repro.core.fault import BreakerState, CircuitBreaker
from repro.core.managers.compute import ProviderDown
from repro.core.provider import ProviderHandle, ProviderSpec, ValidationError
from repro.core.task import Resources
from repro.runtime.tracing import Trace


class GroupExhausted(ProviderDown):
    """Every member breaker is open: the logical provider is down."""


@dataclass
class GroupMember:
    """One provider inside a group: identity + weight + health + load."""

    name: str
    weight: float = 1.0
    slots: int = 1  # concurrent task slots (spec concurrency x nodes)
    breaker: CircuitBreaker = field(default_factory=CircuitBreaker)
    outstanding: int = 0  # tasks dispatched, not yet completed/failed
    dispatched: int = 0
    completed: int = 0
    failed: int = 0


# ---------------------------------------------------------------------------
# Balancing strategies
# ---------------------------------------------------------------------------


class GroupStrategy:
    name = "base"

    def pick(self, members: list[GroupMember]) -> GroupMember:
        raise NotImplementedError


class RoundRobinStrategy(GroupStrategy):
    name = "round_robin"

    def __init__(self):
        self._n = 0

    def pick(self, members: list[GroupMember]) -> GroupMember:
        choice = members[self._n % len(members)]
        self._n += 1
        return choice


class LeastLoadedStrategy(GroupStrategy):
    name = "least_loaded"

    def pick(self, members: list[GroupMember]) -> GroupMember:
        return min(members, key=lambda m: (m.outstanding, m.dispatched))


class WeightedStrategy(GroupStrategy):
    """Capacity-proportional: fill members so load/weight stays balanced."""

    name = "weighted"

    def pick(self, members: list[GroupMember]) -> GroupMember:
        return min(members, key=lambda m: (m.outstanding + 1) / max(m.weight, 1e-9))


STRATEGIES = {
    s.name: s for s in (RoundRobinStrategy, LeastLoadedStrategy, WeightedStrategy)
}


def make_strategy(name: str) -> GroupStrategy:
    try:
        return STRATEGIES[name]()
    except KeyError:
        raise ValidationError(
            f"unknown group strategy {name!r}; choose from {sorted(STRATEGIES)}"
        ) from None


# ---------------------------------------------------------------------------
# The group
# ---------------------------------------------------------------------------


class ProviderGroup:
    """A load-balanced, failover-aware pool of providers.

    Duck-types the slice of ``ProviderHandle`` that binding policies use
    (``.name`` and ``.spec.capacity()``), so a group can stand anywhere a
    provider can in the bind path.
    """

    def __init__(
        self,
        name: str,
        handles: list[ProviderHandle],
        strategy: str = "round_robin",
        failure_threshold: int = 3,
        reset_timeout_s: float = 30.0,
        min_healthy: int = 1,
    ):
        if not handles:
            raise ValidationError(f"group {name!r}: needs at least one member")
        platforms = {h.spec.platform for h in handles}
        if len(platforms) > 1:
            raise ValidationError(
                f"group {name!r}: members span incompatible platforms {sorted(platforms)}"
            )
        names = [h.name for h in handles]
        if len(set(names)) != len(names):
            raise ValidationError(f"group {name!r}: duplicate members {names}")
        self.name = name
        self.min_healthy = min_healthy
        self.strategy = make_strategy(strategy)
        self.trace = Trace()
        self._lock = threading.Lock()
        self._members: dict[str, GroupMember] = {}
        # broker wiring (attach_runtime): the capacity ledger receives O(1)
        # member events; on_topology_change invalidates the proxy's cached
        # bind-target list on breaker transitions
        self._ledger = None
        self._on_topology_change = None
        self._events = None  # broker-owned EventBus (attach_runtime)
        # breaker config is remembered so members that JOIN a live group
        # (elastic scale-out, core/autoscaler.py) get identical protection
        self._failure_threshold = failure_threshold
        self._reset_timeout_s = reset_timeout_s
        for h in handles:
            self._members[h.name] = self._make_member(h)
        # synthetic spec: element-wise max member capacity, so a task fits
        # the group iff it fits the largest member
        self.spec = ProviderSpec(
            name=name,
            platform=handles[0].spec.platform,
            connector=handles[0].spec.connector,
            node_capacity=Resources(
                cpus=max(h.spec.capacity().cpus for h in handles),
                accels=max(h.spec.capacity().accels for h in handles),
                memory_mb=max(h.spec.capacity().memory_mb for h in handles),
            ),
            n_nodes=1,
        )
        self.trace.add("group_created")

    def _make_member(self, h: ProviderHandle) -> GroupMember:
        cap = h.spec.capacity()
        return GroupMember(
            name=h.name,
            weight=float(cap.cpus + cap.accels),
            slots=max(1, h.spec.concurrency * h.spec.n_nodes),
            breaker=CircuitBreaker(
                failure_threshold=self._failure_threshold,
                reset_timeout_s=self._reset_timeout_s,
            ),
        )

    # -- broker wiring (capacity ledger, core/ledger.py) -----------------
    def attach_runtime(self, ledger, on_topology_change=None, events=None) -> None:
        """Wire the broker's CapacityLedger (and the proxy's bind-target
        cache invalidation) into this group's member events: dispatch/
        completion load deltas, membership churn, and every breaker
        transition become O(1) ledger updates, replacing the per-read
        member scans the broker used to do.  ``events`` additionally puts
        every member counter change and breaker transition on the broker's
        event bus (core/events.py), making stats() a log-derived view."""
        with self._lock:
            self._ledger = ledger
            self._on_topology_change = on_topology_change
            self._events = events
            members = list(self._members.values())
        for m in members:
            self._wire_member(m)

    def _wire_member(self, m: GroupMember) -> None:
        ledger = self._ledger
        if ledger is not None:
            ledger.upsert_member(
                m.name, m.slots, counted=m.breaker.state != BreakerState.OPEN
            )

        def _on_transition(old, new, name=m.name):
            if self._ledger is not None:
                self._ledger.set_counted(name, new != BreakerState.OPEN)
            if self._events is not None:
                self._events.emit(
                    "breaker.transition", member=name, old=old.value, new=new.value
                )
            cb = self._on_topology_change
            if cb is not None:
                cb()

        m.breaker.on_transition = _on_transition

    def _ledger_load(self, name: str, delta: int) -> None:
        if self._ledger is not None:
            self._ledger.load_delta(name, delta)

    # -- membership ------------------------------------------------------
    @property
    def member_names(self) -> list[str]:
        with self._lock:  # remove_member may pop concurrently
            return list(self._members)

    def member(self, name: str) -> GroupMember:
        return self._members[name]

    def __contains__(self, name: str) -> bool:
        return name in self._members

    def available_members(self) -> list[GroupMember]:
        """Members whose breaker would admit traffic (non-mutating peek)."""
        with self._lock:
            members = list(self._members.values())
        return [m for m in members if m.breaker.available()]

    def routable(self) -> bool:
        """Is the group a valid bind target right now?"""
        return len(self.available_members()) >= max(1, self.min_healthy)

    def idle_slots(self) -> int:
        """Free concurrent-execution slots across breaker-available members.

        A *hint* for the streaming dispatcher's backfill sizing (how much
        ready work the pool can absorb right now), not an admission limit —
        members queue excess work internally."""
        with self._lock:
            members = list(self._members.values())
        return sum(
            max(0, m.slots - m.outstanding) for m in members if m.breaker.available()
        )

    # -- dispatch-time member resolution ---------------------------------
    def select(self, exclude: Optional[str] = None) -> str:
        """Resolve the member that receives the next pod.

        ``exclude`` skips a member that just failed the caller (retry must
        not land on the same member).  Raises GroupExhausted when no member
        admits traffic.
        """
        with self._lock:
            # peek with available() and gate only the chosen member with
            # allow(): calling allow() on every candidate would consume an
            # un-dispatched half-open probe ticket and strand that member
            candidates = [
                m
                for m in self._members.values()
                if m.name != exclude and m.breaker.available()
            ]
            while candidates:
                choice = self.strategy.pick(candidates)
                if choice.breaker.allow():
                    return choice.name
                candidates.remove(choice)  # lost the probe race: try others
            raise GroupExhausted(self.name)

    def note_dispatch(self, member: str, n_tasks: int) -> None:
        with self._lock:
            m = self._members[member]
            m.outstanding += n_tasks
            m.dispatched += n_tasks
            self._ledger_load(member, n_tasks)
            if self._events is not None:
                self._events.emit(
                    "group.dispatch", group=self.name, member=member, n=n_tasks
                )

    # -- health feedback -------------------------------------------------
    def record_success(self, member: str) -> None:
        m = self._members.get(member)
        if m is None:
            return
        with self._lock:
            m.outstanding = max(0, m.outstanding - 1)
            m.completed += 1
            self._ledger_load(member, -1)
            if self._events is not None:
                self._events.emit(
                    "group.complete", group=self.name, member=member, failed=False
                )
        m.breaker.record_success()

    def record_failure(self, member: str) -> None:
        """Counter + breaker feedback for one failed task.  Hard outage
        signals go through mark_down (via Hydra._handle_member_down), which
        solely owns the OPEN transition."""
        m = self._members.get(member)
        if m is None:
            return
        with self._lock:
            m.outstanding = max(0, m.outstanding - 1)
            m.failed += 1
            self._ledger_load(member, -1)
            if self._events is not None:
                self._events.emit(
                    "group.complete", group=self.name, member=member, failed=True
                )
        m.breaker.record_failure()

    def record_skip(self, member: str) -> None:
        """A dispatched task was skipped (finished elsewhere first): release
        its load slot and any probe ticket it carried, without touching
        completion counters or the breaker's failure accounting."""
        m = self._members.get(member)
        if m is None:
            return
        with self._lock:
            m.outstanding = max(0, m.outstanding - 1)
            self._ledger_load(member, -1)
            if self._events is not None:
                self._events.emit("group.skip", group=self.name, member=member)
        m.breaker.release_probe()

    def record_straggler(self, member: str) -> None:
        """Watchdog verdict: a soft failure against the member's breaker."""
        m = self._members.get(member)
        if m is not None:
            m.breaker.record_failure()

    def mark_down(self, member: str) -> None:
        """Hard down signal (ProviderDown): open the breaker immediately."""
        m = self._members.get(member)
        if m is None:
            return
        was = m.breaker.state
        m.breaker.trip()
        with self._lock:
            # a down member holds no dispatchable work: its orphans are being
            # reassigned or failing, and a stale outstanding count would make
            # load-based strategies shun the member forever after recovery
            m.outstanding = 0
            if self._ledger is not None:
                self._ledger.load_reset(member)
        if was != BreakerState.OPEN:
            self.trace.add(f"breaker_open:{member}")

    def add_member(self, handle: ProviderHandle) -> GroupMember:
        """Dynamic member join on a LIVE group (elastic scale-out): the new
        member enters rotation with a fresh breaker and inherits the group's
        breaker config.  The synthetic spec grows element-wise so tasks that
        fit the new (possibly larger) member become eligible mid-run."""
        if handle.spec.platform != self.spec.platform:
            raise ValidationError(
                f"group {self.name!r}: member {handle.name!r} platform "
                f"{handle.spec.platform!r} != group platform {self.spec.platform!r}"
            )
        with self._lock:
            if handle.name in self._members:
                raise ValidationError(
                    f"group {self.name!r}: member {handle.name!r} already present"
                )
            member = self._make_member(handle)
            self._members[handle.name] = member
            cap, have = handle.spec.capacity(), self.spec.node_capacity
            self.spec.node_capacity = Resources(
                cpus=max(have.cpus, cap.cpus),
                accels=max(have.accels, cap.accels),
                memory_mb=max(have.memory_mb, cap.memory_mb),
            )
        self._wire_member(member)  # converts its ledger row to a member row
        if self._events is not None:
            self._events.emit(
                "group.member_join",
                group=self.name,
                member=handle.name,
                slots=member.slots,
            )
        self.trace.add(f"member_joined:{handle.name}")
        return member

    def remove_member(self, name: str) -> None:
        """Permanently drop a member (elastic removal): it leaves rotation
        for good — no half-open probes to a provider that no longer exists."""
        with self._lock:
            gone = self._members.pop(name, None) is not None
        if gone and self._ledger is not None:
            self._ledger.remove(name)
        if gone and self._events is not None:
            self._events.emit("group.member_leave", group=self.name, member=name)
        self.trace.add(f"member_removed:{name}")

    def breaker_state(self, member: str) -> BreakerState:
        return self._members[member].breaker.state

    # -- metrics ---------------------------------------------------------
    def stats(self) -> list[dict]:
        """One metrics row per member (group-aware metrics, broker.py).
        The dispatched/completed/failed counters come straight from the
        member accumulators (they double as HYDRA_EVENTS_CHECK ground
        truth); the bus folds the same group.* events into its member-keyed
        view, and strict mode asserts the two agree."""
        with self._lock:
            return [
                {
                    "group": self.name,
                    "member": m.name,
                    "breaker": m.breaker.state.value,
                    "trips": m.breaker.trips,
                    "weight": m.weight,
                    "slots": m.slots,
                    "outstanding": m.outstanding,
                    "dispatched": m.dispatched,
                    "completed": m.completed,
                    "failed": m.failed,
                }
                for m in self._members.values()
            ]
