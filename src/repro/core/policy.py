"""Binding policies: which provider runs which task (paper §1: "user-specified
brokering policies determine whether tasks ... execute on cloud or HPC").

The paper's released Hydra binds statically before execution; *adaptive*
runtime re-binding is its stated future work ("dynamic and adaptive binding
of tasks to resources at runtime", §6) and is implemented here as
``AdaptivePolicy`` (beyond-paper, measured in EXPERIMENTS.md §Perf).

Policies bind to *targets*, which are either concrete ``ProviderHandle``s or
logical ``ProviderGroup``s (core/group.py) — both expose ``.name`` and
``.spec.capacity()``, which is all a policy may rely on.  When a task is
bound to a group, the group resolves the concrete member at dispatch time;
runtime feedback (``observe``) arrives keyed by the *logical* bound name, so
a policy's load/EWMA accounting never sees intra-group member churn.

Hot-path complexity (§Perf, the exp9 scheduler core):

  * **Indexed eligibility** — when bound to the proxy's versioned bind-target
    cache (``attach_proxy``), ``_eligible`` is a dict lookup per capacity
    signature instead of a per-task scan; the index drops whole on any
    topology change (register/deregister/health/breaker events bump the
    proxy version).  Eligible sets built this way are ``EligibleTargets``
    lists tagged with their (version, signature) key.
  * **Lazy-rekeyed placement heaps** — the stateful policies
    (``LoadAwarePolicy``/``AdaptivePolicy``/``DataGravityPolicy``) keep one
    min-heap per eligible-set key, so ``_choose`` is O(log n) instead of
    ``min()`` over every provider under the lock.  Heap entries are score
    snapshots; every score change pushes a fresh entry (per-name version
    numbers invalidate the old ones) and any remaining staleness — e.g. the
    fleet-average EWMA prior drifting under a no-history provider — is
    repaired at pop time by re-keying the top entry with its true score.
  * **Batched data costs** — within one ``bind_bulk`` the gravity policy
    resolves staging costs once per (inputs-signature, targets) via
    ``StagingService.transfer_cost_many`` instead of per task per target.
"""
from __future__ import annotations

import heapq
import itertools
import threading
from collections import defaultdict
from contextlib import contextmanager
from typing import Optional

from repro.core.task import Task


class NoEligibleProvider(RuntimeError):
    """No registered target can fit the task's resource requirements.

    A typed subclass so callers that bind *batches* late (the streaming
    dispatcher in core/dispatcher.py) can fail exactly the offending task
    and keep dispatching the rest of the batch, instead of aborting the
    whole submission on one oversized task."""

    def __init__(self, task: Task):
        self.task = task
        super().__init__(
            f"no provider can fit task {task.uid} requiring {vars(task.resources)}"
        )


def apportion_budget(
    budget: int,
    demands: list[int],
    weights: list[float],
    carry: Optional[list[float]] = None,
) -> tuple[list[int], list[float]]:
    """Split an integer batch budget across lanes in proportion to weight —
    the dispatcher's lane-aware backfill sizing (core/dispatcher.py).

    Weighted largest-remainder apportionment with carried deficits: each
    lane's ideal share is ``budget * w_i / W`` plus whatever fraction it was
    shorted last round, so over consecutive rounds every nonzero-weight lane
    with standing demand converges on its exact proportional share — a
    weight-1 lane next to a weight-100 lane is *slowed*, never starved
    (tests/test_tenants.py proves this as a property).  Surplus from lanes
    whose demand is smaller than their share re-apportions to the rest;
    zero-weight lanes only see budget no weighted lane wants.

    Returns ``(grants, new_carry)``; grants[i] <= demands[i] and
    sum(grants) <= budget always hold.  ``new_carry`` is the deficit to pass
    back next round — callers reset a lane's carry when it empties.
    """
    n = len(demands)
    assert len(weights) == n
    new_carry = [0.0] * n if carry is None else [max(0.0, c) for c in carry]
    grants = [0] * n
    remaining = max(0, int(budget))
    while remaining > 0:
        active = [i for i in range(n) if demands[i] > grants[i] and weights[i] > 0]
        if not active:
            # only weightless lanes still have demand: plain round-robin
            idle = [i for i in range(n) if demands[i] > grants[i]]
            if not idle:
                break
            for i in idle:
                if remaining <= 0:
                    break
                grants[i] += 1
                remaining -= 1
            continue
        total_w = sum(weights[i] for i in active)
        round_budget = remaining
        allotted = 0
        for i in active:
            share = round_budget * weights[i] / total_w + new_carry[i]
            whole = min(int(share), demands[i] - grants[i], remaining - allotted)
            grants[i] += whole
            allotted += whole
            if demands[i] > grants[i]:
                # shorted (by rounding, its demand cap, or budget exhaustion):
                # carry the deficit so next round repays it first.  Bounded
                # by the round budget, so a long-starved lane cannot bank an
                # unbounded claim and then monopolize a whole batch.
                new_carry[i] = min(float(round_budget), share - whole)
            else:
                new_carry[i] = 0.0  # satisfied: a drained lane banks nothing
        remaining -= allotted
        if remaining > 0 and allotted == 0:
            # every share rounded to zero (tiny budget, many lanes): the
            # largest accumulated deficit wins one slot — this is what makes
            # starvation impossible even at budget == 1
            best = max(active, key=lambda i: (new_carry[i], weights[i]))
            grants[best] += 1
            new_carry[best] = max(0.0, new_carry[best] - 1.0)
            remaining -= 1
    return grants, new_carry


class EligibleTargets(list):
    """An eligibility-validated target list tagged with the (topology
    version, capacity signature) it was computed for — the key stateful
    policies hang their placement heaps on.  Treated as immutable."""

    __slots__ = ("key",)

    def __init__(self, items, key=None):
        super().__init__(items)
        self.key = key


class Policy:
    name = "base"
    # data-aware placement (core/staging.py): when a StagingService is
    # attached, ``data_cost_s`` charges cold reads their modeled transfer
    # time; replica reads are free.  Policies that fold this into _choose
    # become locality-aware; the rest stay locality-blind (the exp8 control).
    staging = None

    def __init__(self):
        self._proxy = None  # versioned bind-target source (attach_proxy)
        self._elig_ver: Optional[int] = None
        self._elig_cache: dict[tuple, EligibleTargets] = {}
        self._elig_lock = threading.Lock()
        # per-THREAD bulk data-cost scope: the dispatcher's staging-gate
        # pass and a concurrent fault-path bind_bulk must not share (or
        # clear) each other's batch cache
        self._bulk_local = threading.local()

    def attach_staging(self, staging) -> None:
        self.staging = staging

    def attach_proxy(self, proxy) -> None:
        """Wire the ProviderProxy whose versioned bind-target cache keys the
        eligibility index; without it every _eligible call scans."""
        self._proxy = proxy

    def data_cost_s(self, task: Task, name: str) -> float:
        """Modeled seconds to materialize the task's missing input bytes at
        target ``name``'s site (0 when staging is off or inputs resident)."""
        if self.staging is None or not task.inputs:
            return 0.0
        return self.staging.transfer_cost_s(task.inputs, name)

    @contextmanager
    def bulk_scope(self):
        """Scope several sequential ``bind`` calls into one batch for the
        data-cost cache (the dispatcher's staging gate binds input-carrying
        tasks one by one — with this scope a gate pass over a batch reading
        the same shard set prices its placements ONCE, exactly like
        bind_bulk does).  The scope is thread-local: concurrent binders
        each get their own."""
        self._bulk_local.cache = {}
        try:
            yield
        finally:
            self._bulk_local.cache = None

    def data_costs(self, task: Task, ok: list) -> dict[str, float]:
        """Per-target stage-in cost for the task's inputs, resolved in ONE
        staging query — and cached per (inputs-signature, targets) for the
        duration of a bind_bulk, so a batch of tasks reading the same shard
        set prices its placements once instead of tasks x targets times."""
        if self.staging is None or not task.inputs:
            return {}
        sig = tuple(sorted(task.inputs))
        names = tuple(p.name for p in ok)
        cache = getattr(self._bulk_local, "cache", None)
        if cache is not None:
            hit = cache.get((sig, names))
            if hit is not None:
                return hit
        costs = self.staging.transfer_cost_many(sig, names)
        if cache is not None:
            cache[(sig, names)] = costs
        return costs

    def bind(self, task: Task, providers: list) -> str:
        """providers: bind targets — ProviderHandle or ProviderGroup."""
        return self._choose(task, self._eligible(task, providers))

    def _choose(self, task: Task, ok: list) -> str:
        """Pick among pre-validated eligible targets (policy-specific)."""
        raise NotImplementedError

    def bind_bulk(self, tasks: list[Task], providers: list) -> list[str]:
        """Vectorized binding (§Perf): one eligibility pass per distinct
        (resources, pin) signature instead of a per-task scan; policies may
        override.

        Atomic with respect to stateful policies: eligibility is validated
        for the WHOLE batch before any _choose mutates load accounting, so a
        NoEligibleProvider raise leaves outstanding/EWMA state untouched and
        the caller can safely re-bind the placeable remainder.

        A task carrying a staging-gate reservation (``reserved_provider``,
        core/dispatcher.py) is routed back to the target the gate already
        bound — and accounted — it to: its inputs were staged to that site on
        that promise.  A reservation whose target has since died is released
        (``unbind``) and the task re-chooses normally."""
        sig_cache: dict = {}
        eligible = []
        for t in tasks:
            sig = (t.pinned_provider, t.resources.cpus, t.resources.accels, t.resources.memory_mb)
            ok = sig_cache.get(sig)
            if ok is None:
                ok = self._eligible(t, providers)
                sig_cache[sig] = ok
            eligible.append(ok)
        names = []
        fresh_scope = getattr(self._bulk_local, "cache", None) is None
        if fresh_scope:
            self._bulk_local.cache = {}
        try:
            for t, ok in zip(tasks, eligible):
                reserved, t.reserved_provider = t.reserved_provider, None
                if reserved is not None:
                    if any(p.name == reserved for p in ok):
                        # load already accounted at reservation time: no _choose
                        names.append(reserved)
                        continue
                    self.unbind(t, reserved)  # target gone: release, re-choose
                names.append(self._choose(t, ok))
        finally:
            if fresh_scope:
                self._bulk_local.cache = None
        return names

    def observe(self, provider: str, runtime_s: float) -> None:
        """Runtime feedback hook (used by adaptive policies).  ``provider``
        is the logical bound name: a group name for group-bound tasks."""

    def unbind(self, task: Task, name: Optional[str] = None) -> None:
        """Undo load accounting for a task that was bound but never made it
        to a provider (pipeline aborts and the streaming dispatcher's retry
        path re-bind such tasks: without this hook stateful policies would
        double-count).  ``name`` overrides the bound name for tasks whose
        provider attribute was never updated (mid-bind aborts)."""

    def forget(self, name: str) -> None:
        """Drop all accumulated state for a released provider (elastic
        scale-in).  Without this, a re-acquired instance under a recycled
        name would inherit the dead instance's load/EWMA history."""

    def _eligible(self, task: Task, providers: list) -> list:
        """Targets that can fit the task (a pin may name a group too).

        O(1) amortized when ``providers`` is the proxy's current cached
        bind-target list: results are indexed per capacity signature and the
        whole index drops on any topology-version bump.  Filtered lists
        (rebind-with-exclude, speculation) fall back to the scan."""
        if task.pinned_provider:
            pin = [p for p in providers if p.name == task.pinned_provider]
            if pin:
                return pin
        res = task.resources
        ver = self._proxy.targets_version(providers) if self._proxy is not None else None
        if ver is None:
            ok = [p for p in providers if res.fits(p.spec.capacity())]
            if not ok:
                raise NoEligibleProvider(task)
            return ok
        sig = (res.cpus, res.accels, res.memory_mb)
        with self._elig_lock:
            if ver != self._elig_ver:  # topology moved: the whole index is stale
                self._elig_cache = {}
                self._elig_ver = ver
            ok = self._elig_cache.get(sig)
        if ok is None:
            ok = EligibleTargets(
                (p for p in providers if res.fits(p.spec.capacity())),
                key=(ver, sig),
            )
            with self._elig_lock:
                # install only if the index still belongs to OUR version: a
                # concurrent topology bump may have rotated the cache while
                # we built, and a stale-era list must not survive into the
                # new version's index
                if self._elig_ver == ver:
                    self._elig_cache[sig] = ok
        if not ok:
            raise NoEligibleProvider(task)
        return ok


class RoundRobinPolicy(Policy):
    name = "round_robin"

    def __init__(self):
        super().__init__()
        self._n = 0
        self._lock = threading.Lock()

    def _choose(self, task: Task, ok: list) -> str:
        with self._lock:
            choice = ok[self._n % len(ok)]
            self._n += 1
        return choice.name


class CapabilityPolicy(Policy):
    """Pick the provider with the most spare capability for the task class:
    accelerator tasks -> accel-richest pool; cpu tasks -> cpu-richest pool.
    The argmax is cached per (eligible-set key, task class): capacities only
    change with the topology version, which rotates the key."""

    name = "capability"

    def __init__(self):
        super().__init__()
        self._best: dict[tuple, str] = {}

    def _choose(self, task: Task, ok: list) -> str:
        accel = task.resources.accels > 0
        key = getattr(ok, "key", None)
        if key is not None:
            hit = self._best.get((key, accel))
            if hit is not None:
                return hit
        if accel:
            name = max(ok, key=lambda p: p.spec.capacity().accels).name
        else:
            name = max(ok, key=lambda p: p.spec.capacity().cpus).name
        if key is not None:
            if len(self._best) > 1024:  # old topology versions: let them go
                self._best = {}
            self._best[(key, accel)] = name
        return name


class _HeapPolicy(Policy):
    """Shared lazy-rekeyed-heap machinery for load/EWMA-scored policies.

    One min-heap per eligible-set key (``EligibleTargets.key``).  Entries
    are ``(score, seq, name, ver)`` snapshots; ``self._ver[name]`` advances
    on every score change and every placement, invalidating older entries.
    ``_rescore`` pushes a fresh entry into each heap whose eligible set
    contains the name (the number of live heaps is the number of distinct
    capacity signatures in flight — typically one).  A top entry whose
    snapshot no longer equals the true score is re-keyed in place
    (``heapreplace``) rather than trusted, which is what keeps prior-drift
    staleness from mis-placing work.  All methods expect self._lock held."""

    def __init__(self):
        super().__init__()
        self._lock = threading.Lock()
        self._ver: dict[str, int] = defaultdict(int)
        self._heaps: dict[tuple, list] = {}
        self._heap_members: dict[tuple, frozenset] = {}
        self._seq = itertools.count()

    def _score(self, name: str) -> float:
        raise NotImplementedError

    def _rescore(self, name: str) -> None:
        self._ver[name] += 1
        if not self._heaps:
            return
        score, ver = self._score(name), self._ver[name]
        for key, members in self._heap_members.items():
            if name not in members:
                continue
            heap = self._heaps[key]
            if len(heap) > 64 + 8 * len(members):
                # a heap nobody pops (dormant capacity signature) would
                # otherwise accumulate one stale snapshot per event forever:
                # rebuild in place from current scores, bounding every heap
                # at O(members)
                heap[:] = [
                    (self._score(m), next(self._seq), m, self._ver[m])
                    for m in members
                ]
                heapq.heapify(heap)
            else:
                heapq.heappush(heap, (score, next(self._seq), name, ver))

    def _drop(self, name: str) -> None:
        """forget(): invalidate without re-seeding (the name is leaving)."""
        self._ver[name] += 1

    def _heap_for(self, ok: list) -> Optional[list]:
        key = getattr(ok, "key", None)
        if key is None:
            return None
        heap = self._heaps.get(key)
        if heap is None:
            stale = [k for k in self._heaps if k[0] != key[0]]
            for k in stale:  # dead topology versions stop receiving pushes
                del self._heaps[k]
                del self._heap_members[k]
            heap = [
                (self._score(p.name), next(self._seq), p.name, self._ver[p.name])
                for p in ok
            ]
            heapq.heapify(heap)
            self._heaps[key] = heap
            self._heap_members[key] = frozenset(p.name for p in ok)
        return heap

    def _pick_min(self, ok: list) -> str:
        """Argmin-score target in O(log n) via the eligible set's heap;
        falls back to a scan for untagged lists.  Callers hold self._lock
        and still own the post-placement bookkeeping for the winner."""
        heap = self._heap_for(ok)
        if heap is not None:
            while heap:
                score, _, name, ver = heap[0]
                if ver != self._ver[name]:
                    heapq.heappop(heap)  # superseded snapshot
                    continue
                true = self._score(name)
                if true != score:
                    # lazy rekey: correct the snapshot in place and re-sort
                    heapq.heapreplace(heap, (true, next(self._seq), name, ver))
                    continue
                return name
            # heap drained (every member forgotten mid-flight): fall through
        return min(ok, key=lambda p: self._score(p.name)).name


class LoadAwarePolicy(_HeapPolicy):
    """Least-outstanding-tasks binding (queue-depth balancing)."""

    name = "load_aware"

    def __init__(self):
        super().__init__()
        self.outstanding: dict[str, int] = defaultdict(int)

    def _score(self, name: str) -> float:
        return self.outstanding[name]

    def _choose(self, task: Task, ok: list) -> str:
        with self._lock:
            name = self._pick_min(ok)
            self.outstanding[name] += 1
            self._rescore(name)
            return name

    def observe(self, provider: str, runtime_s: float) -> None:
        with self._lock:
            self.outstanding[provider] = max(0, self.outstanding[provider] - 1)
            self._rescore(provider)

    def unbind(self, task: Task, name: Optional[str] = None) -> None:
        name = name or task.group or task.provider
        if name:
            with self._lock:
                self.outstanding[name] = max(0, self.outstanding[name] - 1)
                self._rescore(name)

    def forget(self, name: str) -> None:
        with self._lock:
            self.outstanding.pop(name, None)
            self._drop(name)


class AdaptivePolicy(_HeapPolicy):
    """Throughput-weighted binding (beyond-paper: the paper's future work).

    Keeps an EWMA of per-provider task service time and routes proportionally
    more work to faster providers, while still balancing outstanding load.
    """

    name = "adaptive"

    def __init__(self, alpha: float = 0.2):
        super().__init__()
        self.alpha = alpha
        self.ewma: dict[str, float] = {}
        self.outstanding: dict[str, int] = defaultdict(int)
        self._ewma_sum = 0.0  # running aggregate: O(1) fleet prior

    def _fleet_prior(self) -> float:
        """Neutral EWMA prior for providers with no history yet (callers
        hold self._lock): a member that appeared mid-run (elastic scale-out)
        is assumed as fast as the current fleet average, not 1000x faster —
        an optimistic default would flood brand-new capacity before its
        first completion.  Maintained as a running sum so reading it is O(1)
        on the per-task path."""
        n = len(self.ewma)
        return (self._ewma_sum / n) if n else 1e-3

    def _expected_finish_s(self, name: str, prior: float) -> float:
        """Expected finish time ~ (queue + 1) x service time (callers hold
        self._lock).  Shared by the adaptive and data-gravity policies so
        the queueing model cannot silently diverge between them."""
        svc = max(self.ewma.get(name, prior), 1e-6)
        return (self.outstanding[name] + 1) * svc

    def _score(self, name: str) -> float:
        return self._expected_finish_s(name, self._fleet_prior())

    def _choose(self, task: Task, ok: list) -> str:
        with self._lock:
            name = self._pick_min(ok)
            self.outstanding[name] += 1
            self._rescore(name)
            return name

    def observe(self, provider: str, runtime_s: float) -> None:
        with self._lock:
            cur = self.ewma.get(provider)
            new = runtime_s if cur is None else (1 - self.alpha) * cur + self.alpha * runtime_s
            self.ewma[provider] = new
            self._ewma_sum += new - (cur or 0.0)
            self.outstanding[provider] = max(0, self.outstanding[provider] - 1)
            self._rescore(provider)

    def unbind(self, task: Task, name: Optional[str] = None) -> None:
        """Load release only — no EWMA update: the task never ran."""
        name = name or task.group or task.provider
        if name:
            with self._lock:
                self.outstanding[name] = max(0, self.outstanding[name] - 1)
                self._rescore(name)

    def forget(self, name: str) -> None:
        with self._lock:
            gone = self.ewma.pop(name, None)
            if gone is not None:
                self._ewma_sum -= gone
            self.outstanding.pop(name, None)
            self._drop(name)


class DataGravityPolicy(AdaptivePolicy):
    """Locality-aware binding (beyond-paper; StreamFlow-style): expected
    completion = modeled stage-in time for the task's missing input bytes
    (core/staging.py: replica reads free, cold reads charged the link model)
    + the adaptive queue/service-time estimate.  Placement therefore prefers
    providers already holding — or co-located with — a task's inputs, and
    only pays a cross-site transfer when the data-local queue is long enough
    to make shipping bytes cheaper than waiting.

    Tasks without declared inputs have a zero data term everywhere and ride
    the adaptive heap; tasks with inputs scan the (typically small) eligible
    set against a data-cost map resolved once per (inputs-signature,
    targets) per bind_bulk (``Policy.data_costs``)."""

    name = "data_gravity"

    def _choose(self, task: Task, ok: list) -> str:
        if not task.inputs:
            return super()._choose(task, ok)
        # staging reads (registry/engine locks) happen OUTSIDE the policy
        # lock: staging never calls back into policies, but keeping the
        # ordering one-way makes that invariant structural
        data_cost = self.data_costs(task, ok)
        with self._lock:
            prior = self._fleet_prior()
            choice = min(
                ok,
                key=lambda p: (
                    data_cost.get(p.name, 0.0) + self._expected_finish_s(p.name, prior),
                    p.name,
                ),
            )
            self.outstanding[choice.name] += 1
            self._rescore(choice.name)
            return choice.name


POLICIES = {
    p.name: p
    for p in (
        RoundRobinPolicy,
        CapabilityPolicy,
        LoadAwarePolicy,
        AdaptivePolicy,
        DataGravityPolicy,
    )
}


def make_policy(name: str) -> Policy:
    return POLICIES[name]()
