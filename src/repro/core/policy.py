"""Binding policies: which provider runs which task (paper §1: "user-specified
brokering policies determine whether tasks ... execute on cloud or HPC").

The paper's released Hydra binds statically before execution; *adaptive*
runtime re-binding is its stated future work ("dynamic and adaptive binding
of tasks to resources at runtime", §6) and is implemented here as
``AdaptivePolicy`` (beyond-paper, measured in EXPERIMENTS.md §Perf).

Policies bind to *targets*, which are either concrete ``ProviderHandle``s or
logical ``ProviderGroup``s (core/group.py) — both expose ``.name`` and
``.spec.capacity()``, which is all a policy may rely on.  When a task is
bound to a group, the group resolves the concrete member at dispatch time;
runtime feedback (``observe``) arrives keyed by the *logical* bound name, so
a policy's load/EWMA accounting never sees intra-group member churn.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Optional

from repro.core.provider import ProviderHandle
from repro.core.task import Task


class Policy:
    name = "base"

    def bind(self, task: Task, providers: list) -> str:
        """providers: bind targets — ProviderHandle or ProviderGroup."""
        raise NotImplementedError

    def bind_bulk(self, tasks: list[Task], providers: list) -> list[str]:
        """Vectorized binding (§Perf): one eligibility pass for homogeneous
        spans instead of a per-task policy call.  Default falls back to the
        per-task path; policies may override."""
        return [self.bind(t, providers) for t in tasks]

    def observe(self, provider: str, runtime_s: float) -> None:
        """Runtime feedback hook (used by adaptive policies).  ``provider``
        is the logical bound name: a group name for group-bound tasks."""

    def _eligible(self, task: Task, providers: list) -> list:
        """Targets that can fit the task (a pin may name a group too)."""
        if task.pinned_provider:
            pin = [p for p in providers if p.name == task.pinned_provider]
            if pin:
                return pin
        ok = [p for p in providers if task.resources.fits(p.spec.capacity())]
        if not ok:
            raise RuntimeError(
                f"no provider can fit task {task.uid} requiring {vars(task.resources)}"
            )
        return ok


class RoundRobinPolicy(Policy):
    name = "round_robin"

    def __init__(self):
        self._n = 0
        self._lock = threading.Lock()

    def bind(self, task: Task, providers: list[ProviderHandle]) -> str:
        ok = self._eligible(task, providers)
        with self._lock:
            choice = ok[self._n % len(ok)]
            self._n += 1
        return choice.name

    def bind_bulk(self, tasks: list[Task], providers: list[ProviderHandle]) -> list[str]:
        """One eligibility check per distinct (resources, pin) signature;
        round-robin assignment in a single locked pass."""
        sig_cache: dict = {}
        out = []
        with self._lock:
            for t in tasks:
                sig = (t.pinned_provider, t.resources.cpus, t.resources.accels, t.resources.memory_mb)
                ok = sig_cache.get(sig)
                if ok is None:
                    ok = self._eligible(t, providers)
                    sig_cache[sig] = ok
                out.append(ok[self._n % len(ok)].name)
                self._n += 1
        return out


class CapabilityPolicy(Policy):
    """Pick the provider with the most spare capability for the task class:
    accelerator tasks -> accel-richest pool; cpu tasks -> cpu-richest pool."""

    name = "capability"

    def bind(self, task: Task, providers: list[ProviderHandle]) -> str:
        ok = self._eligible(task, providers)
        if task.resources.accels > 0:
            return max(ok, key=lambda p: p.spec.capacity().accels).name
        return max(ok, key=lambda p: p.spec.capacity().cpus).name


class LoadAwarePolicy(Policy):
    """Least-outstanding-tasks binding (queue-depth balancing)."""

    name = "load_aware"

    def __init__(self):
        self.outstanding: dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()

    def bind(self, task: Task, providers: list[ProviderHandle]) -> str:
        ok = self._eligible(task, providers)
        with self._lock:
            choice = min(ok, key=lambda p: self.outstanding[p.name])
            self.outstanding[choice.name] += 1
            return choice.name

    def observe(self, provider: str, runtime_s: float) -> None:
        with self._lock:
            self.outstanding[provider] = max(0, self.outstanding[provider] - 1)


class AdaptivePolicy(Policy):
    """Throughput-weighted binding (beyond-paper: the paper's future work).

    Keeps an EWMA of per-provider task service time and routes proportionally
    more work to faster providers, while still balancing outstanding load.
    """

    name = "adaptive"

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self.ewma: dict[str, float] = {}
        self.outstanding: dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()

    def bind(self, task: Task, providers: list[ProviderHandle]) -> str:
        ok = self._eligible(task, providers)
        with self._lock:
            def score(p: ProviderHandle) -> float:
                rate = 1.0 / max(self.ewma.get(p.name, 1e-3), 1e-6)
                # expected finish time ~ (queue + 1) / service rate
                return (self.outstanding[p.name] + 1) / rate

            choice = min(ok, key=score)
            self.outstanding[choice.name] += 1
            return choice.name

    def observe(self, provider: str, runtime_s: float) -> None:
        with self._lock:
            cur = self.ewma.get(provider)
            self.ewma[provider] = (
                runtime_s if cur is None else (1 - self.alpha) * cur + self.alpha * runtime_s
            )
            self.outstanding[provider] = max(0, self.outstanding[provider] - 1)


POLICIES = {
    p.name: p
    for p in (RoundRobinPolicy, CapabilityPolicy, LoadAwarePolicy, AdaptivePolicy)
}


def make_policy(name: str) -> Policy:
    return POLICIES[name]()
