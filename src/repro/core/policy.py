"""Binding policies: which provider runs which task (paper §1: "user-specified
brokering policies determine whether tasks ... execute on cloud or HPC").

The paper's released Hydra binds statically before execution; *adaptive*
runtime re-binding is its stated future work ("dynamic and adaptive binding
of tasks to resources at runtime", §6) and is implemented here as
``AdaptivePolicy`` (beyond-paper, measured in EXPERIMENTS.md §Perf).

Policies bind to *targets*, which are either concrete ``ProviderHandle``s or
logical ``ProviderGroup``s (core/group.py) — both expose ``.name`` and
``.spec.capacity()``, which is all a policy may rely on.  When a task is
bound to a group, the group resolves the concrete member at dispatch time;
runtime feedback (``observe``) arrives keyed by the *logical* bound name, so
a policy's load/EWMA accounting never sees intra-group member churn.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Optional

from repro.core.task import Task


class NoEligibleProvider(RuntimeError):
    """No registered target can fit the task's resource requirements.

    A typed subclass so callers that bind *batches* late (the streaming
    dispatcher in core/dispatcher.py) can fail exactly the offending task
    and keep dispatching the rest of the batch, instead of aborting the
    whole submission on one oversized task."""

    def __init__(self, task: Task):
        self.task = task
        super().__init__(
            f"no provider can fit task {task.uid} requiring {vars(task.resources)}"
        )


class Policy:
    name = "base"
    # data-aware placement (core/staging.py): when a StagingService is
    # attached, ``data_cost_s`` charges cold reads their modeled transfer
    # time; replica reads are free.  Policies that fold this into _choose
    # become locality-aware; the rest stay locality-blind (the exp8 control).
    staging = None

    def attach_staging(self, staging) -> None:
        self.staging = staging

    def data_cost_s(self, task: Task, name: str) -> float:
        """Modeled seconds to materialize the task's missing input bytes at
        target ``name``'s site (0 when staging is off or inputs resident)."""
        if self.staging is None or not task.inputs:
            return 0.0
        return self.staging.transfer_cost_s(task.inputs, name)

    def bind(self, task: Task, providers: list) -> str:
        """providers: bind targets — ProviderHandle or ProviderGroup."""
        return self._choose(task, self._eligible(task, providers))

    def _choose(self, task: Task, ok: list) -> str:
        """Pick among pre-validated eligible targets (policy-specific)."""
        raise NotImplementedError

    def bind_bulk(self, tasks: list[Task], providers: list) -> list[str]:
        """Vectorized binding (§Perf): one eligibility pass per distinct
        (resources, pin) signature instead of a per-task scan; policies may
        override.

        Atomic with respect to stateful policies: eligibility is validated
        for the WHOLE batch before any _choose mutates load accounting, so a
        NoEligibleProvider raise leaves outstanding/EWMA state untouched and
        the caller can safely re-bind the placeable remainder.

        A task carrying a staging-gate reservation (``reserved_provider``,
        core/dispatcher.py) is routed back to the target the gate already
        bound — and accounted — it to: its inputs were staged to that site on
        that promise.  A reservation whose target has since died is released
        (``unbind``) and the task re-chooses normally."""
        sig_cache: dict = {}
        eligible = []
        for t in tasks:
            sig = (t.pinned_provider, t.resources.cpus, t.resources.accels, t.resources.memory_mb)
            ok = sig_cache.get(sig)
            if ok is None:
                ok = self._eligible(t, providers)
                sig_cache[sig] = ok
            eligible.append(ok)
        names = []
        for t, ok in zip(tasks, eligible):
            reserved, t.reserved_provider = t.reserved_provider, None
            if reserved is not None:
                if any(p.name == reserved for p in ok):
                    # load already accounted at reservation time: no _choose
                    names.append(reserved)
                    continue
                self.unbind(t, reserved)  # target gone: release, re-choose
            names.append(self._choose(t, ok))
        return names

    def observe(self, provider: str, runtime_s: float) -> None:
        """Runtime feedback hook (used by adaptive policies).  ``provider``
        is the logical bound name: a group name for group-bound tasks."""

    def unbind(self, task: Task, name: Optional[str] = None) -> None:
        """Undo load accounting for a task that was bound but never made it
        to a provider (pipeline aborts and the streaming dispatcher's retry
        path re-bind such tasks: without this hook stateful policies would
        double-count).  ``name`` overrides the bound name for tasks whose
        provider attribute was never updated (mid-bind aborts)."""

    def forget(self, name: str) -> None:
        """Drop all accumulated state for a released provider (elastic
        scale-in).  Without this, a re-acquired instance under a recycled
        name would inherit the dead instance's load/EWMA history."""

    def _eligible(self, task: Task, providers: list) -> list:
        """Targets that can fit the task (a pin may name a group too)."""
        if task.pinned_provider:
            pin = [p for p in providers if p.name == task.pinned_provider]
            if pin:
                return pin
        ok = [p for p in providers if task.resources.fits(p.spec.capacity())]
        if not ok:
            raise NoEligibleProvider(task)
        return ok


class RoundRobinPolicy(Policy):
    name = "round_robin"

    def __init__(self):
        self._n = 0
        self._lock = threading.Lock()

    def _choose(self, task: Task, ok: list) -> str:
        with self._lock:
            choice = ok[self._n % len(ok)]
            self._n += 1
        return choice.name


class CapabilityPolicy(Policy):
    """Pick the provider with the most spare capability for the task class:
    accelerator tasks -> accel-richest pool; cpu tasks -> cpu-richest pool."""

    name = "capability"

    def _choose(self, task: Task, ok: list) -> str:
        if task.resources.accels > 0:
            return max(ok, key=lambda p: p.spec.capacity().accels).name
        return max(ok, key=lambda p: p.spec.capacity().cpus).name


class LoadAwarePolicy(Policy):
    """Least-outstanding-tasks binding (queue-depth balancing)."""

    name = "load_aware"

    def __init__(self):
        self.outstanding: dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()

    def _choose(self, task: Task, ok: list) -> str:
        with self._lock:
            choice = min(ok, key=lambda p: self.outstanding[p.name])
            self.outstanding[choice.name] += 1
            return choice.name

    def observe(self, provider: str, runtime_s: float) -> None:
        with self._lock:
            self.outstanding[provider] = max(0, self.outstanding[provider] - 1)

    def unbind(self, task: Task, name: Optional[str] = None) -> None:
        name = name or task.group or task.provider
        if name:
            with self._lock:
                self.outstanding[name] = max(0, self.outstanding[name] - 1)

    def forget(self, name: str) -> None:
        with self._lock:
            self.outstanding.pop(name, None)


class AdaptivePolicy(Policy):
    """Throughput-weighted binding (beyond-paper: the paper's future work).

    Keeps an EWMA of per-provider task service time and routes proportionally
    more work to faster providers, while still balancing outstanding load.
    """

    name = "adaptive"

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self.ewma: dict[str, float] = {}
        self.outstanding: dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()

    def _fleet_prior(self) -> float:
        """Neutral EWMA prior for providers with no history yet (callers
        hold self._lock): a member that appeared mid-run (elastic scale-out)
        is assumed as fast as the current fleet average, not 1000x faster —
        an optimistic default would flood brand-new capacity before its
        first completion."""
        known = [v for v in self.ewma.values() if v > 0]
        return (sum(known) / len(known)) if known else 1e-3

    def _expected_finish_s(self, name: str, prior: float) -> float:
        """Expected finish time ~ (queue + 1) x service time (callers hold
        self._lock).  Shared by the adaptive and data-gravity policies so
        the queueing model cannot silently diverge between them."""
        svc = max(self.ewma.get(name, prior), 1e-6)
        return (self.outstanding[name] + 1) * svc

    def _choose(self, task: Task, ok: list) -> str:
        with self._lock:
            prior = self._fleet_prior()
            choice = min(ok, key=lambda p: self._expected_finish_s(p.name, prior))
            self.outstanding[choice.name] += 1
            return choice.name

    def observe(self, provider: str, runtime_s: float) -> None:
        with self._lock:
            cur = self.ewma.get(provider)
            self.ewma[provider] = (
                runtime_s if cur is None else (1 - self.alpha) * cur + self.alpha * runtime_s
            )
            self.outstanding[provider] = max(0, self.outstanding[provider] - 1)

    def unbind(self, task: Task, name: Optional[str] = None) -> None:
        """Load release only — no EWMA update: the task never ran."""
        name = name or task.group or task.provider
        if name:
            with self._lock:
                self.outstanding[name] = max(0, self.outstanding[name] - 1)

    def forget(self, name: str) -> None:
        with self._lock:
            self.ewma.pop(name, None)
            self.outstanding.pop(name, None)


class DataGravityPolicy(AdaptivePolicy):
    """Locality-aware binding (beyond-paper; StreamFlow-style): expected
    completion = modeled stage-in time for the task's missing input bytes
    (core/staging.py: replica reads free, cold reads charged the link model)
    + the adaptive queue/service-time estimate.  Placement therefore prefers
    providers already holding — or co-located with — a task's inputs, and
    only pays a cross-site transfer when the data-local queue is long enough
    to make shipping bytes cheaper than waiting."""

    name = "data_gravity"

    def _choose(self, task: Task, ok: list) -> str:
        # staging reads (registry/engine locks) happen OUTSIDE the policy
        # lock: staging never calls back into policies, but keeping the
        # ordering one-way makes that invariant structural
        data_cost = {p.name: self.data_cost_s(task, p.name) for p in ok}
        with self._lock:
            prior = self._fleet_prior()
            choice = min(
                ok,
                key=lambda p: (
                    data_cost[p.name] + self._expected_finish_s(p.name, prior),
                    p.name,
                ),
            )
            self.outstanding[choice.name] += 1
            return choice.name


POLICIES = {
    p.name: p
    for p in (
        RoundRobinPolicy,
        CapabilityPolicy,
        LoadAwarePolicy,
        AdaptivePolicy,
        DataGravityPolicy,
    )
}


def make_policy(name: str) -> Policy:
    return POLICIES[name]()
