"""Data-aware staging subsystem (paper §3.1: data operations are first-class).

Cross-platform staging is a dominant cost when workloads span commercial
cloud, science cloud, and HPC: StreamFlow showed locality-aware placement
across hybrid topologies materially changes makespan, and the hybrid-cloud
literature identifies *data gravity* as the main coupling constraint between
cloud and HPC tiers.  This module makes those dynamics reproducible:

  DatasetRegistry   named, sized artifacts with per-site replica tracking
                    and capacity-bounded LRU eviction (a replica is never
                    evicted if it is pinned or the dataset's last copy).
  TransferEngine    per-platform-pair bandwidth/latency models (seeded
                    distributions, like the autoscaler's LatencyModel),
                    driven entirely by ``Clock.call_later`` so a run is
                    deterministic under VirtualClock.  Each directed
                    site-pair link has a concurrency limit; excess transfers
                    queue FIFO.  In-flight transfers de-duplicate (a second
                    request for the same (dataset, destination) piggybacks),
                    and a source-site death re-routes the transfer to a
                    surviving replica instead of failing it.
  StagingService    the broker-facing facade: per-task stage-in barriers
                    (``stage_task``), data-gravity scoring for the binding
                    policies (``transfer_cost_s``), stage-out on completion
                    (``task_completed``), and ``stats()``.

Sites are *bind-target* names: every registered provider is a site, every
provider group is one logical site (its members share a group-local store,
the way the paper's platforms share a filesystem), and ``shared`` is the
cross-site object store the DataManager already models.  Replica reads are
free; cold reads are charged the modeled transfer time — which is exactly
the asymmetry the data-gravity policy (core/policy.py) folds into placement.
"""
from __future__ import annotations

import math
import random
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

from repro.runtime.clock import ScheduledCall, get_clock
from repro.runtime.tracing import Counter, Trace

SHARED_SITE = "shared"

_DEFAULT_CAP = object()  # sentinel: "use the registry's default capacity"


class StagingError(RuntimeError):
    pass


class UnknownDataset(StagingError):
    pass


class UnknownSite(StagingError):
    pass


class DatasetLost(StagingError):
    """Every replica of a dataset is gone: no source to transfer from."""


# ---------------------------------------------------------------------------
# Dataset registry: replicas + capacity-bounded LRU eviction
# ---------------------------------------------------------------------------


@dataclass
class Dataset:
    """A named, sized artifact.  ``pinned`` replicas are never evicted
    (source data that exists outside the brokered fleet)."""

    name: str
    size_mb: float
    pinned: bool = False


@dataclass
class _Site:
    name: str
    platform: str
    capacity_mb: Optional[float] = None  # None = unbounded
    replicas: dict = field(default_factory=dict)  # dataset name -> lru tick
    used_mb: float = 0.0


class DatasetRegistry:
    """Which dataset lives where, with per-site capacity + LRU eviction.

    The LRU clock is a logical counter (not wall time), so eviction order is
    identical under WallClock and VirtualClock and across reruns."""

    def __init__(self, default_capacity_mb: Optional[float] = None):
        self.default_capacity_mb = default_capacity_mb
        self._datasets: dict[str, Dataset] = {}
        self._sites: dict[str, _Site] = {}
        self._tick = 0
        self._lock = threading.RLock()
        self.evictions = 0
        self._events = None  # broker-owned EventBus (StagingService.attach_events)
        self.register_site(SHARED_SITE, platform=SHARED_SITE, capacity_mb=None)

    # -- sites ---------------------------------------------------------
    def register_site(
        self,
        name: str,
        platform: str = "cloud",
        capacity_mb=_DEFAULT_CAP,
    ) -> None:
        if capacity_mb is _DEFAULT_CAP:
            capacity_mb = self.default_capacity_mb
        with self._lock:
            if name not in self._sites:
                self._sites[name] = _Site(name, platform, capacity_mb)

    def platform_of(self, site: str) -> str:
        with self._lock:
            s = self._sites.get(site)
            if s is None:
                raise UnknownSite(f"unknown staging site {site!r}")
            return s.platform

    def used_mb(self, site: str) -> float:
        with self._lock:
            s = self._sites.get(site)
            return 0.0 if s is None else s.used_mb

    # -- datasets ------------------------------------------------------
    def add(
        self,
        name: str,
        size_mb: float,
        sites: Iterable[str] = (),
        pinned: bool = False,
    ) -> Dataset:
        """Declare (or re-declare) a dataset; optionally place replicas."""
        with self._lock:
            ds = self._datasets.get(name)
            if ds is None:
                ds = Dataset(name, float(size_mb), pinned)
                self._datasets[name] = ds
            else:  # re-generated output (retry): the new size is authoritative
                delta = float(size_mb) - ds.size_mb
                if delta:
                    # resize existing replicas in place, or a later drop/evict
                    # would subtract the NEW size from accounting done at the
                    # OLD size and corrupt every capacity check at the site
                    for s in self._sites.values():
                        if name in s.replicas:
                            s.used_mb += delta
                ds.size_mb = float(size_mb)
                ds.pinned = ds.pinned or pinned
        for site in sites:
            self.place_replica(name, site)
        return ds

    def get(self, name: str) -> Dataset:
        with self._lock:
            ds = self._datasets.get(name)
            if ds is None:
                raise UnknownDataset(f"unknown dataset {name!r}")
            return ds

    def known(self, name: str) -> bool:
        with self._lock:
            return name in self._datasets

    def locate(self, name: str) -> list[str]:
        with self._lock:
            return sorted(
                s.name for s in self._sites.values() if name in s.replicas
            )

    def resident(self, name: str, site: str) -> bool:
        with self._lock:
            s = self._sites.get(site)
            return s is not None and name in s.replicas

    def touch(self, name: str, site: str) -> None:
        """Mark a replica recently used (a read keeps hot data resident)."""
        with self._lock:
            s = self._sites.get(site)
            if s is not None and name in s.replicas:
                self._tick += 1
                s.replicas[name] = self._tick

    # -- placement / eviction ------------------------------------------
    def place_replica(self, name: str, site: str) -> list[str]:
        """Add a replica at ``site``, LRU-evicting colder replicas if the
        site is over capacity.  Never evicts a pinned replica or a dataset's
        last copy; raises StagingError if the dataset cannot fit even after
        evicting everything evictable."""
        with self._lock:
            ds = self.get(name)
            s = self._sites.get(site)
            if s is None:
                raise UnknownSite(f"unknown staging site {site!r}")
            if name in s.replicas:
                self._tick += 1
                s.replicas[name] = self._tick
                return []
            evicted: list[str] = []
            if s.capacity_mb is not None and ds.size_mb > s.capacity_mb:
                raise StagingError(
                    f"dataset {name!r} ({ds.size_mb} MB) exceeds site "
                    f"{site!r} capacity ({s.capacity_mb} MB)"
                )
            if s.capacity_mb is not None:
                while s.used_mb + ds.size_mb > s.capacity_mb:
                    victim = self._lru_victim(s)
                    if victim is None:
                        raise StagingError(
                            f"site {site!r} cannot fit {name!r}: "
                            f"{s.used_mb:.0f}/{s.capacity_mb:.0f} MB held by "
                            "pinned or last-copy replicas"
                        )
                    del s.replicas[victim]
                    s.used_mb -= self._datasets[victim].size_mb
                    self.evictions += 1
                    if self._events is not None:
                        self._events.emit("replica.evict", dataset=victim, site=site)
                    evicted.append(victim)
            self._tick += 1
            s.replicas[name] = self._tick
            s.used_mb += ds.size_mb
            return evicted

    def _lru_victim(self, s: _Site) -> Optional[str]:
        # callers hold self._lock
        best, best_tick = None, None
        for name, tick in s.replicas.items():
            ds = self._datasets[name]
            if ds.pinned:
                continue
            if len(self.locate(name)) <= 1:  # last copy: data loss, never
                continue
            if best_tick is None or tick < best_tick:
                best, best_tick = name, tick
        return best

    def drop_replica(self, name: str, site: str) -> None:
        with self._lock:
            s = self._sites.get(site)
            if s is not None and name in s.replicas:
                del s.replicas[name]
                s.used_mb -= self._datasets[name].size_mb

    def drop_site(self, site: str) -> list[str]:
        """A site died: every replica it held is gone.  Returns the datasets
        that lost their LAST replica (now unreachable anywhere)."""
        with self._lock:
            s = self._sites.pop(site, None)
            if s is None:
                return []
            lost = [n for n in s.replicas if not self.locate(n)]
            return lost

    def replicas_at(self, site: str) -> list[str]:
        with self._lock:
            s = self._sites.get(site)
            return sorted(s.replicas) if s is not None else []

    # -- byte accounting for placement ---------------------------------
    def missing(self, names: Iterable[str], site: str) -> list[str]:
        with self._lock:
            s = self._sites.get(site)
            have = s.replicas if s is not None else {}
            return [n for n in names if n not in have]

    def missing_mb(self, names: Iterable[str], site: str) -> float:
        with self._lock:
            return sum(self.get(n).size_mb for n in self.missing(names, site))

    def resident_mb(self, names: Iterable[str], site: str) -> float:
        with self._lock:
            s = self._sites.get(site)
            if s is None:
                return 0.0
            return sum(
                self.get(n).size_mb for n in names if n in s.replicas
            )


# ---------------------------------------------------------------------------
# Link models: per-platform-pair bandwidth/latency distributions
# ---------------------------------------------------------------------------


@dataclass
class LinkModel:
    """One directed platform-pair link.  Bandwidth is lognormal around
    ``bandwidth_mbps`` (sigma = ``jitter``), mirroring the autoscaler's
    LatencyModel parameterization: the mean is preserved when jitter moves."""

    bandwidth_mbps: float = 100.0  # MB/s
    latency_s: float = 0.05
    jitter: float = 0.15  # lognormal sigma; 0 = fixed bandwidth

    def sample_duration_s(self, rng: random.Random, size_mb: float) -> float:
        bw = self.bandwidth_mbps
        if self.jitter > 0:
            mu = math.log(max(bw, 1e-9)) - self.jitter**2 / 2.0
            bw = rng.lognormvariate(mu, self.jitter)
        return self.latency_s + size_mb / max(bw, 1e-6)

    def expected_s(self, size_mb: float) -> float:
        return self.latency_s + size_mb / max(self.bandwidth_mbps, 1e-6)


# Paper-shaped defaults (Table 1 platforms): intra-cloud links are fast,
# cloud<->HPC crossings are the narrow waist, the shared object store sits
# between, and HPC<->HPC rides the science DTN backbone.
DEFAULT_LINKS: dict[tuple[str, str], LinkModel] = {
    ("cloud", "cloud"): LinkModel(bandwidth_mbps=120.0, latency_s=0.05),
    ("cloud", "hpc"): LinkModel(bandwidth_mbps=40.0, latency_s=0.2),
    ("hpc", "cloud"): LinkModel(bandwidth_mbps=40.0, latency_s=0.2),
    ("hpc", "hpc"): LinkModel(bandwidth_mbps=200.0, latency_s=0.1),
    ("cloud", SHARED_SITE): LinkModel(bandwidth_mbps=100.0, latency_s=0.05),
    (SHARED_SITE, "cloud"): LinkModel(bandwidth_mbps=100.0, latency_s=0.05),
    ("hpc", SHARED_SITE): LinkModel(bandwidth_mbps=60.0, latency_s=0.1),
    (SHARED_SITE, "hpc"): LinkModel(bandwidth_mbps=60.0, latency_s=0.1),
}
FALLBACK_LINK = LinkModel(bandwidth_mbps=80.0, latency_s=0.1)


# ---------------------------------------------------------------------------
# Transfer engine: clock-driven, link-limited, re-routable
# ---------------------------------------------------------------------------

_transfer_ids = Counter("xfer")

QUEUED, ACTIVE, DONE, FAILED = "QUEUED", "ACTIVE", "DONE", "FAILED"


class Transfer:
    def __init__(self, dataset: str, size_mb: float, src: str, dst: str):
        self.uid = _transfer_ids.next()
        self.dataset = dataset
        self.size_mb = size_mb
        self.src = src
        self.dst = dst
        self.state = QUEUED
        self.queued_at = get_clock().now()
        self.started_at: Optional[float] = None
        self.done_at: Optional[float] = None
        self.reroutes = 0
        # bumped on every (re)start: a completion timer that fired for an
        # earlier start (and lost the lock race to a site_down re-route)
        # must not complete the restarted transfer at the stale deadline
        self.epoch = 0
        self.waiters: list[Callable[[bool], None]] = []
        self.call: Optional[ScheduledCall] = None

    @property
    def link(self) -> tuple[str, str]:
        return (self.src, self.dst)


class TransferEngine:
    """Executes dataset transfers on the active Clock.

    Every wait is a ``Clock.call_later`` deadline, so under a VirtualClock
    the auto-advancer jumps straight to transfer completions and a whole
    staging-heavy run takes real milliseconds.  Durations are sampled from
    one seeded RNG in start order: identical request sequences with the same
    seed produce an identical transfer schedule."""

    def __init__(
        self,
        registry: DatasetRegistry,
        seed: int = 0,
        links: Optional[dict[tuple[str, str], LinkModel]] = None,
        max_per_link: int = 2,
    ):
        self.registry = registry
        self.rng = random.Random(seed)
        self.links = dict(DEFAULT_LINKS)
        if links:
            self.links.update(links)
        self.max_per_link = max(1, max_per_link)
        self.trace = Trace()
        self._lock = threading.RLock()
        self._active: dict[tuple[str, str], list[Transfer]] = {}
        self._queued: dict[tuple[str, str], deque] = {}
        self._inflight: dict[tuple[str, str], Transfer] = {}  # (ds, dst)
        self.log: list[dict] = []  # completed-transfer schedule (determinism tests)
        # stats
        self.mb_moved = 0.0
        self.cache_hits = 0
        self.cold_reads = 0
        self.completed = 0
        self.failures = 0
        self.reroutes = 0
        self.queue_wait_s = 0.0
        self._events = None  # broker-owned EventBus (StagingService.attach_events)

    def _emit(self, name: str, **attrs) -> None:
        # callers hold self._lock, keeping each legacy increment and its
        # event adjacent so float folds match the accumulators bit-for-bit
        if self._events is not None:
            self._events.emit(name, **attrs)

    # -- link lookup ---------------------------------------------------
    def link_model(self, src_site: str, dst_site: str) -> LinkModel:
        key = (self.registry.platform_of(src_site), self.registry.platform_of(dst_site))
        return self.links.get(key, FALLBACK_LINK)

    def expected_transfer_s(self, name: str, dst: str) -> float:
        """Cheapest modeled time to materialize ``name`` at ``dst`` (0 if
        already resident): the cold-read charge gravity-aware policies use."""
        if self.registry.resident(name, dst):
            return 0.0
        ds = self.registry.get(name)
        src = self._best_source(name, dst)
        if src is None:
            return float("inf")
        return self.link_model(src, dst).expected_s(ds.size_mb)

    def _best_source(self, name: str, dst: str) -> Optional[str]:
        ds = self.registry.get(name)
        best, best_cost = None, None
        for site in self.registry.locate(name):
            if site == dst:
                return site
            cost = self.link_model(site, dst).expected_s(ds.size_mb)
            if best_cost is None or cost < best_cost:
                best, best_cost = site, cost
        return best

    def note_hit(self, name: str, site: str) -> None:
        """Replica-hit accounting (the counter is shared with fetch()'s
        transfer threads, so the increment must take the engine lock)."""
        with self._lock:
            self.cache_hits += 1
            self._emit("transfer.hit", dataset=name, site=site)
        self.registry.touch(name, site)

    # -- the fetch API -------------------------------------------------
    def fetch(self, name: str, dst: str, on_done: Callable[[bool], None]) -> None:
        """Materialize dataset ``name`` at site ``dst``; ``on_done(ok)``
        fires when it is resident (immediately on a replica hit) or when the
        transfer is abandoned (dataset lost everywhere)."""
        fire: Optional[bool] = None
        with self._lock:
            if self.registry.resident(name, dst):
                self.cache_hits += 1
                self._emit("transfer.hit", dataset=name, site=dst)
                self.registry.touch(name, dst)
                fire = True
            elif not self.registry.known(name):
                # an input that was never declared (typo, or a producer that
                # never registered its output): a failure the CALLER must
                # surface on the task — never an exception that could unwind
                # the dispatcher loop mid-batch
                self.failures += 1
                self._emit("transfer.fail", dataset=name, dst=dst)
                fire = False
            else:
                inflight = self._inflight.get((name, dst))
                if inflight is not None:
                    inflight.waiters.append(on_done)
                else:
                    ds = self.registry.get(name)
                    src = self._best_source(name, dst)
                    if src is None:
                        self.failures += 1
                        self._emit("transfer.fail", dataset=name, dst=dst)
                        fire = False
                    else:
                        self.cold_reads += 1
                        self._emit("transfer.cold", dataset=name, dst=dst)
                        tr = Transfer(name, ds.size_mb, src, dst)
                        tr.waiters.append(on_done)
                        self._inflight[(name, dst)] = tr
                        self._enqueue(tr)
        if fire is not None:
            on_done(fire)

    def _enqueue(self, tr: Transfer) -> None:
        # callers hold self._lock
        active = self._active.setdefault(tr.link, [])
        if len(active) < self.max_per_link:
            self._start(tr)
        else:
            self._queued.setdefault(tr.link, deque()).append(tr)

    def _start(self, tr: Transfer) -> None:
        # callers hold self._lock; sampling order == start order (seeded)
        clock = get_clock()
        duration = self.link_model(tr.src, tr.dst).sample_duration_s(
            self.rng, tr.size_mb
        )
        tr.state = ACTIVE
        tr.started_at = clock.now()
        tr.epoch += 1
        epoch = tr.epoch
        self.queue_wait_s += max(0.0, tr.started_at - tr.queued_at)
        self._emit(
            "transfer.start",
            dataset=tr.dataset,
            src=tr.src,
            dst=tr.dst,
            wait_s=max(0.0, tr.started_at - tr.queued_at),
        )
        self._active.setdefault(tr.link, []).append(tr)
        self.trace.add(f"start:{tr.dataset}:{tr.src}->{tr.dst}:{duration:.3f}s")
        tr.call = clock.call_later(duration, lambda: self._complete(tr, epoch))

    def _complete(self, tr: Transfer, epoch: int) -> None:
        """Transfer deadline elapsed (runs on a clock thread)."""
        waiters: list[Callable[[bool], None]] = []
        ok = True
        with self._lock:
            # state check alone is not enough: a timer that already _fire()d
            # (cancel() came too late) can block on this lock while site_down
            # re-routes and RESTARTS the transfer — the epoch pins this
            # completion to the start that scheduled it
            if tr.state != ACTIVE or tr.epoch != epoch:
                return
            self._detach(tr)
            tr.state = DONE
            tr.done_at = get_clock().now()
            try:
                self.registry.place_replica(tr.dataset, tr.dst)
            except StagingError:
                # destination vanished or cannot fit even after eviction
                tr.state = FAILED
                self.failures += 1
                self._emit("transfer.fail", dataset=tr.dataset, dst=tr.dst)
                ok = False
            else:
                self.mb_moved += tr.size_mb
                self.completed += 1
                self._emit(
                    "transfer.done",
                    dataset=tr.dataset,
                    src=tr.src,
                    dst=tr.dst,
                    mb=tr.size_mb,
                )
                self.log.append(
                    {
                        "dataset": tr.dataset,
                        "src": tr.src,
                        "dst": tr.dst,
                        "mb": tr.size_mb,
                        "t": tr.done_at,
                    }
                )
            self._inflight.pop((tr.dataset, tr.dst), None)
            waiters, tr.waiters = tr.waiters, []
            self.trace.add(f"done:{tr.dataset}:{tr.src}->{tr.dst}")
        for cb in waiters:
            cb(ok)

    def _detach(self, tr: Transfer) -> None:
        # callers hold self._lock: remove from active, start next queued
        active = self._active.get(tr.link, [])
        if tr in active:
            active.remove(tr)
        queue = self._queued.get(tr.link)
        while queue and len(active) < self.max_per_link:
            self._start(queue.popleft())

    # -- fault handling ------------------------------------------------
    def site_down(self, site: str) -> list[str]:
        """A site died.  Its replicas are dropped; transfers sourced from it
        re-route to a surviving replica (full restart — partial transfers
        are not resumable across sources); transfers *to* it fail their
        waiters so the owning task can re-gate to a new placement.  Returns
        datasets that lost their last replica."""
        failed: list[Transfer] = []
        with self._lock:
            lost = self.registry.drop_site(site)
            affected = [
                tr
                for trs in list(self._active.values())
                for tr in trs
                if tr.src == site or tr.dst == site
            ]
            for queue in self._queued.values():
                affected.extend(
                    tr for tr in list(queue) if tr.src == site or tr.dst == site
                )
            for tr in affected:
                if tr.call is not None:
                    tr.call.cancel()
                tr.state = QUEUED
                active = self._active.get(tr.link, [])
                if tr in active:
                    active.remove(tr)
                queue = self._queued.get(tr.link)
                if queue and tr in queue:
                    queue.remove(tr)
                if tr.dst == site or tr.dataset in lost:
                    tr.state = FAILED
                    self.failures += 1
                    self._emit("transfer.fail", dataset=tr.dataset, dst=tr.dst)
                    self._inflight.pop((tr.dataset, tr.dst), None)
                    failed.append(tr)
                    continue
                # source died mid-flight: restart from the next-best replica
                new_src = self._best_source(tr.dataset, tr.dst)
                if new_src is None:
                    tr.state = FAILED
                    self.failures += 1
                    self._emit("transfer.fail", dataset=tr.dataset, dst=tr.dst)
                    self._inflight.pop((tr.dataset, tr.dst), None)
                    failed.append(tr)
                    continue
                tr.src = new_src
                tr.reroutes += 1
                self.reroutes += 1
                self._emit(
                    "transfer.reroute", dataset=tr.dataset, src=new_src, dst=tr.dst
                )
                # a restart queues anew: without this, the next _start would
                # re-count the original queue wait PLUS the whole aborted
                # active period as queue wait
                tr.queued_at = get_clock().now()
                self.trace.add(f"reroute:{tr.dataset}:{new_src}->{tr.dst}")
                self._enqueue(tr)
            # freed link slots: pull whatever queued behind the dead site
            for link, active in list(self._active.items()):
                queue = self._queued.get(link)
                while queue and len(active) < self.max_per_link:
                    self._start(queue.popleft())
        for tr in failed:
            waiters, tr.waiters = tr.waiters, []
            for cb in waiters:
                cb(False)
        return lost

    def link_override(self, key: tuple[str, str], model: LinkModel) -> LinkModel:
        """Swap the LinkModel for one directed platform pair (chaos windows:
        degradation / partition).  Returns the model previously in effect so
        the caller can restore it when the window closes."""
        with self._lock:
            prev = self.links.get(key, FALLBACK_LINK)
            self.links[key] = model
            return prev

    def resample_link(self, key: tuple[str, str]) -> int:
        """Re-plan every ACTIVE transfer riding the platform pair ``key``:
        cancel its completion deadline and restart it so the duration is
        re-sampled under the CURRENT link model.  Like a site_down re-route,
        a restart is from scratch (partial progress is not resumable across
        a link renegotiation) and queues anew for its link slot.  The epoch
        bump in _start invalidates any stale completion timer that already
        fired and is waiting on the lock.  Returns the restart count."""
        with self._lock:
            affected = []
            for trs in list(self._active.values()):
                for tr in trs:
                    try:
                        k = (
                            self.registry.platform_of(tr.src),
                            self.registry.platform_of(tr.dst),
                        )
                    except UnknownSite:
                        continue  # endpoint died concurrently: site_down owns it
                    if k == key:
                        affected.append(tr)
            for tr in affected:
                if tr.call is not None:
                    tr.call.cancel()
                active = self._active.get(tr.link, [])
                if tr in active:
                    active.remove(tr)
                tr.state = QUEUED
                tr.queued_at = get_clock().now()
                self.trace.add(f"resample:{tr.dataset}:{tr.src}->{tr.dst}")
                self._enqueue(tr)
            return len(affected)

    def active_transfers(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._active.values())

    def queued_transfers(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._queued.values())

    def shutdown(self) -> None:
        """Cancel everything in flight and FAIL its waiters: a waiter left
        unfired would strand its task in the dispatcher's blocked set (and
        its Future unresolved) forever."""
        waiters: list[Callable[[bool], None]] = []
        with self._lock:
            pending = [tr for trs in self._active.values() for tr in trs]
            pending += [tr for q in self._queued.values() for tr in q]
            for tr in pending:
                if tr.call is not None:
                    tr.call.cancel()
                tr.state = FAILED
                w, tr.waiters = tr.waiters, []
                waiters.extend(w)
            self._active.clear()
            self._queued.clear()
            self._inflight.clear()
        for cb in waiters:
            cb(False)


# ---------------------------------------------------------------------------
# StagingService: the broker-facing facade
# ---------------------------------------------------------------------------


class StagingService:
    """Registry + engine + per-task stage-in barriers + stage-out.

    One per broker.  The streaming dispatcher calls ``stage_task`` before
    dispatching a task whose declared inputs are missing at its placement
    site; binding policies call ``transfer_cost_s`` to fold data locality
    into placement; the broker calls ``task_completed`` to register outputs
    (stage-out) and ``site_down`` when a provider dies."""

    def __init__(
        self,
        seed: int = 0,
        default_capacity_mb: Optional[float] = None,
        links: Optional[dict[tuple[str, str], LinkModel]] = None,
        max_per_link: int = 2,
        mirror_outputs: bool = False,
    ):
        self.registry = DatasetRegistry(default_capacity_mb=default_capacity_mb)
        self.engine = TransferEngine(
            self.registry, seed=seed, links=links, max_per_link=max_per_link
        )
        # write-through stage-out: every declared output also lands a replica
        # in the shared object store, so a later WHOLE-SITE outage (chaos)
        # cannot take an intermediate dataset's last copy with it.  Like the
        # drain path's evacuate(), the copy is not time-modeled; the bytes
        # are reported separately (``mirrored_mb``).
        self.mirror_outputs = mirror_outputs
        self._lock = threading.Lock()
        self.stage_ins = 0
        self.stage_outs = 0
        self.stage_out_drops = 0  # outputs that could not fit their site
        self.evacuated_mb = 0.0  # last-copy bytes saved by graceful drains
        self.mirrored_mb = 0.0  # write-through stage-out copies (chaos durability)
        self.transfer_wait_s = 0.0  # total task-observed stage-in wait
        self._events = None  # broker-owned EventBus (attach_events)

    def attach_events(self, bus) -> None:
        """Wire the broker's event bus through the whole staging stack:
        service-level stage-in/out accounting, engine transfer lifecycle,
        and registry evictions all become structured events
        (core/events.py), with every emission adjacent to its legacy
        counter so HYDRA_EVENTS_CHECK can hold them bit-equal."""
        self._events = bus
        self.engine._events = bus
        self.registry._events = bus

    def _emit(self, name: str, **attrs) -> None:
        # callers hold self._lock (same adjacency rule as the engine's)
        if self._events is not None:
            self._events.emit(name, **attrs)

    # -- site lifecycle ------------------------------------------------
    def register_site(
        self, name: str, platform: str = "cloud", capacity_mb=_DEFAULT_CAP
    ) -> None:
        self.registry.register_site(name, platform, capacity_mb)

    def site_down(self, name: str) -> list[str]:
        return self.engine.site_down(name)

    def evacuate(self, site: str) -> float:
        """Graceful drain (elastic scale-in, NOT an outage): any dataset
        whose only replica lives on the departing site is copied into the
        shared store first, so a routine voluntary release can never
        terminally fail downstream tasks over data loss.  The drain path is
        not time-modeled, so neither is the evacuation copy; the bytes are
        reported separately (``evacuated_mb``)."""
        moved = 0.0
        for name in self.registry.replicas_at(site):
            if self.registry.locate(name) == [site]:  # last copy: save it
                try:
                    self.registry.place_replica(name, SHARED_SITE)
                except StagingError:
                    continue
                moved += self.registry.get(name).size_mb
        if moved:
            with self._lock:
                self.evacuated_mb += moved
                self._emit("stage.evacuate", site=site, mb=moved)
        return moved

    # -- placement scoring ---------------------------------------------
    def missing(self, names: Iterable[str], site: str) -> list[str]:
        return self.registry.missing(names, site)

    def transfer_cost_s(self, names: Iterable[str], site: str) -> float:
        """Modeled seconds to materialize every missing input at ``site``
        (replica reads are free; unknown datasets charge nothing — they are
        declared at the producer's completion, which gates dispatch anyway).
        Transfers ride separate links concurrently, so the cost of a set is
        its slowest member, not the sum.  One semantics, one implementation:
        this is the single-site view of ``transfer_cost_many``."""
        return self.transfer_cost_many(names, (site,))[site]

    def transfer_cost_many(self, names: Iterable[str], sites: Iterable[str]) -> dict[str, float]:
        """``transfer_cost_s`` for one input set across MANY candidate sites
        in a single pass: the per-dataset source/size lookups are shared
        across sites instead of re-resolved per (task, target), which is
        what lets the gravity policy price a whole bind batch without
        re-querying the registry per task (§Perf, exp9)."""
        known = [n for n in names if self.registry.known(n)]
        costs: dict[str, float] = {}
        for site in sites:
            worst = 0.0
            for n in known:
                cost = self.engine.expected_transfer_s(n, site)
                if cost == float("inf"):
                    continue  # lost dataset: surfaces at stage time, not bind time
                worst = max(worst, cost)
            costs[site] = worst
        return costs

    def note_local(self, names: Iterable[str], site: str) -> None:
        """Every input already resident (the gate's fast path): count the
        replica hits and keep their LRU state warm."""
        for n in names:
            if self.registry.resident(n, site):
                self.engine.note_hit(n, site)

    # -- stage-in ------------------------------------------------------
    def stage_task(self, task, site: str, on_ready: Callable[[bool], None]) -> None:
        """Materialize every input of ``task`` at ``site``; ``on_ready(ok)``
        fires once when all transfers land (or once on the first failure).
        Transfers for distinct inputs run concurrently (per-link limits
        permitting) and overlap with other tasks' compute."""
        names = list(task.inputs)
        missing = self.registry.missing(names, site)
        self.note_local((n for n in names if n not in missing), site)
        if not missing:
            on_ready(True)
            return
        clock = get_clock()
        t0 = clock.now()
        state = {"left": len(missing), "failed": False, "done": False}
        lock = threading.Lock()
        with self._lock:
            self.stage_ins += 1
            self._emit("stage.in", task=task.uid, site=site, missing=len(missing))
        task.trace.add(f"stage_in_start:{site}:{len(missing)}")

        def finish(ok: bool) -> None:
            with self._lock:
                wait = max(0.0, clock.now() - t0)
                self.transfer_wait_s += wait
                self._emit("stage.wait", task=task.uid, wait_s=wait)
            task.trace.add("stage_in_done" if ok else "stage_in_failed")
            on_ready(ok)

        def one_done(ok: bool) -> None:
            with lock:
                if state["done"]:
                    return
                if not ok:
                    state["done"] = True
                    state["failed"] = True
                else:
                    state["left"] -= 1
                    if state["left"] > 0:
                        return
                    state["done"] = True
            finish(not state["failed"])

        for n in missing:
            with lock:
                if state["done"]:  # a synchronous failure already resolved
                    break  # the barrier: don't launch orphan transfers
            self.engine.fetch(n, site, one_done)

    # -- stage-out -----------------------------------------------------
    def task_completed(self, task, site: str) -> None:
        """Register the task's declared outputs as replicas at the site that
        ran it, and keep its inputs' LRU state warm there."""
        for name in task.inputs:
            self.registry.touch(name, site)
        for name, size_mb in task.outputs.items():
            self.registry.add(name, size_mb)
            try:
                self.registry.place_replica(name, site)
            except StagingError:
                # scratch full of pinned/last-copy data: the output spills to
                # the shared store instead of silently vanishing
                with self._lock:
                    self.stage_out_drops += 1
                    self._emit("stage.drop", dataset=name, site=site)
                self.registry.place_replica(name, SHARED_SITE)
            if self.mirror_outputs and not self.registry.resident(name, SHARED_SITE):
                try:
                    self.registry.place_replica(name, SHARED_SITE)
                except StagingError:
                    pass  # shared store full of pinned data: best-effort
                else:
                    with self._lock:
                        mb = self.registry.get(name).size_mb
                        self.mirrored_mb += mb
                        self._emit("stage.mirror", dataset=name, mb=mb)
            with self._lock:
                self.stage_outs += 1
                self._emit("stage.out", dataset=name, site=site, mb=size_mb)
        if task.outputs:
            task.trace.add(f"stage_out:{site}:{len(task.outputs)}")

    # -- metrics -------------------------------------------------------
    def stats(self) -> dict:
        """Engine + stage-in/out counters.  Parked-task counts live with the
        dispatcher (the single owner of the blocked set): see
        ``Hydra.staging_stats()``, which merges in ``staging_blocked``.

        With an event bus attached, every accumulated counter here is the
        log-derived view (core/events.py); the legacy accumulators stay as
        the HYDRA_EVENTS_CHECK ground truth.  Emission order matches
        accumulation order (both under the engine/service locks), so even
        the float sums are bit-identical.  active/queued transfers are live
        gauges, never folds."""
        e = self.engine
        if self._events is not None:
            v = self._events.view
            counters = {
                "mb_moved": round(v.get("hydra.staging.mb_moved"), 3),
                "transfers": int(v.get("hydra.staging.transfers")),
                "cache_hits": int(v.get("hydra.staging.cache_hits")),
                "cold_reads": int(v.get("hydra.staging.cold_reads")),
                "reroutes": int(v.get("hydra.staging.reroutes")),
                "transfer_failures": int(v.get("hydra.staging.transfer_failures")),
                "evictions": int(v.get("hydra.staging.evictions")),
                "queue_wait_s": round(v.get("hydra.staging.queue_wait_s"), 3),
                "transfer_wait_s": round(v.get("hydra.staging.transfer_wait_s"), 3),
                "stage_ins": int(v.get("hydra.staging.stage_ins")),
                "stage_outs": int(v.get("hydra.staging.stage_outs")),
                "stage_out_drops": int(v.get("hydra.staging.stage_out_drops")),
                "evacuated_mb": round(v.get("hydra.staging.evacuated_mb"), 3),
                "mirrored_mb": round(v.get("hydra.staging.mirrored_mb"), 3),
            }
        else:
            with self._lock:
                wait = self.transfer_wait_s
                outs, drops = self.stage_outs, self.stage_out_drops
                evac, mirrored = self.evacuated_mb, self.mirrored_mb
            counters = {
                "mb_moved": round(e.mb_moved, 3),
                "transfers": e.completed,
                "cache_hits": e.cache_hits,
                "cold_reads": e.cold_reads,
                "reroutes": e.reroutes,
                "transfer_failures": e.failures,
                "evictions": self.registry.evictions,
                "queue_wait_s": round(e.queue_wait_s, 3),
                "transfer_wait_s": round(wait, 3),
                "stage_ins": self.stage_ins,
                "stage_outs": outs,
                "stage_out_drops": drops,
                "evacuated_mb": round(evac, 3),
                "mirrored_mb": round(mirrored, 3),
            }
        counters["active_transfers"] = e.active_transfers()
        counters["queued_transfers"] = e.queued_transfers()
        return counters

    def shutdown(self) -> None:
        self.engine.shutdown()
