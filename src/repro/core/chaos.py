"""Chaos engine: seeded, clock-scheduled, *correlated* fault injection.

The hybrid-cloud literature (PAPERS.md) treats correlated site/link failure
as the norm for cloud+HPC fleets, not the exception: a zone outage takes a
provider, its scratch storage, and its group siblings down *together*; a
WAN event partitions a whole platform pair at once; a provisioning-API
brownout quarantines every launch of a template.  This module injects those
coupled events against a live ``Hydra`` broker, scheduled entirely on the
``Clock`` abstraction — so under a ``VirtualClock`` an adversarial run is
deterministic and takes real milliseconds — and records what it did in an
append-only log the scenario layer (repro/scenarios) folds into its report.

Event types and their injection points:

  SiteOutage        Hydra.remove_provider(drain=False) per victim — hard
                    outage: manager fails in-flight work, staging drops the
                    site's replicas and re-routes/fails its transfers, the
                    orphan sweep re-binds survivors.  A group target takes
                    every member AND the group's logical staging site down
                    together; the autoscaler is told so dead elastic names
                    stop occupying pool headroom.
  LinkWindow        TransferEngine.link_override for a platform pair (both
                    directions by default) for ``duration_s``: factor > 0
                    degrades bandwidth, factor <= 0 partitions the pair.
                    Active transfers on the pair are restarted under the new
                    model (resample_link) at open AND close.
  QuarantineStorm   ProviderPool.force_quarantine(template): the scale-out
                    loop stops buying the template until the window closes
                    (rehabilitate) — a provisioning-API brownout.
  PreemptKill       task.mark_failed(Preempted) on up to ``count`` RUNNING
                    tasks with retry budget left; the executing manager
                    notices the FAILED state when the work function returns
                    and routes the task through the normal retry machinery.
                    With a TaskCheckpointer attached (core/broker.py
                    ``enable_task_checkpoints``), checkpointable victims
                    instead RESUME from their captured ``progress_frac`` on
                    a surviving provider without charging ``max_retries`` —
                    the storm becomes a priced, recoverable regime
                    (core/market.py) rather than a retry-budget drain.

Every event carries ``at_s`` relative to ``arm()`` time.  The engine never
raises out of a clock callback: injection errors are captured in the log
(``"error"`` entries) so one failed injection cannot wedge the clock thread
that fires every other deadline in the run.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import Optional, Union

from repro.core.managers.compute import Preempted
from repro.core.staging import FALLBACK_LINK, LinkModel
from repro.runtime.clock import ScheduledCall, get_clock

PARTITION_BANDWIDTH_MBPS = 1e-6  # effectively unroutable, never div-by-zero


@dataclass(frozen=True)
class SiteOutage:
    """Whole-site loss: provider (or group: all members) + its staging site."""

    at_s: float
    site: str
    kind: str = field(default="site_outage", init=False)

    @property
    def target(self) -> str:
        return self.site


@dataclass(frozen=True)
class LinkWindow:
    """Degradation (factor > 0 scales bandwidth) or partition (factor <= 0)
    of one platform pair for ``duration_s`` seconds."""

    at_s: float
    duration_s: float
    src_platform: str
    dst_platform: str
    factor: float = 0.0  # <= 0: partition
    bidirectional: bool = True
    kind: str = field(default="link_window", init=False)

    @property
    def target(self) -> str:
        arrow = "<->" if self.bidirectional else "->"
        return f"{self.src_platform}{arrow}{self.dst_platform}"


@dataclass(frozen=True)
class QuarantineStorm:
    """Provisioning-API brownout for one launch template."""

    at_s: float
    template: str
    duration_s: float = 0.0  # 0: stays until a real arrival resets it
    kind: str = field(default="quarantine_storm", init=False)

    @property
    def target(self) -> str:
        return self.template


@dataclass(frozen=True)
class PreemptKill:
    """Kill up to ``count`` RUNNING tasks (spot reclaim / walltime kill)."""

    at_s: float
    count: int = 1
    provider: Optional[str] = None  # None: fleet-wide
    kind: str = field(default="preempt_kill", init=False)

    @property
    def target(self) -> str:
        return self.provider or "*"


ChaosEvent = Union[SiteOutage, LinkWindow, QuarantineStorm, PreemptKill]


class ChaosEngine:
    """Schedules a seeded list of ChaosEvents against one broker.

    ``arm()`` books every event as a ``Clock.call_later`` deadline up front
    — which is also what makes a LinkWindow partition safe under a
    VirtualClock auto-advancer: the window-close deadline is always pending
    and *earlier* than any partition-priced transfer completion, so the
    advancer can never leap the run over the recovery.  ``stop()`` cancels
    outstanding deadlines and closes any link window still open, restoring
    the saved models."""

    def __init__(self, broker, events: list[ChaosEvent], seed: int = 0):
        self.broker = broker
        self.events = sorted(events, key=lambda e: (e.at_s, e.kind, e.target))
        self.rng = random.Random(seed)
        self.log: list[dict] = []
        self._lock = threading.RLock()
        self._calls: list[ScheduledCall] = []
        self._saved_links: dict[tuple[str, str], LinkModel] = {}
        self._open_windows = 0
        self._armed = False
        # per-kind injection counters (scenario reports)
        self.injected: dict[str, int] = {}
        self.preempted_uids: list[str] = []

    # -- scheduling ----------------------------------------------------
    def planned(self) -> list[tuple[float, str, str]]:
        """The deterministic event schedule: (at_s, kind, target)."""
        return [(e.at_s, e.kind, e.target) for e in self.events]

    def arm(self) -> "ChaosEngine":
        """Book every event on the active clock, relative to now."""
        with self._lock:
            if self._armed:
                raise RuntimeError("chaos engine already armed")
            self._armed = True
            clock = get_clock()
            for ev in self.events:
                self._calls.append(
                    clock.call_later(max(0.0, ev.at_s), lambda e=ev: self._fire(e))
                )
        return self

    def stop(self) -> None:
        """Cancel pending events; close any still-open link window."""
        with self._lock:
            calls, self._calls = self._calls, []
            for call in calls:
                call.cancel()
            saved, self._saved_links = dict(self._saved_links), {}
            self._open_windows = 0
        engine = self.broker.staging.engine
        for key, model in saved.items():
            engine.link_override(key, model)
            engine.resample_link(key)

    def _fire(self, ev: ChaosEvent) -> None:
        """Runs on a clock thread: must never raise (see module docstring)."""
        handler = {
            "site_outage": self._site_outage,
            "link_window": self._open_link_window,
            "quarantine_storm": self._quarantine_storm,
            "preempt_kill": self._preempt_kill,
        }[ev.kind]
        try:
            detail = handler(ev)
        except Exception as exc:  # noqa: BLE001 - log, never wedge the clock
            self._record(ev.kind, ev.target, {"error": repr(exc)})
        else:
            self._record(ev.kind, ev.target, detail)

    def _record(self, kind: str, target: str, detail: dict) -> None:
        with self._lock:
            self.injected[kind] = self.injected.get(kind, 0) + 1
            self.broker.events.emit("chaos.inject", kind=kind, target=target)
            self.log.append(
                {
                    "t": round(get_clock().now(), 6),
                    "kind": kind,
                    "target": target,
                    "detail": detail,
                }
            )

    # -- handlers ------------------------------------------------------
    def _site_outage(self, ev: SiteOutage) -> dict:
        b = self.broker
        if b.proxy.is_group(ev.site):
            # correlated: the whole zone goes — every member, then the
            # group-local store the survivors would otherwise still read
            victims = list(b.proxy.get_group(ev.site).member_names())
        else:
            victims = [ev.site]
        removed = []
        for name in victims:
            try:
                b.remove_provider(name, drain=False, deregister=False)
            except KeyError:
                continue  # already gone (raced an elastic release)
            removed.append(name)
            if b.autoscaler is not None:
                b.autoscaler.note_provider_lost(name)
        if b.proxy.is_group(ev.site):
            b.staging.site_down(ev.site)
            b.data.deregister_site(ev.site)
        return {"removed": removed}

    def _degraded_model(self, base: LinkModel, factor: float) -> LinkModel:
        if factor <= 0:  # partition: unroutable, not divide-by-zero
            return LinkModel(
                bandwidth_mbps=PARTITION_BANDWIDTH_MBPS,
                latency_s=base.latency_s,
                jitter=0.0,
            )
        return LinkModel(
            bandwidth_mbps=base.bandwidth_mbps * factor,
            latency_s=base.latency_s,
            jitter=base.jitter,
        )

    def _link_keys(self, ev: LinkWindow) -> list[tuple[str, str]]:
        keys = [(ev.src_platform, ev.dst_platform)]
        if ev.bidirectional and ev.src_platform != ev.dst_platform:
            keys.append((ev.dst_platform, ev.src_platform))
        return keys

    def _open_link_window(self, ev: LinkWindow) -> dict:
        engine = self.broker.staging.engine
        restarted = 0
        with self._lock:
            self._open_windows += 1
            for key in self._link_keys(ev):
                prev = engine.link_override(
                    key, self._degraded_model(engine.links.get(key, FALLBACK_LINK), ev.factor)
                )
                # nested/overlapping windows: keep the ORIGINAL model, so the
                # last close restores reality and not an earlier degradation
                self._saved_links.setdefault(key, prev)
            self._calls.append(
                get_clock().call_later(
                    ev.duration_s, lambda e=ev: self._close_link_window(e)
                )
            )
        for key in self._link_keys(ev):
            restarted += engine.resample_link(key)
        return {
            "factor": ev.factor,
            "duration_s": ev.duration_s,
            "restarted_transfers": restarted,
        }

    def _close_link_window(self, ev: LinkWindow) -> None:
        engine = self.broker.staging.engine
        restarted = 0
        with self._lock:
            self._open_windows = max(0, self._open_windows - 1)
            restore = {}
            if self._open_windows == 0:
                # last window out restores every saved pair (overlapping
                # windows over the same pair share one saved original)
                restore, self._saved_links = dict(self._saved_links), {}
            else:
                for key in self._link_keys(ev):
                    if key in self._saved_links:
                        restore[key] = self._saved_links.pop(key)
        for key, model in restore.items():
            engine.link_override(key, model)
            restarted += engine.resample_link(key)
        self._record(
            "link_restore", ev.target, {"restarted_transfers": restarted}
        )

    def _quarantine_storm(self, ev: QuarantineStorm) -> dict:
        scaler = self.broker.autoscaler
        if scaler is None:
            return {"skipped": "no autoscaler attached"}
        scaler.pool.force_quarantine(ev.template)
        if ev.duration_s > 0:
            with self._lock:
                self._calls.append(
                    get_clock().call_later(
                        ev.duration_s, lambda e=ev: self._end_quarantine(e)
                    )
                )
        return {"duration_s": ev.duration_s}

    def _end_quarantine(self, ev: QuarantineStorm) -> None:
        scaler = self.broker.autoscaler
        if scaler is not None:
            scaler.pool.rehabilitate(ev.template)
        self._record("quarantine_lift", ev.template, {})

    def _preempt_kill(self, ev: PreemptKill) -> dict:
        # only victims with retry budget left: chaos verifies resilience, it
        # must not manufacture a terminal failure the invariants then flag
        victims = [
            t
            for t in self.broker._running_tasks()
            if t.retries < t.max_retries
            and (ev.provider is None or t.provider == ev.provider)
        ]
        victims.sort(key=lambda t: t.uid)  # stable pool for the seeded draw
        if len(victims) > ev.count:
            victims = self.rng.sample(victims, ev.count)
        killed = []
        for t in victims:
            if t.mark_failed(Preempted(t.provider or "?")):
                t.trace.add("preempted")
                killed.append(t.uid)
        with self._lock:
            self.preempted_uids.extend(killed)
        return {"requested": ev.count, "killed": len(killed)}

    # -- metrics -------------------------------------------------------
    def stats(self) -> dict:
        """Injection counts are the log-derived view over chaos.inject
        events (the legacy dict stays as HYDRA_EVENTS_CHECK ground truth);
        the rest are live gauges of this engine's plan state."""
        injected = {
            k: int(n)
            for k, n in sorted(
                self.broker.events.view.keyed_get("hydra.chaos.injected").items()
            )
        }
        with self._lock:
            return {
                "events_planned": len(self.events),
                "injected": injected,
                "preempted": len(self.preempted_uids),
                "open_link_windows": self._open_windows,
                "log_entries": len(self.log),
            }
