"""Streaming DAG dispatcher: micro-batched, late-bound, backfilling.

Frontier-mode workflow execution (the paper's Argo analogue) turns *every*
readiness event into a fresh full-pipeline ``broker.submit()`` — one
bind/partition/serialize/dispatch round per micro-frontier, often a
single-task pod.  Per-submission overhead therefore grows with
DAG depth x instance count, the opposite of the paper's near-constant
broker-overhead claim (§5.4, §6).

The streaming dispatcher inverts that: ONE long-lived loop owns a
ready-queue fed by every running workflow, and

  * **micro-batches**: ready tasks arriving within ``batch_window`` (measured
    on the active clock, so virtual-time tests stay fast) coalesce into one
    submission of up to ``max_batch`` tasks — across ALL workflow instances,
    so 800 one-task frontiers become a handful of well-filled pods;
  * **late-binds**: the binding policy and the provider-group breaker state
    (core/group.py) are consulted when the batch *dispatches*, not when the
    DAG was built — a member that died a millisecond ago is already out of
    rotation;
  * **backfills**: batches are drained shallow-DAG-depth-first and sized
    against the pools' ``idle_slots()`` hint, so when the shallow frontier
    is too small to fill idle capacity, ready tasks from deeper workflows
    ride along instead of waiting for their instance's "turn".

``WorkflowManager`` (core/managers/workflow.py) shrinks to a dependency
tracker that feeds this queue.
"""
from __future__ import annotations

import heapq
import math
import threading
from typing import TYPE_CHECKING, Optional

from repro.core.policy import NoEligibleProvider, apportion_budget
from repro.core.staging import StagingError
from repro.core.task import SLO_CLASSES, Task, TaskState
from repro.runtime.clock import get_clock
from repro.runtime.tracing import Counter, Trace

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.broker import Hydra

_batch_ids = Counter("batch")


class StreamingDispatcher:
    """The broker's long-lived ready-queue -> micro-batch -> submit loop."""

    def __init__(
        self,
        broker: "Hydra",
        batch_window: float = 0.002,
        max_batch: int = 256,
        min_batch: int = 32,
        max_consecutive_failures: int = 500,
    ):
        self.broker = broker
        self.batch_window = batch_window
        self.max_batch = max(1, max_batch)
        self.min_batch = max(1, min(min_batch, self.max_batch))
        # back-to-back dispatch failures (~10ms backoff each) before a
        # persistent outage is surfaced onto the tasks instead of retried
        self.max_consecutive_failures = max_consecutive_failures
        self.trace = Trace()
        # ready queue: per-(slo_class, tenant) LANES, each a heap keyed by
        # (depth, arrival) so the shallow-first drain stays O(log n) per
        # task.  The drain walks classes in strict SLO_CLASSES order —
        # every interactive lane empties before any batch lane sees budget
        # (queued batch backfill is preempted, not running work) — and
        # splits the budget among same-class lanes by tenant weight
        # (policy.apportion_budget, deficits carried in _lane_carry).  The
        # single-lane common case (no tenant config) pops directly, so the
        # exp9 hot path pays one dict lookup over the old flat heap.
        self._lanes: dict[tuple[str, str], list[tuple[int, int, Task]]] = {}
        self._lane_carry: dict[tuple[str, str], float] = {}
        self._npending = 0
        self._class_pending: dict[str, int] = {c: 0 for c in SLO_CLASSES}
        self._queued: set[str] = set()  # uids in the lanes (dedup guard)
        # tasks parked on stage-in (core/staging.py): OUT of the ready heap,
        # so pending()/queue_pressure() never count work that no amount of
        # new capacity could run — exactly what keeps the autoscaler from
        # buying providers for tasks that are waiting on bytes, not slots.
        # _blocked_at stamps the park time: deferred_demand() decays parked
        # tasks back into the autoscaler's demand signal (recently parked ~
        # transfers in flight ~ capacity needed soon; anciently stuck ~ 0).
        self._blocked: dict[str, Task] = {}
        self._blocked_at: dict[str, float] = {}
        # checkpoint resumes re-entering the gate (ckpt/checkpoint.py): the
        # resume carries its ckpt:<uid> dataset as an input, so it pays the
        # normal data-gravity placement + staging cost on the way back in
        self.resume_gated = 0
        self.max_staging_attempts = 3
        self._seq = 0
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # staging-retry timers the dispatcher OWNS: stop() cancels them and
        # resolves their tasks, so shutdown can never race a late requeue
        # into a dead loop (and no task future is left dangling)
        self._timer_lock = threading.Lock()
        self._retry_timers: dict[object, Task] = {}
        # metrics: the streaming-vs-frontier story in benchmarks/exp6
        self.batches = 0
        self.tasks_dispatched = 0
        self.retry_backoffs = 0
        self.loop_errors = 0
        self._consecutive_failures = 0  # current retry streak (reset on success)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "StreamingDispatcher":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name="hydra-stream"
            )
            self._thread.start()
            self.trace.add("dispatcher_started")
        return self

    def stop(self, wait: bool = True) -> None:
        self._stop.set()
        self._wake.set()
        # sweep the staging-retry timer registry: a timer that has not fired
        # is cancelled and its task failed cleanly (an enqueue into a
        # stopping loop would strand the future unresolved forever); a timer
        # mid-fire re-checks _stop and fails its task itself
        with self._timer_lock:
            timers = list(self._retry_timers.items())
            self._retry_timers.clear()
        for timer, task in timers:
            timer.cancel()
            with self._lock:
                self._unpark_locked(task.uid)
            self._fail_task(
                task,
                StagingError(f"task {task.uid}: dispatcher stopped during staging retry"),
            )
        if wait and self._thread is not None:
            self._thread.join(timeout=5.0)
        self.trace.add("dispatcher_stopped")

    def notify_capacity(self) -> None:
        """Idle supply grew (completion, breaker close, provider arrival —
        the CapacityLedger's capacity-gain callback via the broker): wake
        the loop now instead of letting a poll timeout expire.  This is what
        removes the 20-50 ms real-time floor per saturated round that used
        to dominate virtual-clock runs."""
        self._wake.set()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive() and not self._stop.is_set()

    # -- the ready queue -------------------------------------------------
    def enqueue(self, tasks: list[Task]) -> None:
        """Feed ready tasks (deps satisfied) from any workflow or caller."""
        if not tasks:
            return
        with self._lock:
            added = False
            for t in tasks:
                if t.uid in self._queued:
                    continue
                self._queued.add(t.uid)
                lane = (t.slo_class, t.tenant)
                heapq.heappush(
                    self._lanes.setdefault(lane, []), (t.depth, self._seq, t)
                )
                self._seq += 1
                self._npending += 1
                self._class_pending[t.slo_class] += 1
                added = True
            if added:
                self._idle.clear()
        self._wake.set()

    def pending(self) -> int:
        with self._lock:
            return self._npending

    def pending_by_class(self) -> dict[str, int]:
        """Ready-queue depth per SLO class: the autoscaler's per-class
        pressure input, so interactive demand can buy capacity even while
        batch admission is throttled."""
        with self._lock:
            return dict(self._class_pending)

    def queue_pressure(self) -> float:
        """Demand over supply: ready-queue depth / (idle + incoming slots).
        THE autoscaler input (core/autoscaler.py): > 1 means the queue could
        not be absorbed even if every free and in-acquisition slot took one
        task; ~0 means the pool is idle.

        Zero-supply semantics are explicit: no pending work is 0.0 whatever
        the supply.  With pending work and no free slot, two states that the
        old ``pending / max(supply, 1)`` conflated are now distinguished:
        a *saturated-but-live* fleet (slots exist, all busy — in-flight work
        will free them) reads as the raw pending count (finite, maximally
        pressured), while a fleet with no live capacity at all (every
        breaker OPEN, nothing incoming) reads as ``inf`` — a sentinel the
        autoscaler maps through its probe-aware path (Autoscaler.pressure)
        instead of a raw count that merely *scaled* with backlog (100k tasks
        read as "pressure 100000", slamming the pool to max during a
        full-fleet outage that a single breaker probe would recover)."""
        pending = self.pending()
        if pending <= 0:
            return 0.0
        supply = self.broker.idle_slots() + self.broker.incoming_slots()
        if supply > 0:
            return pending / supply
        # supply==0 implies incoming==0 too, so total alone decides whether
        # any live slot could ever absorb this queue
        if self.broker.total_slots() > 0:
            return float(pending)
        return float("inf")

    def deferred_demand(self, tau_s: float = 60.0) -> float:
        """Staging-parked tasks as *decayed* autoscaler demand.

        A task parked on stage-in is not runnable — but its transfers are
        in flight and it will want a slot in seconds, which is exactly when
        an elastic pool that drained to zero during a link partition would
        make the whole herd wait out a re-acquisition ramp.  Count each
        parked task as ``exp(-age/tau)`` demand: freshly parked ~ 1 slot
        needed soon, stuck-for-minutes ~ 0 (no point buying capacity for
        bytes that are not arriving).  This replaces the at-scale preset's
        ``min_instances`` warm-floor workaround (scenarios/presets.py)."""
        now = get_clock().now()
        with self._lock:
            stamps = list(self._blocked_at.values())
        return sum(math.exp(-max(0.0, now - t0) / tau_s) for t0 in stamps)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the queue is empty and no batch is in flight (tests)."""
        return self._idle.wait(timeout)

    # -- the loop --------------------------------------------------------
    def _loop(self) -> None:
        while not self._stop.is_set():
            if not self.pending():
                with self._lock:
                    if not self._npending:  # recheck under the lock
                        self._wake.clear()
                        # drain()'s contract is "nothing left to dispatch":
                        # a task parked on stage-in is still owed a dispatch,
                        # so the queue is not idle while any task is blocked
                        if not self._blocked:
                            self._idle.set()
                # enqueue always signals _wake, so this wait is purely
                # event-driven; the timeout is a belt-and-braces valve, far
                # off the hot path (it used to be a 50 ms poll)
                self._wake.wait(timeout=0.5)
                continue
            # open the micro-batch window: readiness events from other
            # workflows coalesce here (clock-aware: virtual windows are free)
            clock = get_clock()
            if self.batch_window > 0:
                clock.sleep(self.batch_window)
            try:
                # hold the clock only across the drain: submit() may sleep
                # modeled provider latencies on this same clock, and hold()'s
                # contract forbids sleeping under a hold (deadlock valve)
                with clock.hold():
                    batch = self._take_batch()
                if batch:
                    self._dispatch(batch)
                elif self.pending():
                    # saturated under the elastic throttle.  Every capacity
                    # gain is an event now: completions and breaker closes
                    # signal through the CapacityLedger (notify_capacity),
                    # provider arrivals through Autoscaler._arrive.  Clear
                    # first, THEN re-read idle supply (O(1) ledger): a gain
                    # landing in the gap set _wake after our clear, so the
                    # wait below returns immediately instead of losing it.
                    self._wake.clear()
                    if self.broker.idle_slots() <= 0 and not self._stop.is_set():
                        self._wake.wait(0.25)
            except Exception:
                # the loop is the broker's lifeline: a raced completion or a
                # recovery-path error must never kill the dispatcher thread.
                # Back off so a persistent error cannot become a hot spin.
                self.loop_errors += 1
                self.broker.events.emit("dispatch.loop_error")
                self.trace.add("loop_error")
                self._stop.wait(0.05)

    def _take_batch(self) -> list[Task]:
        """Drain up to the batch budget: strict SLO-class priority, weighted
        fair share among same-class tenant lanes, shallow DAG depth first
        within a lane (backfill: deeper-workflow tasks fill whatever
        capacity the frontier leaves).

        With an autoscaler attached — or a tenant front door configured
        (core/admission.py) — the budget is capped at the pool's
        actually-free slots: work held back here is precisely the queue
        pressure that buys new providers, late binding hands it to arriving
        capacity instead of burying a busy provider's internal queue, and
        queued batch backfill stays HERE, preemptible by an interactive
        lane, rather than becoming un-reorderable manager-queue depth."""
        if self.broker.autoscaler is not None or self.broker.admission is not None:
            budget = min(self.max_batch, self.broker.idle_slots())
            if budget <= 0:
                # the ledger reads zero, but a breaker whose reset window
                # elapsed is only *probeable* — it re-enters the counted
                # supply when a dispatch triggers its OPEN -> HALF_OPEN
                # transition.  Peek time-aware capacity (cold path) so a
                # fully-tripped fleet at pool max still gets its probe.
                budget = min(self.max_batch, self.broker.probe_slots())
            if budget <= 0:
                return []
        else:
            budget = min(self.max_batch, max(self.broker.idle_slots(), self.min_batch))
        batch: list[Task] = []
        stale: list[Task] = []
        with self._lock:
            if len(self._lanes) == 1:
                # the no-tenant-config fast path: one lane == the old flat
                # heap, no apportionment arithmetic on the exp9 hot path
                self._pop_lane(next(iter(self._lanes)), budget, batch, stale)
            else:
                remaining = budget
                for slo_class in SLO_CLASSES:
                    if remaining <= 0:
                        break
                    keys = sorted(k for k in self._lanes if k[0] == slo_class)
                    if not keys:
                        continue
                    if len(keys) == 1:
                        remaining -= self._pop_lane(keys[0], remaining, batch, stale)
                        continue
                    demands = [len(self._lanes[k]) for k in keys]
                    weights = [self._tenant_weight(k[1]) for k in keys]
                    carry = [self._lane_carry.get(k, 0.0) for k in keys]
                    grants, new_carry = apportion_budget(
                        remaining, demands, weights, carry
                    )
                    for k, g, c in zip(keys, grants, new_carry):
                        self._lane_carry[k] = c  # _pop_lane drops it if emptied
                        remaining -= self._pop_lane(k, g, batch, stale)
        for t in stale:
            # a canceled task may still hold a staging-gate reservation:
            # dropping it without unbinding would leak policy load accounting
            # for the reserved provider forever (released outside the lock —
            # policy locks nest under the dispatcher's, never the reverse)
            self._release_reservation(t)
        return self._stage_gate(batch)

    def _pop_lane(
        self, key: tuple[str, str], k: int, batch: list[Task], stale: list[Task]
    ) -> int:
        """Pop up to ``k`` tasks from one lane, shallow-first (callers hold
        self._lock).  Returns the number popped (stale/canceled tasks count
        against the grant: their slot was budgeted this round either way)."""
        heap = self._lanes.get(key)
        popped = 0
        while heap and popped < k:
            _, _, t = heapq.heappop(heap)
            self._queued.discard(t.uid)
            self._npending -= 1
            self._class_pending[key[0]] -= 1
            popped += 1
            (stale if t.final else batch).append(t)
        if heap is not None and not heap:
            del self._lanes[key]
            self._lane_carry.pop(key, None)  # an empty lane banks no deficit
        return popped

    def _tenant_weight(self, tenant: str) -> float:
        admission = self.broker.admission
        return admission.weight(tenant) if admission is not None else 1.0

    # -- the staging gate (core/staging.py) ------------------------------
    def _stage_gate(self, batch: list[Task]) -> list[Task]:
        """Stage-in insertion point: a task whose declared inputs are missing
        at its placement site is parked while its transfers fly, and ONLY
        that task — the rest of the batch dispatches now, so transfers
        overlap with other tasks' compute.

        Placement is decided HERE, via the binding policy (a stateful
        reservation the later ``bind_bulk`` honors): staging to a predicted
        site and then binding elsewhere would ship bytes to the wrong
        platform.  Replica-resident tasks pay nothing and flow straight
        through; the data-gravity policy makes that the common case."""
        staging = getattr(self.broker, "staging", None)
        if staging is None or not any(t.inputs for t in batch):
            return batch
        with self.broker.policy.bulk_scope():
            return self._stage_gate_scoped(batch, staging)

    def _stage_gate_scoped(self, batch: list[Task], staging) -> list[Task]:
        # inside policy.bulk_scope(): every gate bind in this pass shares one
        # staging cost map per (inputs-signature, targets) — a batch of tasks
        # reading the same shard set prices its placements once (§Perf exp9)
        ready: list[Task] = []
        targets = None
        for t in batch:
            if not t.inputs:
                ready.append(t)
                continue
            if t.ckpt_dataset is not None and t.trace.last("resume_gated") is None:
                # first gate pass after a checkpoint resume: placement below
                # stages ckpt:<uid> to whatever surviving site the policy picks
                t.trace.add("resume_gated")
                with self._lock:
                    self.resume_gated += 1
            if targets is None:
                targets = self.broker.proxy.bind_targets()
            name = t.reserved_provider
            if name is not None and not any(p.name == name for p in targets):
                # the reserved target died (its replicas with it): release
                # the reservation and re-bind, instead of letting bind_bulk
                # silently re-choose a site the inputs never reached
                self._release_reservation(t)
                t.trace.add(f"regate:{name}")
                name = None
            if name is None:
                if not targets:
                    ready.append(t)  # full outage: the retry path owns it
                    continue
                try:
                    name = self.broker.policy.bind(t, targets)
                except NoEligibleProvider:
                    ready.append(t)  # surfaced by the dispatch error path
                    continue
                t.reserved_provider = name
            # an existing reservation with inputs missing at its site is
            # staged (again) to that SAME target: covers eviction between
            # staging and dispatch, and external reservers (speculation)
            # that want placement pinned away from a straggling provider.
            # Nothing staging-side may unwind into the dispatch loop: an
            # exception here would silently drop the whole popped batch.
            try:
                missing = staging.missing(t.inputs, name)
                if not missing:
                    staging.note_local(t.inputs, name)
                    ready.append(t)  # replica hit: free read, dispatch now
                    continue
                with self._lock:
                    self._park_locked(t)
                gen = t.staging_attempts  # pins callbacks to THIS round
                staging.stage_task(
                    t, name, lambda ok, t=t, g=gen: self._staged(t, ok, g)
                )
            except Exception:
                self.trace.add("stage_gate_error")
                with self._lock:  # the failure path assumes blocked membership
                    self._park_locked(t)
                self._staged(t, False, t.staging_attempts)
        return ready

    def _park_locked(self, t: Task) -> None:
        # callers hold self._lock.  A re-park of an already-parked task (the
        # gate's exception path) keeps the ORIGINAL stamp: the task has been
        # waiting since then, and deferred_demand should decay it as such.
        if t.uid not in self._blocked:
            self._blocked[t.uid] = t
            self._blocked_at[t.uid] = get_clock().now()

    def _unpark_locked(self, uid: str) -> None:
        self._blocked.pop(uid, None)
        self._blocked_at.pop(uid, None)

    def _staged(self, t: Task, ok: bool, gen: int) -> None:
        """Stage-in barrier resolved (may run on a clock thread).  ``gen``
        is the task's staging_attempts when this round's barrier was armed:
        a leftover waiter from a superseded round (e.g. a transfer that was
        still flying when the gate's exception path already failed and
        re-gated the task) must not act on the task's CURRENT round —
        every failure bumps staging_attempts, invalidating older gens."""
        if t.staging_attempts != gen:
            return  # stale callback from a superseded staging round
        if t.final:  # canceled while its bytes were in flight
            with self._lock:
                self._unpark_locked(t.uid)
            self._release_reservation(t)
            return
        if ok:
            # enqueue BEFORE leaving _blocked: in the opposite order the
            # loop could observe heap-empty + blocked-empty in the gap and
            # flash _idle (drain()/autoscaler demand would misread it)
            self.enqueue([t])  # reservation rides along to bind_bulk
            with self._lock:
                self._unpark_locked(t.uid)
            return
        # transfer failed (site died / dataset lost / input never declared):
        # release the gate's reservation and re-gate against the surviving
        # topology after a short backoff, so an instantly-failing stage
        # (unknown dataset) cannot burn every attempt in microseconds.  The
        # backoff must NOT block this thread (_staged runs on the virtual
        # clock's advancer thread or inline under the gate's clock.hold()),
        # and it is REAL time by design: a virtual deadline might never be
        # served on a manually-driven or closing clock.  The task stays in
        # _blocked until the re-enqueue, so drain()/stalled counts never see
        # a phantom idle window mid-retry.
        self._release_reservation(t)
        t.staging_attempts += 1
        if t.staging_attempts > self.max_staging_attempts or self._stop.is_set():
            # out of attempts — or the dispatcher is shutting down, where a
            # retry would enqueue into a loop that will never pop it and
            # leave the future unresolved forever
            with self._lock:
                self._unpark_locked(t.uid)
            self._fail_task(
                t, StagingError(f"task {t.uid}: staging failed for {t.inputs}")
            )
            return

        self._schedule_requeue(t)

    def _schedule_requeue(self, t: Task, delay_s: float = 0.01) -> None:
        """Re-gate ``t`` after a short REAL-time backoff, through a timer
        the dispatcher owns: the registry entry is claimed exactly once —
        by the firing timer or by stop()'s sweep — so a shutdown racing the
        backoff either cancels the requeue cleanly (failing the task, whose
        future must not dangle) or lets it land in a still-live loop."""

        def _requeue() -> None:
            with self._timer_lock:
                claimed = self._retry_timers.pop(timer, None)
            if claimed is None:
                return  # stop() swept this timer: it owns the task's fate
            if self._stop.is_set():
                with self._lock:
                    self._unpark_locked(t.uid)
                self._fail_task(
                    t, StagingError(f"task {t.uid}: dispatcher stopped during staging retry")
                )
                return
            # enqueue BEFORE leaving _blocked (same idle-flash ordering as
            # the staging success path)
            self.enqueue([t])
            with self._lock:
                self._unpark_locked(t.uid)
            if self._stop.is_set() and not t.done():
                # stop() raced past our registry claim (we popped ourselves
                # before its sweep, then it set _stop): the loop may already
                # have exited without popping this enqueue — resolve the
                # future rather than strand it
                self._fail_task(
                    t, StagingError(f"task {t.uid}: dispatcher stopped during staging retry")
                )

        timer = threading.Timer(delay_s, _requeue)
        timer.daemon = True
        with self._timer_lock:
            self._retry_timers[timer] = t
        timer.start()

    def _release_reservation(self, t: Task) -> None:
        if t.reserved_provider is not None:
            self.broker.policy.unbind(t, t.reserved_provider)
            t.reserved_provider = None

    def stalled_on_staging(self) -> int:
        with self._lock:
            return len(self._blocked)

    def stalled_in_backlog(self) -> int:
        """Staging-blocked tasks the broker's backlog() scan ALSO counts
        (re-gated retries from already-dispatched submissions): exactly the
        overlap the autoscaler must subtract so tasks stalled purely on
        staging never read as unmet demand."""
        with self._lock:
            return sum(1 for t in self._blocked.values() if t.in_submission)

    def _dispatch(self, batch: list[Task]) -> None:
        batch_id = _batch_ids.next()
        try:
            sub = self.broker.submit(
                batch,
                partitioning=self.broker.partitioning,
                tasks_per_pod=self.broker.tasks_per_pod,
                batch_id=batch_id,
            )
        except NoEligibleProvider:
            # late binding found an unplaceable task (bind_bulk validates
            # eligibility before any stateful binding, so no load accounting
            # leaked): fail only the offenders, stream the rest through
            placeable = []
            deferred = False
            targets = self.broker.proxy.bind_targets()
            if not targets:  # raced into a full outage: transient, not fatal
                self._retry(batch)
                return
            for t in batch:
                try:
                    self.broker.policy._eligible(t, targets)
                    placeable.append(t)
                except NoEligibleProvider as exc:
                    if self.broker.incoming_could_fit(t):
                        # capacity that can actually RUN this task is
                        # mid-acquisition (core/autoscaler.py): keep it
                        # queued instead of terminally failing it
                        placeable.append(t)
                        deferred = True
                    else:
                        self._fail_task(t, exc)  # surface the typed error
            self.retry_backoffs += 1
            self.broker.events.emit("dispatch.retry")
            if placeable:
                self.enqueue(placeable)
            if deferred:
                self._stop.wait(0.01)  # don't hot-spin while capacity boots
            return
        except Exception as exc:
            self._retry(batch, exc)
            return
        self.batches += 1
        self.tasks_dispatched += len(batch)
        # one event per BATCH, not per task: the log costs O(batches) on the
        # exp9/exp11 hot path while the view still derives the task total
        self.broker.events.emit("dispatch.batch", n=len(batch))
        self._consecutive_failures = 0
        self.trace.add(f"batch:{batch_id}:{len(batch)}:{len(sub.pods)}")

    def _retry(self, batch: list[Task], exc: Optional[BaseException] = None) -> None:
        """Transient dispatch failure (e.g. every provider momentarily
        unhealthy): requeue what is safe to re-bind, back off briefly.
        Tasks the failed round already handed to a provider (SUBMITTED /
        RUNNING) are NOT requeued — they either finish there or re-enter
        through the broker's fault machinery."""
        self.retry_backoffs += 1
        self.broker.events.emit("dispatch.retry")
        self._consecutive_failures += 1
        self.trace.add("dispatch_retry")
        # pipeline aborts before dispatch release the whole batch's load
        # accounting broker-side (exc carries the marker); only a failure
        # AFTER dispatch started leaves bound-but-undelivered tasks to us
        released = exc is not None and getattr(exc, "_hydra_load_released", False)
        requeueable = []
        for t in batch:
            if t.final or t.tstate not in (TaskState.NEW, TaskState.BOUND, TaskState.PARTITIONED):
                continue
            if not released and t.tstate != TaskState.NEW:
                # bound in the failed round but never reached a provider:
                # release the policy's load accounting before re-binding
                self.broker.policy.unbind(t)
            requeueable.append(t)
        if (
            self._consecutive_failures > self.max_consecutive_failures
            and exc is not None
            and self.broker.incoming_slots() == 0
        ):
            # a persistent outage (counter resets on any success): surface
            # instead of spinning forever — unless replacement capacity is
            # already mid-acquisition, in which case the outage is ending
            for t in requeueable:
                self._fail_task(t, exc)
            return
        self.enqueue(requeueable)
        self._stop.wait(0.01)

    def _fail_task(self, t: Task, exc: BaseException) -> None:
        """Terminal failure: move tstate to a final state FIRST (workflow
        completion checks ``all(t.final)``), then resolve the future."""
        self._release_reservation(t)
        t.try_advance(TaskState.CANCELED)
        try:
            if not t.done():
                t.set_exception(exc)
        except Exception:  # raced with a concurrent resolution: already final
            pass

    # -- metrics ---------------------------------------------------------
    def _finite_pressure(self) -> Optional[float]:
        """queue_pressure() for JSON consumers: the zero-supply ``inf``
        sentinel becomes None (no finite pressure is honest there)."""
        p = self.queue_pressure()
        return round(p, 3) if math.isfinite(p) else None

    def stats(self) -> dict:
        """Dict-shaped adapter over the broker's event log: the dispatch
        counters are the log-derived view (core/events.py), folded from
        dispatch.batch/retry/loop_error events emitted adjacent to the
        legacy accumulators (which stay as HYDRA_EVENTS_CHECK ground
        truth).  Queue depths and pressure are live gauges."""
        view = self.broker.events.view
        batches = int(view.get("hydra.dispatch.batches"))
        tasks = int(view.get("hydra.dispatch.tasks"))
        return {
            "batches": batches,
            "tasks_dispatched": tasks,
            "mean_batch_size": round(tasks / max(batches, 1), 2),
            "pending": self.pending(),
            "pending_by_class": self.pending_by_class(),
            "lanes": len(self._lanes),
            "staging_blocked": self.stalled_on_staging(),
            "resume_gated": self.resume_gated,
            "queue_pressure": self._finite_pressure(),
            "incoming_slots": self.broker.incoming_slots(),
            "retry_backoffs": int(view.get("hydra.dispatch.retry_backoffs")),
            "loop_errors": int(view.get("hydra.dispatch.loop_errors")),
            "batch_window_s": self.batch_window,
            "max_batch": self.max_batch,
        }
