"""CapacityLedger: O(1) broker-side capacity counters (§Perf, scheduler core).

The paper's headline claim is near-constant broker overhead as tasks and
platforms scale (§5.4, §6).  Before this module the broker recomputed its
supply/demand picture by *scanning*: ``idle_slots()``/``total_slots()``
walked every bind target per micro-batch, and ``backlog()`` re-counted every
task of every live submission per autoscaler tick (behind a 50 ms cache) —
so dispatch cost grew with tasks x providers, the opposite of the paper's
result.

The ledger inverts that: a small counter set updated O(1) on the events that
actually change capacity, read O(1) by the dispatcher/autoscaler hot paths:

  event                                   counters touched
  -----------------------------------     -------------------------------
  provider register / deregister          total, idle
  provider blacklist (outage)             total, idle, outstanding
  group member join / leave               total, idle
  member breaker transition (fault.py)    total, idle  (counted flag)
  task dispatch / finish / skip           idle          (outstanding)
  acquisition begin / complete / abort    incoming
  task enters a submission                backlog
  task future resolves                    backlog

One row per *concrete* provider (direct or group member).  A row is
``counted`` — contributing to supply — while its health signal says traffic
may flow: ``handle.healthy`` for direct providers, ``breaker.state != OPEN``
for group members (the breaker's timed OPEN -> HALF_OPEN reopening is an
*event* too: it happens inside ``allow()``, never by mere passage of time,
which is what makes supply exactly event-countable).

Backlog counts *distinct unresolved tasks that have entered a submission*:
resolution (the task future settling) is the O(1) observable completion
event.  A retry-pending FAILED task therefore stays in the backlog until it
finally resolves — it is still owed work — where the old scan dropped and
re-added it around each retry.

Honesty harness: with ``strict`` enabled (``HYDRA_LEDGER_CHECK=1``;
tests/conftest.py turns it on for the whole tier-1 suite) every read
cross-checks the counters against a from-scratch recompute supplied by the
broker.  Because events land a few instructions apart from the state they
mirror, a strict check retries briefly before declaring divergence: a *race*
heals within microseconds, a *leak* never does.  Divergence raises
``LedgerDivergence`` and is re-raised from ``Hydra.shutdown()`` so a
swallowed hot-loop check still fails the suite.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional


class LedgerDivergence(AssertionError):
    """The O(1) counters disagree with a from-scratch recompute: an event
    source is missing or double-firing.  Always a broker bug."""


@dataclass
class _Row:
    slots: int
    outstanding: int = 0
    counted: bool = True

    @property
    def idle(self) -> int:
        return max(0, self.slots - self.outstanding) if self.counted else 0

    @property
    def total(self) -> int:
        return self.slots if self.counted else 0


class CapacityLedger:
    """Event-maintained capacity counters.  All mutators are O(1); all reads
    are O(1) (plus the strict-mode cross-check, which is O(state) and only
    enabled under tests)."""

    def __init__(self, strict: bool = False):
        self._lock = threading.Lock()
        self._rows: dict[str, _Row] = {}
        self._incoming: dict[str, int] = {}  # pending acquisition -> slots
        self._idle = 0
        self._total = 0
        self._incoming_slots = 0
        self._backlog = 0
        self.strict = strict
        self.divergences = 0
        self.last_divergence: Optional[str] = None
        self._recompute: Optional[Callable[[], dict]] = None
        self._on_capacity_gain: Optional[Callable[[], None]] = None

    def attach(
        self,
        recompute: Optional[Callable[[], dict]] = None,
        on_capacity_gain: Optional[Callable[[], None]] = None,
    ) -> None:
        """``recompute`` rebuilds the counter set from scratch (the strict
        cross-check's ground truth); ``on_capacity_gain`` fires — outside the
        ledger lock — whenever idle supply grows, so the dispatcher can wake
        on completions/arrivals instead of polling on a real-time timeout."""
        self._recompute = recompute
        self._on_capacity_gain = on_capacity_gain

    # -- event mutators (all O(1)) --------------------------------------
    def _apply(self, fn) -> None:
        """Run ``fn`` under the lock; fire the capacity-gain callback after
        releasing it when idle supply grew."""
        with self._lock:
            before = self._idle + self._incoming_slots
            fn()
            gained = (self._idle + self._incoming_slots) > before
        if gained and self._on_capacity_gain is not None:
            self._on_capacity_gain()

    def _set_row(self, name: str, row: Optional[_Row]) -> None:
        # callers hold self._lock
        old = self._rows.pop(name, None)
        if old is not None:
            self._idle -= old.idle
            self._total -= old.total
        if row is not None:
            self._rows[name] = row
            self._idle += row.idle
            self._total += row.total

    def upsert_direct(self, name: str, slots: int) -> None:
        """An ungrouped provider registered (or re-registered)."""
        self._apply(lambda: self._set_row(name, _Row(slots=max(1, slots))))

    def upsert_member(self, name: str, slots: int, counted: bool = True) -> None:
        """A provider became (or joined as) a group member: its row restarts
        with the group's per-member load accounting (outstanding = 0)."""
        self._apply(
            lambda: self._set_row(name, _Row(slots=max(1, slots), counted=counted))
        )

    def remove(self, name: str) -> None:
        """Provider/member deregistered: its supply is gone.  Idempotent —
        removal paths (outage, scale-in, rollback) may overlap."""
        self._apply(lambda: self._set_row(name, None))

    def deactivate(self, name: str) -> None:
        """Blacklist/outage: the row stays (the name is still registered)
        but contributes nothing, and a dead provider owes no dispatchable
        work (outstanding resets with it)."""

        def _do():
            row = self._rows.get(name)
            if row is None:
                return
            self._idle -= row.idle
            self._total -= row.total
            row.counted = False
            row.outstanding = 0

        self._apply(_do)

    def set_counted(self, name: str, counted: bool) -> None:
        """Breaker transition (group member health): slots enter/leave the
        supply side.  Fired by the member's CircuitBreaker ``on_transition``
        hook, so the timed OPEN -> HALF_OPEN reopening is still an event."""

        def _do():
            row = self._rows.get(name)
            if row is None or row.counted == counted:
                return
            self._idle -= row.idle
            self._total -= row.total
            row.counted = counted
            self._idle += row.idle
            self._total += row.total

        self._apply(_do)

    def load_delta(self, name: str, delta: int) -> None:
        """Outstanding-task accounting (dispatch +n / completion -1), with
        the same clamp-at-zero the broker and groups apply.  The hottest
        event (twice per task): hand-inlined, no closure."""
        cb = None
        with self._lock:
            row = self._rows.get(name)
            if row is None:
                return
            before = row.idle
            row.outstanding = max(0, row.outstanding + delta)
            gained = row.idle - before
            self._idle += gained
            if gained > 0:
                cb = self._on_capacity_gain
        if cb is not None:
            cb()

    def load_reset(self, name: str) -> None:
        """A downed member's orphans are being reassigned: it owes nothing."""

        def _do():
            row = self._rows.get(name)
            if row is None:
                return
            self._idle -= row.idle
            row.outstanding = 0
            self._idle += row.idle

        self._apply(_do)

    def begin_incoming(self, name: str, slots: int) -> None:
        def _do():
            old = self._incoming.pop(name, 0)
            self._incoming[name] = max(1, slots)
            self._incoming_slots += max(1, slots) - old

        self._apply(_do)

    def end_incoming(self, name: str) -> None:
        """Acquisition completed or aborted.  Idempotent."""

        def _do():
            self._incoming_slots -= self._incoming.pop(name, 0)

        self._apply(_do)

    def task_entered(self, n: int = 1) -> None:
        with self._lock:
            self._backlog += n

    def task_resolved(self, n: int = 1) -> None:
        with self._lock:
            self._backlog = max(0, self._backlog - n)

    # -- O(1) reads ------------------------------------------------------
    def idle_slots(self) -> int:
        self._maybe_check()
        with self._lock:
            return self._idle

    def total_slots(self) -> int:
        self._maybe_check()
        with self._lock:
            return self._total

    def incoming_slots(self) -> int:
        self._maybe_check()
        with self._lock:
            return self._incoming_slots

    def backlog(self) -> int:
        self._maybe_check()
        with self._lock:
            return self._backlog

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "idle_slots": self._idle,
                "total_slots": self._total,
                "incoming_slots": self._incoming_slots,
                "backlog": self._backlog,
            }

    def stats(self) -> dict:
        out = self.snapshot()
        out["strict"] = self.strict
        out["divergences"] = self.divergences
        return out

    # -- the honesty harness ---------------------------------------------
    def _maybe_check(self) -> None:
        if self.strict and self._recompute is not None:
            self.check()

    def check(self, retries: int = 30, retry_sleep_s: float = 0.002) -> None:
        """Cross-check counters against a from-scratch recompute.

        Events land a few instructions after the state they mirror (a
        completion decrements the group's member counter, then the ledger),
        so a transient mismatch under concurrency is expected and heals in
        microseconds; only a *persistent* mismatch — a leaked or double
        event — is divergence.  The recompute runs OUTSIDE the ledger lock:
        it takes broker/proxy/group locks, and taking those under the ledger
        lock would invert the broker -> ledger lock order."""
        last = None
        for _ in range(max(1, retries)):
            expect = self._recompute()
            got = self.snapshot()
            diffs = {
                k: {"ledger": got[k], "recomputed": expect[k]}
                for k in expect
                if got[k] != expect[k]
            }
            if not diffs:
                return
            last = diffs
            time.sleep(retry_sleep_s)
        self.divergences += 1
        self.last_divergence = repr(last)
        raise LedgerDivergence(f"capacity ledger diverged from recompute: {last}")
