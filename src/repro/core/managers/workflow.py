"""Workflow Manager — the Argo-connector analogue (paper §5.4).

Hydra itself brokers *workloads* (independent tasks); workflows need a DAG
engine on top.  In the paper that engine is Argo on Kubernetes and
RADICAL-EnTK on HPC; here it is a small dependency-driven submitter that
pushes ready tasks through the broker as their dependencies complete.  Like
Argo under Hydra, it adds no broker-side overhead: each ready frontier is a
normal broker submission.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.task import Task, TaskState
from repro.runtime.tracing import Trace


class Workflow:
    """A DAG of tasks.  add(task, deps=[...]) wires edges."""

    _n = 0

    def __init__(self, name: str = ""):
        Workflow._n += 1
        self.name = name or f"wf.{Workflow._n:05d}"
        self.tasks: list[Task] = []
        self.deps: dict[str, set[str]] = {}
        self.children: dict[str, list[str]] = {}
        self.trace = Trace()

    def add(self, task: Task, deps: Optional[list[Task]] = None) -> Task:
        self.tasks.append(task)
        dep_uids = {d.uid for d in (deps or [])}
        self.deps[task.uid] = set(dep_uids)
        for d in dep_uids:
            self.children.setdefault(d, []).append(task.uid)
        return task

    @property
    def done(self) -> bool:
        return all(t.final for t in self.tasks)

    @property
    def failed(self) -> bool:
        return any(t.tstate == TaskState.FAILED and t.retries >= t.max_retries for t in self.tasks)

    def makespan(self) -> Optional[float]:
        t0 = self.trace.first("started")
        t1 = self.trace.last("finished")
        return None if t0 is None or t1 is None else t1 - t0


class WorkflowManager:
    def __init__(self, broker, partitioning: str = "mcpp", tasks_per_pod: int = 64):
        self.broker = broker
        self.partitioning = partitioning
        self.tasks_per_pod = tasks_per_pod
        self._lock = threading.Lock()

    def run(self, workflows: list[Workflow], wait: bool = True) -> list[Workflow]:
        """Run many workflow instances concurrently (paper Exp 4: up to 800)."""
        by_uid: dict[str, tuple[Workflow, Task]] = {}
        remaining: dict[str, set[str]] = {}
        done_events = {wf.name: threading.Event() for wf in workflows}

        for wf in workflows:
            wf.trace.add("started")
            for t in wf.tasks:
                by_uid[t.uid] = (wf, t)
                remaining[t.uid] = set(wf.deps[t.uid])

        def on_done(fut_task: Task):
            def cb(fut):
                wf, _ = by_uid[fut_task.uid]
                if fut.cancelled() or fut.exception() is not None:
                    # cancel downstream; the workflow is failed
                    self._cancel_downstream(wf, fut_task)
                    if wf.done:
                        wf.trace.add("finished")
                        done_events[wf.name].set()
                    return
                ready = []
                with self._lock:
                    for child_uid in wf.children.get(fut_task.uid, []):
                        remaining[child_uid].discard(fut_task.uid)
                        if not remaining[child_uid]:
                            ready.append(by_uid[child_uid][1])
                if ready:
                    self._submit(ready)
                if wf.done:
                    wf.trace.add("finished")
                    done_events[wf.name].set()

            return cb

        for uid, (wf, t) in by_uid.items():
            t.add_done_callback(on_done(t))

        # submit the initial frontier of every workflow in ONE bulk submission
        frontier = [t for uid, (wf, t) in by_uid.items() if not remaining[uid]]
        if frontier:
            self._submit(frontier)

        if wait:
            for wf in workflows:
                done_events[wf.name].wait()
        return workflows

    def _submit(self, tasks: list[Task]):
        self.broker.submit(tasks, partitioning=self.partitioning, tasks_per_pod=self.tasks_per_pod)

    def _cancel_downstream(self, wf: Workflow, failed: Task):
        stack = list(wf.children.get(failed.uid, []))
        seen = set()
        while stack:
            uid = stack.pop()
            if uid in seen:
                continue
            seen.add(uid)
            for t in wf.tasks:
                if t.uid == uid and not t.final:
                    t.mark_canceled()
            stack.extend(wf.children.get(uid, []))
