"""Workflow Manager — the Argo-connector analogue (paper §5.4).

Hydra itself brokers *workloads* (independent tasks); workflows need a DAG
engine on top.  In the paper that engine is Argo on Kubernetes and
RADICAL-EnTK on HPC; here it is a dependency tracker with two dispatch
modes:

  frontier  - every readiness event becomes its own ``broker.submit()``
              (the faithful baseline: per-micro-frontier pipeline rounds,
              often single-task pods).
  streaming - readiness events are fed to the broker's long-lived
              StreamingDispatcher (core/dispatcher.py), which coalesces
              ready tasks across ALL running workflow instances into
              micro-batched, late-bound pods and backfills idle capacity
              with deeper-workflow tasks.

The mode follows ``broker.streaming`` unless overridden, so
``Hydra(streaming=True)`` is all a caller needs to change.

DAGs are validated before execution: a cyclic workflow used to deadlock the
run loop forever (no task ever became ready); now ``Workflow.add`` rejects
edges that close a cycle and ``WorkflowManager.run`` re-validates every
instance, raising ``ValueError`` naming the offending cycle.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.core.task import Task, TaskState
from repro.runtime.clock import guard_wait, now
from repro.runtime.tracing import Trace


class Workflow:
    """A DAG of tasks.  add(task, deps=[...]) wires edges."""

    _n = 0

    def __init__(self, name: str = ""):
        Workflow._n += 1
        self.name = name or f"wf.{Workflow._n:05d}"
        self.tasks: list[Task] = []
        self.deps: dict[str, set[str]] = {}
        self.children: dict[str, list[str]] = {}
        self.trace = Trace()

    def add(self, task: Task, deps: Optional[list[Task]] = None) -> Task:
        if task.uid in self.deps:
            raise ValueError(f"{self.name}: task {task.uid} already added")
        dep_uids = {d.uid for d in (deps or [])}
        if task.uid in dep_uids:
            raise ValueError(f"{self.name}: cycle: {task.uid} -> {task.uid}")
        # forward deps may reference tasks added later; an edge dep -> task
        # closes a cycle iff task already reaches dep through children
        path = self._path_to(task.uid, dep_uids)
        if path is not None:
            raise ValueError(f"{self.name}: cycle: {' -> '.join(path + [path[0]])}")
        self.tasks.append(task)
        self.deps[task.uid] = set(dep_uids)
        for d in dep_uids:
            self.children.setdefault(d, []).append(task.uid)
        return task

    def _path_to(self, src: str, targets: set[str]) -> Optional[list[str]]:
        """DFS over children edges: a path src -> ... -> t in targets."""
        stack: list[tuple[str, list[str]]] = [(src, [src])]
        seen: set[str] = set()
        while stack:
            uid, path = stack.pop()
            if uid in targets:
                return path
            if uid in seen:
                continue
            seen.add(uid)
            for child in self.children.get(uid, []):
                stack.append((child, path + [child]))
        return None

    def find_cycle(self) -> Optional[list[str]]:
        """Full-graph validation (run-time guard): a cycle as a uid list,
        or None for a well-formed DAG."""
        WHITE, GREY, BLACK = 0, 1, 2
        color = {t.uid: WHITE for t in self.tasks}
        parent: dict[str, Optional[str]] = {}
        for root in color:
            if color[root] != WHITE:
                continue
            stack: list[tuple[str, bool]] = [(root, False)]
            parent[root] = None
            while stack:
                uid, done = stack.pop()
                if done:
                    color[uid] = BLACK
                    continue
                if color[uid] == BLACK:
                    continue
                color[uid] = GREY
                stack.append((uid, True))
                for child in self.children.get(uid, []):
                    if child not in color:
                        continue  # dep object never added: dangling, not cyclic
                    if color[child] == GREY:  # back edge: reconstruct
                        cycle, cur = [child], uid
                        while cur is not None and cur != child:
                            cycle.append(cur)
                            cur = parent.get(cur)
                        cycle.reverse()
                        return cycle
                    if color[child] == WHITE:
                        parent[child] = uid
                        stack.append((child, False))
        return None

    def depths(self) -> dict[str, int]:
        """Longest-path depth per task (roots = 0), topologically computed.
        Feeds the dispatcher's shallow-first backfill ordering."""
        indeg = {t.uid: len(self.deps.get(t.uid, ())) for t in self.tasks}
        depth = {uid: 0 for uid in indeg}
        frontier = [uid for uid, d in indeg.items() if d == 0]
        while frontier:
            uid = frontier.pop()
            for child in self.children.get(uid, []):
                if child not in indeg:
                    continue
                depth[child] = max(depth[child], depth[uid] + 1)
                indeg[child] -= 1
                if indeg[child] == 0:
                    frontier.append(child)
        return depth

    def find_dangling(self) -> Optional[tuple[str, str]]:
        """A (task_uid, dep_uid) pair whose dep was never add()ed: such a
        dep can never complete, so the task would never become ready and
        the run loop would wait forever."""
        known = {t.uid for t in self.tasks}
        for uid, deps in self.deps.items():
            for d in deps:
                if d not in known:
                    return (uid, d)
        return None

    @property
    def done(self) -> bool:
        return all(t.final for t in self.tasks)

    @property
    def failed(self) -> bool:
        for t in self.tasks:
            if t.tstate == TaskState.FAILED and t.retries >= t.max_retries:
                return True
            # dispatcher-surfaced errors (unplaceable task, persistent
            # outage) land in CANCELED with the error on the future: an
            # errored run must not read as a clean success
            if (
                t.tstate == TaskState.CANCELED
                and t.done()
                and not t.cancelled()
                and t.exception() is not None
            ):
                return True
        return False

    def makespan(self) -> Optional[float]:
        t0 = self.trace.first("started")
        t1 = self.trace.last("finished")
        return None if t0 is None or t1 is None else t1 - t0


class WorkflowManager:
    def __init__(
        self,
        broker,
        partitioning: Optional[str] = None,
        tasks_per_pod: Optional[int] = None,
        streaming: Optional[bool] = None,
    ):
        self.broker = broker
        # None = follow the broker's configuration.  In streaming mode pod
        # shaping belongs to the broker's dispatcher (batches span many
        # workflows), so an explicit per-manager override that disagrees
        # with the broker is rejected in run() instead of silently dropped.
        self._partitioning = partitioning
        self._tasks_per_pod = tasks_per_pod
        # None = follow the broker's mode (Hydra(streaming=True) is enough)
        self._streaming = streaming
        self._lock = threading.Lock()

    @property
    def partitioning(self) -> str:
        return self._partitioning or self.broker.partitioning

    @property
    def tasks_per_pod(self) -> int:
        return self._tasks_per_pod or self.broker.tasks_per_pod

    @property
    def streaming(self) -> bool:
        if self._streaming is not None:
            return self._streaming
        return bool(getattr(self.broker, "streaming", False))

    def _check_streaming_config(self) -> None:
        if not self.streaming:
            return
        if (self._partitioning is not None and self._partitioning != self.broker.partitioning) or (
            self._tasks_per_pod is not None and self._tasks_per_pod != self.broker.tasks_per_pod
        ):
            raise ValueError(
                "streaming mode: pod shaping is owned by the broker's dispatcher "
                "(batches span workflows); configure partitioning/tasks_per_pod "
                "on Hydra(...) instead of WorkflowManager"
            )

    def run(
        self,
        workflows: list[Workflow],
        wait: bool = True,
        timeout: Optional[float] = None,
    ) -> list[Workflow]:
        """Run many workflow instances concurrently (paper Exp 4: up to 800).

        Validates every DAG first (ValueError on cycles), then tracks
        dependencies and pushes readiness events either straight through
        ``broker.submit`` (frontier mode) or into the streaming dispatcher's
        ready-queue (streaming mode)."""
        self._check_streaming_config()
        by_uid: dict[str, tuple[Workflow, Task]] = {}
        remaining: dict[str, set[str]] = {}
        done_events = {wf.name: threading.Event() for wf in workflows}

        for wf in workflows:
            cycle = wf.find_cycle()
            if cycle is not None:
                raise ValueError(
                    f"{wf.name}: cycle: {' -> '.join(cycle + [cycle[0]])}"
                )
            dangling = wf.find_dangling()
            if dangling is not None:
                raise ValueError(
                    f"{wf.name}: task {dangling[0]} depends on {dangling[1]}, "
                    "which was never added to the workflow"
                )

        for wf in workflows:
            wf.trace.add("started")
            depth = wf.depths()
            for t in wf.tasks:
                t.depth = depth.get(t.uid, 0)
                t.workflow = wf.name
                by_uid[t.uid] = (wf, t)
                remaining[t.uid] = set(wf.deps[t.uid])

        # multi-tenant front door: admit the WHOLE run up front, in one
        # all-or-nothing call.  Mid-DAG admission would reject inside a
        # future done-callback — where an AdmissionError has no caller to
        # propagate to and a half-run workflow no clean abort — so the
        # manager charges every task before the first frontier dispatch;
        # the per-frontier dispatch()/submit() admit gates then see
        # already-admitted tasks and pass them through unchanged.  Raises
        # AdmissionError here, before any callback is wired or task sent.
        admission = getattr(self.broker, "admission", None)
        if admission is not None:
            admission.admit([t for _, t in by_uid.values()])

        def on_done(fut_task: Task):
            def cb(fut):
                wf, _ = by_uid[fut_task.uid]
                if fut.cancelled() or fut.exception() is not None:
                    # cancel downstream; the workflow is failed
                    self._cancel_downstream(wf, fut_task)
                    if wf.done:
                        wf.trace.add("finished")
                        done_events[wf.name].set()
                    return
                ready = []
                with self._lock:
                    for child_uid in wf.children.get(fut_task.uid, []):
                        remaining[child_uid].discard(fut_task.uid)
                        if not remaining[child_uid]:
                            ready.append(by_uid[child_uid][1])
                if ready:
                    self._submit(ready)
                if wf.done:
                    wf.trace.add("finished")
                    done_events[wf.name].set()

            return cb

        for uid, (wf, t) in by_uid.items():
            t.add_done_callback(on_done(t))

        # feed the initial frontier of every workflow in ONE bulk push
        frontier = [t for uid, (wf, t) in by_uid.items() if not remaining[uid]]
        if frontier:
            self._submit(frontier)

        if wait:
            # guard timeout: ONE budget across all workflows, bounded on the
            # active clock AND real time — a frozen virtual clock must not
            # multiply the real-time bound by the number of workflows
            v_deadline = None if timeout is None else now() + timeout
            r_deadline = None if timeout is None else time.monotonic() + timeout

            def _in_flight() -> bool:
                # keeps guard_wait's virtual-idle valve closed while any
                # task is executing real (non-clock) work on a provider
                return any(
                    t.tstate
                    in (TaskState.PARTITIONED, TaskState.SUBMITTED, TaskState.RUNNING)
                    for _, t in by_uid.values()
                )

            for wf in workflows:
                left = (
                    None
                    if timeout is None
                    else max(0.0, min(v_deadline - now(), r_deadline - time.monotonic()))
                )
                guard_wait(done_events[wf.name], left, in_flight=_in_flight)
        return workflows

    def _submit(self, tasks: list[Task]):
        if self.streaming:
            self.broker.dispatch(tasks)
        else:
            self.broker.submit(tasks, partitioning=self.partitioning, tasks_per_pod=self.tasks_per_pod)

    def _cancel_downstream(self, wf: Workflow, failed: Task):
        stack = list(wf.children.get(failed.uid, []))
        seen = set()
        while stack:
            uid = stack.pop()
            if uid in seen:
                continue
            seen.add(uid)
            for t in wf.tasks:
                if t.uid == uid and not t.final:
                    t.mark_canceled()
            stack.extend(wf.children.get(uid, []))
