"""CaaS Manager (paper §3.1) adapted to TPU pools: the "container service" is
a compiled-artifact service.

  container image  == compiled XLA executable for (arch, shape, step kind,
                      strategy); building the image == lower+compile; the
                      image registry == the content-addressed compile cache.
  pod              == a dispatch group submitted to the pool in ONE bulk call
                      (the paper's bulk submission that keeps OVH low).

The manager traces env setup/teardown per pod (TPT per the paper) and task
exec windows (TTX), executes noop/sleep/callable tasks directly, and routes
``compute`` tasks through the CompiledArtifactCache onto the provider's
device slice.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Optional

from repro.core.pod import Pod
from repro.core.provider import ProviderHandle
from repro.core.task import Task, TaskState
from repro.runtime.clock import get_clock


class ProviderDown(RuntimeError):
    pass


class Preempted(RuntimeError):
    """A task was killed mid-execution by an external actor (spot reclaim,
    HPC walltime kill, chaos injection).  The killer calls
    ``task.mark_failed(Preempted(...))`` on a RUNNING task; the executing
    manager notices the FAILED state when the work function returns and
    reports the failure exactly once through the normal completion hook, so
    the broker's retry machinery owns the recovery."""


class CompiledArtifactCache:
    """Content-addressed cache of compiled step functions (the "image registry")."""

    def __init__(self):
        self._cache: dict[tuple, Any] = {}
        self._lock = threading.Lock()
        self.builds = 0
        self.hits = 0

    def get_or_build(self, key: tuple, build: Callable[[], Any]):
        with self._lock:
            if key in self._cache:
                self.hits += 1
                return self._cache[key]
        artifact = build()  # compile outside the lock; duplicate builds are benign
        with self._lock:
            if key not in self._cache:
                self._cache[key] = artifact
                self.builds += 1
            return self._cache[key]


# Shared across managers: images are provider-agnostic, like a registry.
ARTIFACTS = CompiledArtifactCache()


class ComputeRuntime:
    """Executes ``compute`` tasks: builds/fetches the compiled step and runs a
    reduced-config instance on the provider's devices (CPU container)."""

    def __init__(self):
        self._states: dict[tuple, Any] = {}
        self._lock = threading.Lock()

    def run(self, task: Task) -> Any:
        import jax

        from repro.configs import get_arch
        from repro.data.pipeline import DataConfig, batch_at
        from repro.models.model import Model
        from repro.optim import adamw
        from repro.train import step as step_lib
        from repro.parallel.sharding import STRATEGIES

        arch = get_arch(task.arch).reduced()
        step_kind = task.step_kind or "train"
        key = (task.arch, step_kind)

        def build():
            from repro.compat import compat_make_mesh

            model = Model(arch)
            mesh = compat_make_mesh((1,), ("data",))
            strategy = STRATEGIES["tp"]
            if step_kind == "train":
                fn = jax.jit(
                    step_lib.make_train_step(model, strategy, mesh, adamw.AdamWConfig())
                )
            elif step_kind == "prefill":
                fn = jax.jit(step_lib.make_prefill_step(model, strategy, mesh, cache_len=32))
            else:
                raise ValueError(step_kind)
            return model, fn

        model, fn = ARTIFACTS.get_or_build(key, build)
        dc = DataConfig(
            vocab_size=arch.vocab_size, seq_len=16, global_batch=2,
            enc_len=arch.enc_len_train, d_model=arch.d_model,
            n_img_tokens=arch.n_img_tokens, family=arch.family,
        )
        batch = batch_at(dc, task.retries)
        with self._lock:
            state = self._states.get(key)
            if state is None:
                import jax as _jax

                state = step_lib.init_train_state(model, _jax.random.key(0))
                self._states[key] = state
        if step_kind == "train":
            params, opt, metrics = fn(state[0], state[1], batch)
            with self._lock:
                self._states[key] = (params, opt)
            return {k: float(v) for k, v in metrics.items()}
        logits, _ = fn(state[0], {k: v for k, v in batch.items() if k != "labels"})
        return {"logits_shape": list(logits.shape)}


COMPUTE_RUNTIME = ComputeRuntime()


class KernelRuntime:
    """Executes ``kernel`` tasks: real Pallas work on the wire.

    ``task.payload`` is a plain dict::

        {"kernel": "rglru_scan",            # kernels/registry.py name
         "shape": {"B": 1, "L": 64, ...},   # omitted -> the kernel's tiny shape
         "dtype": "float32", "reps": 3, "seed": 0,
         "config": {"block_d": 512}}        # optional explicit blocks

    Block-config resolution mirrors kernels/ops.py: explicit payload config
    > autotuned cache (``HYDRA_AUTOTUNE=1`` only) > the kernel's committed
    defaults.  Execution is rep-granular and resumable: ``progress_frac``
    advances after every completed repetition, so a preempt-killed task that
    the checkpointer resumes (ckpt/checkpoint.py) skips the reps it already
    finished — only the partial rep in flight is re-executed.
    """

    def run(self, task: Task) -> Any:
        import time as _time

        import jax

        from repro.kernels import registry as kreg
        from repro.kernels.autotune import tuned_config

        spec = dict(task.payload or {})
        kdef = kreg.get_kernel(spec["kernel"])
        shape = dict(spec.get("shape") or kdef.tiny_shape)
        dtype = spec.get("dtype", "float32")
        reps = max(1, int(spec.get("reps", 1)))
        seed = int(spec.get("seed", 0))
        config = spec.get("config") or tuned_config(kdef.name, shape, dtype) or kdef.defaults(shape)
        interpret = kreg.interpret_default()
        args = kdef.make_args(shape, dtype, seed)
        done = min(reps, int(round(task.progress_frac * reps)))
        out = None
        t0 = _time.perf_counter()
        for r in range(done, reps):
            out = kdef.call(shape, args, config, interpret)
            jax.block_until_ready(out)
            # completed-rep boundary: durable progress the checkpointer can
            # capture without losing more than the rep in flight
            task.kernel_done_s += _time.perf_counter() - t0
            t0 = _time.perf_counter()
            task.progress_frac = (r + 1) / reps
        kernel_s = task.kernel_done_s
        # lifetime totals (reps survive preempt/resume cycles): the broker
        # emits ONE kernel.exec per completed task, so execs reconcile with
        # completed-task counts and reps/seconds with total work performed
        task.kernel_stats = {
            "kernel": kdef.name,
            "reps": reps,
            "kernel_s": kernel_s,
            "config": kreg.config_sig(config),
        }
        return {
            "kernel": kdef.name,
            "sig": kreg.shape_sig(shape, dtype),
            "config": kreg.config_sig(config),
            "reps": reps,
            "skipped_reps": done,
            "kernel_s": kernel_s,
        }


KERNEL_RUNTIME = KernelRuntime()


class CaaSManager:
    """One per cloud-like provider.  Bulk pod submission + tracing."""

    def __init__(
        self,
        handle: ProviderHandle,
        on_task_done: Optional[Callable] = None,
        on_task_skipped: Optional[Callable] = None,
        on_task_finishing: Optional[Callable] = None,
    ):
        self.handle = handle
        self.spec = handle.spec
        self.on_task_done = on_task_done
        self.on_task_skipped = on_task_skipped
        # runs BEFORE mark_done resolves the future: resolving enqueues
        # dependent tasks synchronously, so anything a dependent must be able
        # to observe (declared outputs in the staging registry) registers here
        self.on_task_finishing = on_task_finishing
        self._pool = ThreadPoolExecutor(
            max_workers=self.spec.concurrency, thread_name_prefix=f"caas-{handle.name}"
        )
        self._down = threading.Event()
        self._inflight: set = set()
        self._lock = threading.Lock()
        # health signal counters: consumed by provider-group breakers and
        # the group-aware metrics rows (broker.group_rows / benchmarks)
        self.completed = 0
        self.failed = 0

    # -- lifecycle -----------------------------------------------------
    def fail(self):
        """Simulate a provider outage (tests / fault-tolerance benchmarks)."""
        self._down.set()

    def recover(self):
        self._down.clear()

    def stats(self) -> dict:
        return {
            "provider": self.handle.name,
            "down": self.down,
            "completed": self.completed,
            "failed": self.failed,
        }

    @property
    def down(self) -> bool:
        return self._down.is_set()

    def shutdown(self, wait: bool = True):
        self._pool.shutdown(wait=wait, cancel_futures=not wait)

    # -- submission ----------------------------------------------------
    def submit_pods(self, pods: list[Pod]):
        """Bulk submission: one enqueue per pod (not per task)."""
        if self.down:
            raise ProviderDown(self.handle.name)
        if self.spec.submit_latency_s:
            get_clock().sleep(self.spec.submit_latency_s)  # modeled API round-trip
        futures = []
        for pod in pods:
            for t in pod.tasks:
                t.try_advance(TaskState.SUBMITTED)
                t.trace.add("submitted")
            futures.append(self._pool.submit(self._run_pod, pod))
        return futures

    # -- execution -----------------------------------------------------
    def _run_pod(self, pod: Pod):
        pod.trace.add("env_setup_start")
        if self.spec.env_setup_s:
            get_clock().sleep(self.spec.env_setup_s * (1 if pod.model != "scpp" else 1.0))
        pod.trace.add("env_setup_done")
        try:
            for t in pod.tasks:
                if self.down:
                    # fail the remaining tasks so the broker re-binds them
                    for rest in pod.tasks:
                        if (
                            not rest.final
                            and rest.provider == self.handle.name
                            and rest.mark_failed(ProviderDown(self.handle.name))
                            and self.on_task_done
                        ):
                            self.on_task_done(rest, self.handle.name, failed=True)
                    return
                self._run_task(t)
        finally:
            pod.trace.add("env_teardown_start")
            pod.trace.add("env_teardown_done")

    def _run_task(self, task: Task):
        # canceled, speculatively completed elsewhere, or re-bound away:
        # tell the broker so group load accounting releases the slot
        if task.final or not task.try_advance(TaskState.RUNNING):
            if self.on_task_skipped:
                self.on_task_skipped(task, self.handle.name)
            return
        task.trace.add("exec_start")
        try:
            result = self._execute(task)
        except BaseException as e:
            if task.mark_failed(e):
                with self._lock:
                    self.failed += 1
                if self.on_task_done:
                    self.on_task_done(task, self.handle.name, failed=True)
            return
        if task.tstate == TaskState.FAILED:
            # preempt-style kill landed while _execute was running (see
            # Preempted): report the failure exactly once so the broker
            # retries it — the success path below would swallow it,
            # stranding the task's future forever
            with self._lock:
                self.failed += 1
            if self.on_task_done:
                self.on_task_done(task, self.handle.name, failed=True)
            return
        # skip on duplicate completions (speculation / post-rebind finishes):
        # mark_done no-ops those, and the hook must not re-register outputs
        if self.on_task_finishing and not task.final:
            self.on_task_finishing(task, self.handle.name)
        task.mark_done(result)
        with self._lock:
            self.completed += 1
        if self.on_task_done:
            self.on_task_done(task, self.handle.name, failed=False)

    def _execute(self, task: Task) -> Any:
        if task.kind == "noop":
            return None
        if task.kind == "sleep":
            # checkpoint resume (ckpt/checkpoint.py): only the work beyond
            # the captured progress_frac is re-executed
            get_clock().sleep(task.duration * (1.0 - task.progress_frac))
            return None
        if task.kind == "callable":
            return task.fn() if task.fn else None
        if task.kind == "compute":
            return COMPUTE_RUNTIME.run(task)
        if task.kind == "kernel":
            return KERNEL_RUNTIME.run(task)
        raise ValueError(task.kind)
