"""HPC (Pilot) Manager — the RADICAL-Pilot connector analogue (paper §3.1).

A *pilot* is a persistent allocation acquired once (after a modeled batch
queue wait), into which the manager bulk-submits task descriptions.  Tasks
execute inside the standing allocation without per-task scheduler round
trips — exactly the pilot abstraction Hydra uses on Bridges2.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

from repro.core.managers.compute import COMPUTE_RUNTIME, KERNEL_RUNTIME, ProviderDown
from repro.core.pod import Pod
from repro.core.provider import ProviderHandle
from repro.core.task import Task, TaskState
from repro.runtime.clock import get_clock
from repro.runtime.tracing import Trace


class PilotManager:
    def __init__(
        self,
        handle: ProviderHandle,
        on_task_done: Optional[Callable] = None,
        on_task_skipped: Optional[Callable] = None,
        on_task_finishing: Optional[Callable] = None,
    ):
        self.handle = handle
        self.spec = handle.spec
        self.on_task_done = on_task_done
        self.on_task_skipped = on_task_skipped
        # pre-resolution hook: see CaaSManager.on_task_finishing
        self.on_task_finishing = on_task_finishing
        self.trace = Trace()
        self._q: queue.Queue = queue.Queue()
        self._down = threading.Event()
        self._stop = threading.Event()
        self._started = threading.Event()
        self._workers: list[threading.Thread] = []
        self._stats_lock = threading.Lock()
        # health signal counters (see CaaSManager.stats)
        self.completed = 0
        self.failed = 0
        self._boot = threading.Thread(target=self._acquire_pilot, daemon=True)
        self._boot.start()

    # -- pilot lifecycle -------------------------------------------------
    def _acquire_pilot(self):
        self.trace.add("pilot_queue_start")
        if self.spec.queue_delay_s:
            get_clock().sleep(self.spec.queue_delay_s)  # modeled batch queue wait
        self.trace.add("pilot_active")
        for i in range(self.spec.concurrency):
            w = threading.Thread(
                target=self._worker, daemon=True, name=f"pilot-{self.handle.name}-{i}"
            )
            w.start()
            self._workers.append(w)
        self._started.set()

    def fail(self):
        self._down.set()

    def recover(self):
        self._down.clear()

    @property
    def down(self) -> bool:
        return self._down.is_set()

    def stats(self) -> dict:
        return {
            "provider": self.handle.name,
            "down": self.down,
            "completed": self.completed,
            "failed": self.failed,
        }

    def shutdown(self, wait: bool = True):
        self._stop.set()
        for _ in self._workers:
            self._q.put(None)
        if wait:
            for w in self._workers:
                w.join(timeout=5.0)
        self.trace.add("pilot_released")

    # -- submission --------------------------------------------------------
    def submit_pods(self, pods: list[Pod]):
        """Bulk submission of task descriptions into the pilot queue."""
        if self.down:
            raise ProviderDown(self.handle.name)
        if self.spec.submit_latency_s:
            get_clock().sleep(self.spec.submit_latency_s)
        for pod in pods:
            pod.trace.add("env_setup_start")
            pod.trace.add("env_setup_done")  # pilot env already standing
            for t in pod.tasks:
                t.try_advance(TaskState.SUBMITTED)
                t.trace.add("submitted")
                self._q.put((t, pod))

    # -- execution ---------------------------------------------------------
    def _worker(self):
        while not self._stop.is_set():
            item = self._q.get()
            if item is None:
                return
            task, pod = item
            if self.down:
                if (
                    task.provider == self.handle.name
                    and task.mark_failed(ProviderDown(self.handle.name))
                    and self.on_task_done
                ):
                    self.on_task_done(task, self.handle.name, failed=True)
                continue
            self._run_task(task)
            if all(t.final for t in pod.tasks):
                pod.trace.add("env_teardown_done")

    def _run_task(self, task: Task):
        # finished elsewhere or re-bound away: release the group load slot
        if task.final or not task.try_advance(TaskState.RUNNING):
            if self.on_task_skipped:
                self.on_task_skipped(task, self.handle.name)
            return
        task.trace.add("exec_start")
        try:
            if task.kind == "noop":
                result = None
            elif task.kind == "sleep":
                get_clock().sleep(task.duration)
                result = None
            elif task.kind == "callable":
                result = task.fn() if task.fn else None
            elif task.kind == "compute":
                result = COMPUTE_RUNTIME.run(task)
            elif task.kind == "kernel":
                result = KERNEL_RUNTIME.run(task)
            else:
                raise ValueError(task.kind)
        except BaseException as e:
            if task.mark_failed(e):
                with self._stats_lock:
                    self.failed += 1
                if self.on_task_done:
                    self.on_task_done(task, self.handle.name, failed=True)
            return
        if task.tstate == TaskState.FAILED:
            # preempt-style kill mid-execution: see CaaSManager._run_task
            with self._stats_lock:
                self.failed += 1
            if self.on_task_done:
                self.on_task_done(task, self.handle.name, failed=True)
            return
        # duplicate completions skip the hook: see CaaSManager._run_task
        if self.on_task_finishing and not task.final:
            self.on_task_finishing(task, self.handle.name)
        task.mark_done(result)
        with self._stats_lock:
            self.completed += 1
        if self.on_task_done:
            self.on_task_done(task, self.handle.name, failed=False)
