"""Data Manager (paper §3.1): inter- and cross-provider data operations via a
unified API — copy, move, link, delete, list — plus checkpoint staging.

Each provider has a *site store* (a directory namespace); a *shared* store
models the cross-site object store.  On a real fleet these verbs map to the
pod-local SSD / pod NFS / cross-region object store; the API is identical.

Two hard edges, learned the hard way:

  * Sites must be ``register_site``-ed before any verb touches them: a typo'd
    destination used to silently mint a brand-new site directory, and the
    "staged" data was never seen again.  Unknown sites now raise
    ``UnknownSiteError``.
  * Path containment is checked with ``os.path.commonpath``, not a string
    prefix: ``startswith`` without a trailing separator let ``../ab/x``
    escape site ``a`` into a sibling site ``ab``.

When a ``DatasetRegistry`` (core/staging.py) is attached, the physical verbs
keep the logical replica map coherent: a copy/move/link whose relative path
names a registered dataset records (or drops) the replica at the touched
sites, so modeled placement and on-disk reality do not drift apart.
"""
from __future__ import annotations

import os
import shutil

from repro.runtime.tracing import Trace


class UnknownSiteError(ValueError):
    """A verb named a site that was never ``register_site``-ed."""


class DataManager:
    def __init__(self, root: str):
        self.root = root
        self.trace = Trace()
        self._sites: set[str] = {"shared"}
        self.registry = None  # optional DatasetRegistry (core/staging.py)
        os.makedirs(os.path.join(root, "shared"), exist_ok=True)

    def attach_registry(self, registry) -> None:
        """Couple physical ops to the staging layer's logical replica map."""
        self.registry = registry

    def register_site(self, provider: str) -> str:
        self._sites.add(provider)
        path = self._site(provider)
        os.makedirs(path, exist_ok=True)
        return path

    def deregister_site(self, provider: str) -> None:
        """The site's provider is gone: further verbs naming it must raise
        (UnknownSiteError) instead of silently stranding data in a dead
        directory.  The files themselves are left for the workdir cleanup."""
        self._sites.discard(provider)

    def _site(self, site: str) -> str:
        if site not in self._sites:
            raise UnknownSiteError(
                f"unknown site {site!r}: register_site() it first "
                f"(known: {sorted(self._sites)})"
            )
        return os.path.join(self.root, site)

    def _resolve(self, site: str, rel: str) -> str:
        base = os.path.normpath(self._site(site))
        path = os.path.normpath(os.path.join(base, rel))
        # commonpath, NOT startswith: "a/../ab" shares the "a" string prefix
        # with site "a" but is NOT contained in it
        if os.path.commonpath([base, path]) != base:
            raise ValueError(f"path escape: {site}:{rel}")
        return path

    # -- logical replica coherence (no-ops without a registry) -----------
    def _note_replica(self, site: str, rel: str) -> None:
        if self.registry is not None and self.registry.known(rel):
            from repro.core.staging import StagingError

            try:
                self.registry.place_replica(rel, site)
            except StagingError:
                pass  # site unknown to the model, or modeled scratch full

    def _drop_replica(self, site: str, rel: str) -> None:
        if self.registry is not None and self.registry.known(rel):
            self.registry.drop_replica(rel, site)

    # -- the paper's five verbs ------------------------------------------
    def copy(self, src_site: str, src: str, dst_site: str, dst: str) -> str:
        s, d = self._resolve(src_site, src), self._resolve(dst_site, dst)
        os.makedirs(os.path.dirname(d), exist_ok=True)
        if os.path.isdir(s):
            shutil.copytree(s, d, dirs_exist_ok=True)
        else:
            shutil.copy2(s, d)
        self._note_replica(dst_site, dst)
        self.trace.add(f"copy:{src_site}:{src}->{dst_site}:{dst}")
        return d

    def move(self, src_site: str, src: str, dst_site: str, dst: str) -> str:
        s, d = self._resolve(src_site, src), self._resolve(dst_site, dst)
        os.makedirs(os.path.dirname(d), exist_ok=True)
        shutil.move(s, d)
        self._drop_replica(src_site, src)
        self._note_replica(dst_site, dst)
        self.trace.add(f"move:{src_site}:{src}->{dst_site}:{dst}")
        return d

    def link(self, src_site: str, src: str, dst_site: str, dst: str) -> str:
        """Zero-copy intra-filesystem staging (same-site fast path)."""
        s, d = self._resolve(src_site, src), self._resolve(dst_site, dst)
        os.makedirs(os.path.dirname(d), exist_ok=True)
        if os.path.lexists(d):
            os.unlink(d)
        os.symlink(os.path.abspath(s), d)
        self._note_replica(dst_site, dst)
        self.trace.add(f"link:{src_site}:{src}->{dst_site}:{dst}")
        return d

    def delete(self, site: str, rel: str) -> None:
        p = self._resolve(site, rel)
        if os.path.isdir(p) and not os.path.islink(p):
            shutil.rmtree(p)
        elif os.path.lexists(p):
            os.unlink(p)
        self._drop_replica(site, rel)
        self.trace.add(f"delete:{site}:{rel}")

    def list(self, site: str, rel: str = ".") -> list[str]:
        p = self._resolve(site, rel)
        if not os.path.isdir(p):
            return []
        return sorted(os.listdir(p))

    def exists(self, site: str, rel: str) -> bool:
        return os.path.lexists(self._resolve(site, rel))

    def put_bytes(self, site: str, rel: str, payload: bytes) -> str:
        p = self._resolve(site, rel)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
        self._note_replica(site, rel)
        return p

    def get_bytes(self, site: str, rel: str) -> bytes:
        with open(self._resolve(site, rel), "rb") as f:
            return f.read()

    # -- checkpoint staging ------------------------------------------------
    def stage_checkpoint(self, provider: str, ckpt_dir: str, step: int) -> str:
        """Stage a local checkpoint step dir to the shared store (async save
        path calls this after the write completes)."""
        name = f"step_{step:08d}"
        src = os.path.join(ckpt_dir, name)
        dst = self._resolve("shared", os.path.join("ckpt", provider, name))
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        if os.path.isdir(dst):
            shutil.rmtree(dst)
        shutil.copytree(src, dst)
        self.trace.add(f"stage_ckpt:{provider}:{step}")
        return dst
