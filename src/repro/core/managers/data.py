"""Data Manager (paper §3.1): inter- and cross-provider data operations via a
unified API — copy, move, link, delete, list — plus checkpoint staging.

Each provider has a *site store* (a directory namespace); a *shared* store
models the cross-site object store.  On a real fleet these verbs map to the
pod-local SSD / pod NFS / cross-region object store; the API is identical.
"""
from __future__ import annotations

import os
import shutil

from repro.runtime.tracing import Trace


class DataManager:
    def __init__(self, root: str):
        self.root = root
        self.trace = Trace()
        os.makedirs(os.path.join(root, "shared"), exist_ok=True)

    def register_site(self, provider: str) -> str:
        path = self._site(provider)
        os.makedirs(path, exist_ok=True)
        return path

    def _site(self, site: str) -> str:
        return os.path.join(self.root, site)

    def _resolve(self, site: str, rel: str) -> str:
        path = os.path.normpath(os.path.join(self._site(site), rel))
        if not path.startswith(os.path.normpath(self._site(site))):
            raise ValueError(f"path escape: {site}:{rel}")
        return path

    # -- the paper's five verbs ------------------------------------------
    def copy(self, src_site: str, src: str, dst_site: str, dst: str) -> str:
        s, d = self._resolve(src_site, src), self._resolve(dst_site, dst)
        os.makedirs(os.path.dirname(d), exist_ok=True)
        if os.path.isdir(s):
            shutil.copytree(s, d, dirs_exist_ok=True)
        else:
            shutil.copy2(s, d)
        self.trace.add(f"copy:{src_site}:{src}->{dst_site}:{dst}")
        return d

    def move(self, src_site: str, src: str, dst_site: str, dst: str) -> str:
        s, d = self._resolve(src_site, src), self._resolve(dst_site, dst)
        os.makedirs(os.path.dirname(d), exist_ok=True)
        shutil.move(s, d)
        self.trace.add(f"move:{src_site}:{src}->{dst_site}:{dst}")
        return d

    def link(self, src_site: str, src: str, dst_site: str, dst: str) -> str:
        """Zero-copy intra-filesystem staging (same-site fast path)."""
        s, d = self._resolve(src_site, src), self._resolve(dst_site, dst)
        os.makedirs(os.path.dirname(d), exist_ok=True)
        if os.path.lexists(d):
            os.unlink(d)
        os.symlink(os.path.abspath(s), d)
        self.trace.add(f"link:{src_site}:{src}->{dst_site}:{dst}")
        return d

    def delete(self, site: str, rel: str) -> None:
        p = self._resolve(site, rel)
        if os.path.isdir(p) and not os.path.islink(p):
            shutil.rmtree(p)
        elif os.path.lexists(p):
            os.unlink(p)
        self.trace.add(f"delete:{site}:{rel}")

    def list(self, site: str, rel: str = ".") -> list[str]:
        p = self._resolve(site, rel)
        if not os.path.isdir(p):
            return []
        return sorted(os.listdir(p))

    def exists(self, site: str, rel: str) -> bool:
        return os.path.lexists(self._resolve(site, rel))

    def put_bytes(self, site: str, rel: str, payload: bytes) -> str:
        p = self._resolve(site, rel)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(payload)
        return p

    def get_bytes(self, site: str, rel: str) -> bytes:
        with open(self._resolve(site, rel), "rb") as f:
            return f.read()

    # -- checkpoint staging ------------------------------------------------
    def stage_checkpoint(self, provider: str, ckpt_dir: str, step: int) -> str:
        """Stage a local checkpoint step dir to the shared store (async save
        path calls this after the write completes)."""
        name = f"step_{step:08d}"
        src = os.path.join(ckpt_dir, name)
        dst = self._resolve("shared", os.path.join("ckpt", provider, name))
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        if os.path.isdir(dst):
            shutil.rmtree(dst)
        shutil.copytree(src, dst)
        self.trace.add(f"stage_ckpt:{provider}:{step}")
        return dst
