"""Workload partitioning: tasks -> pods (paper §3.2, §5 SCPP/MCPP).

  SCPP (single container per pod)   - one task per pod; tasks run with their
        own resources.  Higher per-pod serialization cost (the paper measures
        ~46% extra OVH / ~44% lower TH vs MCPP).
  MCPP (multiple containers per pod) - tasks packed into pods that fit the
        provider's per-node capacity; co-scheduled tasks share pod resources.

``binpack`` is the heterogeneity-aware variant (first-fit-decreasing on task
cpu requirements) used for Exp 3B-style mixed workloads.
"""
from __future__ import annotations

from typing import Optional

from repro.core.pod import Pod
from repro.core.task import Resources, Task


def partition(
    tasks: list[Task],
    provider: str,
    model: str = "mcpp",
    pod_capacity: Optional[Resources] = None,
    tasks_per_pod: int = 64,
) -> list[Pod]:
    if model == "scpp":
        return [Pod(provider, [t], "scpp") for t in tasks]
    if model == "mcpp":
        pods = []
        for i in range(0, len(tasks), tasks_per_pod):
            pods.append(Pod(provider, tasks[i : i + tasks_per_pod], "mcpp"))
        return pods
    if model == "binpack":
        cap = pod_capacity or Resources(cpus=16, accels=8, memory_mb=1 << 15)
        return _binpack(tasks, provider, cap)
    raise ValueError(model)


def _binpack(tasks: list[Task], provider: str, cap: Resources) -> list[Pod]:
    """First-fit-decreasing on (cpus, accels, memory)."""
    order = sorted(tasks, key=lambda t: (t.resources.cpus, t.resources.accels, t.resources.memory_mb), reverse=True)
    bins: list[tuple[Resources, list[Task]]] = []
    for t in order:
        placed = False
        for free, members in bins:
            if t.resources.fits(free):
                free.cpus -= t.resources.cpus
                free.accels -= t.resources.accels
                free.memory_mb -= t.resources.memory_mb
                members.append(t)
                placed = True
                break
        if not placed:
            if not t.resources.fits(cap):
                raise ValueError(
                    f"task {t.uid} requires {vars(t.resources)} exceeding pod capacity {vars(cap)}"
                )
            free = Resources(
                cap.cpus - t.resources.cpus, cap.accels - t.resources.accels, cap.memory_mb - t.resources.memory_mb
            )
            bins.append((free, [t]))
    return [Pod(provider, members, "binpack") for _, members in bins]
