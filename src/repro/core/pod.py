"""Pod: a dispatch group of tasks submitted to a provider in one bulk call.

The paper's CaaS Manager partitions a workload into pods and *serializes each
pod* before submission.  The published implementation writes pods to disk
(identified in §5.1/§6 as Hydra's throughput bottleneck, ~46% extra OVH with
SCPP); in-memory pod construction is the paper's named future-work fix.  Both
stores are implemented here so the benchmark suite can measure the exact
trade-off (EXPERIMENTS.md §Perf):

  DiskPodStore    - faithful baseline: one JSON file per pod, fsync'd.
  MemoryPodStore  - optimized: pods serialized to bytes in memory.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.core.task import Task, describe
from repro.runtime.tracing import Counter, Trace

_ids = Counter("pod")


class Pod:
    def __init__(self, provider: str, tasks: list[Task], model: str):
        self.uid = _ids.next()
        self.provider = provider
        self.tasks = tasks
        self.model = model  # "mcpp" | "scpp"
        self.trace = Trace()
        self.serialized: Optional[bytes] = None
        self.path: Optional[str] = None
        # micro-batch provenance: which streaming-dispatcher batch coalesced
        # this pod (None for classic frontier-mode submissions)
        self.batch_id: Optional[str] = None
        for t in tasks:
            t.pod_uid = self.uid

    @property
    def size(self) -> int:
        return len(self.tasks)

    def describe(self) -> dict:
        return {
            "uid": self.uid,
            "provider": self.provider,
            "model": self.model,
            "batch_id": self.batch_id,
            "tasks": [describe(t) for t in self.tasks],
        }


class PodStore:
    def serialize(self, pod: Pod) -> None:
        raise NotImplementedError

    def cleanup(self) -> None:
        pass


class MemoryPodStore(PodStore):
    """Optimized: build + serialize pods in memory (paper future work)."""

    def serialize(self, pod: Pod) -> None:
        pod.serialized = json.dumps(pod.describe()).encode()


class DiskPodStore(PodStore):
    """Faithful baseline: write each pod descriptor to its own file."""

    def __init__(self, workdir: str, fsync: bool = True):
        self.workdir = workdir
        self.fsync = fsync
        os.makedirs(workdir, exist_ok=True)

    def serialize(self, pod: Pod) -> None:
        payload = json.dumps(pod.describe(), indent=2).encode()
        path = os.path.join(self.workdir, f"{pod.uid}.json")
        with open(path, "wb") as f:
            f.write(payload)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())
        pod.path = path
        # faithful: the submission path re-reads the descriptor from disk
        with open(path, "rb") as f:
            pod.serialized = f.read()

    def cleanup(self) -> None:
        for name in os.listdir(self.workdir):
            if name.endswith(".json"):
                try:
                    os.unlink(os.path.join(self.workdir, name))
                except OSError:
                    pass


def make_store(kind: str, workdir: str) -> PodStore:
    if kind == "disk":
        return DiskPodStore(os.path.join(workdir, "pods"))
    if kind == "memory":
        return MemoryPodStore()
    raise ValueError(kind)
