"""Provider Proxy (paper §3.1): collects user + provider interface info and
validates credentials/capabilities before Hydra's engine starts.

A *provider* on the TPU-fleet adaptation is a named device pool: a slice of
the visible accelerator fleet with a platform type (cloud-like on-demand pool
vs HPC-like batch pool), a capability vector, and a connector kind.  The
proxy checks that (1) the credential record is well-formed, (2) the pool's
devices are actually visible to the runtime, (3) pools do not overlap, and
(4) the declared capabilities are consistent - the same role the paper's
Provider Proxy plays for AWS/Azure/Jetstream2/Chameleon credentials.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Optional

import jax

from repro.core.task import Resources
from repro.runtime.tracing import Trace


class CredentialError(RuntimeError):
    pass


class ValidationError(RuntimeError, ValueError):
    """Bad configuration value.  Subclasses ValueError too: callers that
    guard spec construction with ``except ValueError`` (the stdlib contract
    for rejected arguments, e.g. LaunchSpec bounds) catch these, while the
    historical ``except RuntimeError`` handlers keep working."""


@dataclass
class ProviderSpec:
    """Static description of one provider (device pool)."""

    name: str
    platform: str = "cloud"  # "cloud" | "hpc"
    connector: str = "caas"  # "caas" | "pilot"
    n_devices: int = 1
    device_offset: int = 0  # slice [offset, offset+n) of jax.devices()
    node_capacity: Resources = field(default_factory=lambda: Resources(cpus=16, accels=8, memory_mb=1 << 16))
    n_nodes: int = 1
    concurrency: int = 4  # concurrent task slots
    submit_latency_s: float = 0.0  # modeled provider API round-trip
    env_setup_s: float = 0.0  # modeled pod env bring-up (container pull etc.)
    queue_delay_s: float = 0.0  # modeled HPC batch queue wait
    credentials: dict = field(default_factory=lambda: {"token": "local"})

    def capacity(self) -> Resources:
        return Resources(
            cpus=self.node_capacity.cpus * self.n_nodes,
            accels=self.node_capacity.accels * self.n_nodes,
            memory_mb=self.node_capacity.memory_mb * self.n_nodes,
        )


@dataclass
class ProviderHandle:
    """A validated provider: spec + live device slice + health state.

    ``group`` names the ProviderGroup the provider is pooled into, if any;
    grouped providers are reached through their group's logical name and are
    excluded from direct policy binding (their health lives in the group's
    per-member circuit breaker, see core/group.py)."""

    spec: ProviderSpec
    devices: list = field(default_factory=list)
    healthy: bool = True
    group: Optional[str] = None
    # tasks dispatched to this (ungrouped) provider and not yet finished:
    # maintained by the broker, feeds the load-aware idle_slots() hint.
    # Grouped members track load in their GroupMember instead.  Guarded by
    # its own per-handle lock: this counter moves twice per task (dispatch
    # and completion, from hundreds of manager threads), and serializing it
    # through the broker-wide lock was a measurable §Perf hot spot.
    outstanding: int = 0
    load_lock: threading.Lock = field(default_factory=threading.Lock)
    trace: Trace = field(default_factory=Trace)

    @property
    def name(self) -> str:
        return self.spec.name


class ProviderProxy:
    """Registry + validation of providers and provider groups (the paper's
    Provider Proxy, extended with the group layer)."""

    def __init__(self):
        self._providers: dict[str, ProviderHandle] = {}
        self._groups: dict[str, Any] = {}  # name -> ProviderGroup
        self._lock = threading.Lock()
        # topology version: bumped on every change that can alter the
        # bind-target set or its capacities (register/deregister, group
        # membership, health flips, breaker transitions).  Keys the cached
        # bind_targets() list and the policies' eligibility index
        # (core/policy.py), making the per-dispatch "what can I bind to"
        # question O(1) on an unchanged topology.
        self._version = 0
        self._targets_cache: Optional[tuple[int, list]] = None

    def bump_version(self) -> None:
        """Invalidate the cached bind-target list (health flips and breaker
        transitions live outside the proxy, so their owners call this)."""
        with self._lock:
            self._bump()

    def _bump(self) -> None:
        # callers hold self._lock
        self._version += 1
        self._targets_cache = None

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def register(self, spec: ProviderSpec) -> ProviderHandle:
        self._validate_credentials(spec)
        devices = self._validate_devices(spec)
        with self._lock:
            if spec.name in self._providers or spec.name in self._groups:
                raise ValidationError(f"provider {spec.name!r} already registered")
            handle = ProviderHandle(spec=spec, devices=devices)
            handle.trace.add("validated")
            self._providers[spec.name] = handle
            self._bump()
            return handle

    def deregister(self, name: str) -> ProviderHandle:
        with self._lock:
            handle = self._providers.pop(name)
            self._bump()
            return handle

    def get(self, name: str) -> ProviderHandle:
        h = self._providers.get(name)
        if h is None:
            raise KeyError(f"unknown provider {name!r}")
        return h

    def healthy(self) -> list[ProviderHandle]:
        with self._lock:
            return [h for h in self._providers.values() if h.healthy]

    def all(self) -> list[ProviderHandle]:
        with self._lock:
            return list(self._providers.values())

    # -- groups --------------------------------------------------------
    def register_group(self, group) -> None:
        """Register a ProviderGroup; its name becomes a logical bind target
        and its members leave the direct-binding pool."""
        with self._lock:
            if group.name in self._providers or group.name in self._groups:
                raise ValidationError(f"name {group.name!r} already registered")
            for member in group.member_names:
                h = self._providers.get(member)
                if h is None:
                    raise ValidationError(
                        f"group {group.name!r}: member {member!r} is not a registered provider"
                    )
                if h.group is not None:
                    raise ValidationError(
                        f"group {group.name!r}: member {member!r} already in group {h.group!r}"
                    )
            for member in group.member_names:
                self._providers[member].group = group.name
            self._groups[group.name] = group
            self._bump()

    def attach_member(self, group_name: str, member_name: str) -> ProviderHandle:
        """Wire an already-registered provider into a live group (elastic
        scale-out: the group side is ProviderGroup.add_member).  The member
        leaves the direct-binding pool, exactly as at group registration."""
        with self._lock:
            if group_name not in self._groups:
                raise KeyError(f"unknown provider group {group_name!r}")
            h = self._providers.get(member_name)
            if h is None:
                raise ValidationError(
                    f"group {group_name!r}: member {member_name!r} is not a registered provider"
                )
            if h.group is not None:
                raise ValidationError(
                    f"group {group_name!r}: member {member_name!r} already in group {h.group!r}"
                )
            h.group = group_name
            self._bump()
            return h

    def get_group(self, name: str):
        g = self._groups.get(name)
        if g is None:
            raise KeyError(f"unknown provider group {name!r}")
        return g

    def is_group(self, name: str) -> bool:
        return name in self._groups

    def groups(self) -> list:
        with self._lock:
            return list(self._groups.values())

    def bind_targets(self) -> list:
        """What binding policies may choose from: healthy *ungrouped*
        providers plus routable groups (grouped members are reached only
        through their group).

        The list is CACHED per topology version and the cached object is
        returned directly (callers treat it as read-only), so the dispatch
        hot path pays O(1) instead of an O(providers) rebuild per batch —
        and its identity keys the policies' eligibility index.  The cache
        is skipped while any group is excluded for routability: a
        non-routable group can become routable again purely by TIME (its
        members' breaker reset windows elapsing), which no event signals.

        Group routability is evaluated OUTSIDE the proxy lock: a member
        breaker transition (under group/breaker locks) re-enters the proxy
        via bump_version, so peeking group state under the proxy lock would
        close a proxy -> group -> proxy lock cycle."""
        with self._lock:
            cached = self._targets_cache
            if cached is not None and cached[0] == self._version:
                return cached[1]
            ver = self._version
            targets: list = [
                h for h in self._providers.values() if h.healthy and h.group is None
            ]
            groups = list(self._groups.values())
        excluded = False
        for g in groups:
            if g.routable():
                targets.append(g)
            else:
                excluded = True
        with self._lock:
            if not excluded and self._version == ver:
                self._targets_cache = (ver, targets)
        return targets

    def targets_version(self, targets) -> Optional[int]:
        """The topology version ``targets`` was built at — iff it IS the
        proxy's current cached bind-target list (identity check).  Any other
        list (filtered rebind/speculation lists, test fixtures) returns None
        and eligibility falls back to a scan."""
        with self._lock:
            cached = self._targets_cache
            if cached is not None and cached[1] is targets and cached[0] == self._version:
                return cached[0]
            return None

    # ------------------------------------------------------------------
    @staticmethod
    def _validate_credentials(spec: ProviderSpec) -> None:
        creds = spec.credentials
        if not isinstance(creds, dict) or "token" not in creds or not creds["token"]:
            raise CredentialError(f"provider {spec.name!r}: missing or empty credential token")
        if spec.platform not in ("cloud", "hpc"):
            raise ValidationError(f"provider {spec.name!r}: unknown platform {spec.platform!r}")
        if spec.connector not in ("caas", "pilot"):
            raise ValidationError(f"provider {spec.name!r}: unknown connector {spec.connector!r}")

    def _validate_devices(self, spec: ProviderSpec) -> list:
        devs = jax.devices()
        lo, hi = spec.device_offset, spec.device_offset + spec.n_devices
        if spec.n_devices < 1:
            raise ValidationError(f"provider {spec.name!r}: n_devices must be >= 1")
        if hi > len(devs):
            # device pools may logically share the single CPU device in this
            # container; only reject if the pool is empty
            if spec.device_offset >= len(devs):
                slice_ = [devs[spec.device_offset % len(devs)]]
            else:
                slice_ = devs[lo:]
        else:
            slice_ = devs[lo:hi]
        return list(slice_)
