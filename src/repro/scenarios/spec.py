"""Declarative scenario specs: plain dataclasses, fully JSON round-trippable.

A spec is *data* — fleet shape, traffic mix, chaos schedule, invariant
bounds — so a scenario can live in version control, ship to nightly CI, and
be rebuilt bit-identically from its dict form.  ``to_core`` methods turn
declarations into the live core objects (ProviderSpec / LaunchSpec / chaos
events) at run time, inside the runner's active clock.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Optional

from repro.core.autoscaler import LatencyModel, LaunchSpec
from repro.core.chaos import (
    ChaosEvent,
    LinkWindow,
    PreemptKill,
    QuarantineStorm,
    SiteOutage,
)
from repro.core.admission import TenantSpec
from repro.core.provider import ProviderSpec


@dataclass
class TenantDecl:
    """One tenant at the broker's front door (core/admission.py): fair-share
    weight plus optional rate limit and queue bound.  ``None`` for rate /
    max_queued means unlimited — scenario presets declare weights only, so
    the bulk up-front workflow admission is never rejected and fairness
    still shapes the drain order."""

    name: str
    weight: float = 1.0
    rate: Optional[float] = None  # admissions/s (token-bucket refill)
    burst: Optional[float] = None  # bucket depth (default: rate)
    max_queued: Optional[int] = None  # bound on admitted-but-unfinished

    def to_core(self) -> TenantSpec:
        return TenantSpec(
            name=self.name,
            rate=self.rate,
            burst=self.burst,
            max_queued=self.max_queued,
            weight=self.weight,
        )


@dataclass
class ProviderDecl:
    """One statically-registered provider (the paper's standing platforms)."""

    name: str
    platform: str = "cloud"
    connector: str = "caas"  # "caas" | "pilot"
    concurrency: int = 8
    n_nodes: int = 1

    def to_core(self) -> ProviderSpec:
        return ProviderSpec(
            name=self.name,
            platform=self.platform,
            connector=self.connector,
            concurrency=self.concurrency,
            n_nodes=self.n_nodes,
        )


@dataclass
class ElasticDecl:
    """One launchable template for the autoscaler's ProviderPool.  The
    latency is FIXED by default: scenario determinism should hinge on the
    seeded chaos/transfer draws, not on acquisition-latency sampling."""

    template: str
    platform: str = "cloud"
    connector: str = "caas"
    concurrency: int = 8
    min_instances: int = 0
    max_instances: int = 4
    latency_s: float = 15.0
    # market knobs (core/market.py): a price makes the template biddable by
    # the MarketPlanner; an explicit hazard rate models spot-style reclaim
    # pressure (None = the platform default in market._DEFAULT_HAZARD)
    price_per_slot_hour: float = 0.0
    hazard_rate_per_hour: Optional[float] = None

    def to_core(self) -> LaunchSpec:
        hazard = None
        if self.hazard_rate_per_hour is not None:
            from repro.core.market import PreemptionHazard

            hazard = PreemptionHazard(rate_per_hour=self.hazard_rate_per_hour)
        return LaunchSpec(
            template=ProviderSpec(
                name=self.template,
                platform=self.platform,
                connector=self.connector,
                concurrency=self.concurrency,
            ),
            min_instances=self.min_instances,
            max_instances=self.max_instances,
            latency=LatencyModel(distribution="fixed", mean_s=self.latency_s),
            price_per_slot_hour=self.price_per_slot_hour,
            hazard=hazard,
        )


@dataclass
class TrafficSpec:
    """The heterogeneous mix (paper §2): a FACTS sea-rise ensemble plus
    training and serving traffic shapes sharing the same fleet."""

    facts_members: int = 0
    # per-stage modeled runtimes (pre, fit, proj, post), seconds
    facts_durations: tuple = (2.0, 1.0, 3.0, 0.5)
    train_jobs: int = 0
    train_blocks: int = 3  # checkpoint-delimited step blocks per job
    train_block_s: float = 6.0
    serve_waves: int = 0
    serve_tasks_per_wave: int = 8
    serve_task_s: float = 0.5
    # real compute on the wire: a non-empty tuple switches the serve lane
    # from modeled sleeps to ``kind="kernel"`` payloads cycling through
    # these kernels/registry.py names (tiny shapes, so waves stay cheap)
    serve_kernels: tuple = ()
    serve_kernel_reps: int = 1


@dataclass
class ChaosDecl:
    """One declarative chaos event; ``to_core`` maps it onto the typed
    event dataclasses in core/chaos.py."""

    kind: str  # site_outage | link_window | quarantine_storm | preempt_kill
    at_s: float
    site: Optional[str] = None
    duration_s: float = 0.0
    src_platform: str = "cloud"
    dst_platform: str = "hpc"
    factor: float = 0.0
    bidirectional: bool = True
    template: Optional[str] = None
    count: int = 1
    provider: Optional[str] = None

    def to_core(self) -> ChaosEvent:
        if self.kind == "site_outage":
            return SiteOutage(at_s=self.at_s, site=self.site)
        if self.kind == "link_window":
            return LinkWindow(
                at_s=self.at_s,
                duration_s=self.duration_s,
                src_platform=self.src_platform,
                dst_platform=self.dst_platform,
                factor=self.factor,
                bidirectional=self.bidirectional,
            )
        if self.kind == "quarantine_storm":
            return QuarantineStorm(
                at_s=self.at_s, template=self.template, duration_s=self.duration_s
            )
        if self.kind == "preempt_kill":
            return PreemptKill(
                at_s=self.at_s, count=self.count, provider=self.provider
            )
        raise ValueError(f"unknown chaos event kind {self.kind!r}")


@dataclass
class ScenarioSpec:
    name: str
    seed: int = 0
    policy: str = "data_gravity"
    providers: list[ProviderDecl] = field(default_factory=list)
    elastic: list[ElasticDecl] = field(default_factory=list)
    traffic: TrafficSpec = field(default_factory=TrafficSpec)
    tenants: list[TenantDecl] = field(default_factory=list)
    chaos: list[ChaosDecl] = field(default_factory=list)
    # broker shape
    tasks_per_pod: int = 16
    batch_window: float = 0.001
    site_capacity_mb: Optional[float] = None
    # market scheduler + task checkpoints (core/market.py, ckpt/checkpoint.py):
    # a makespan/SLO target arms a MarketPlanner over the elastic templates;
    # a checkpoint interval attaches a TaskCheckpointer so preempt-killed
    # tasks resume from progress_frac instead of restarting
    market_slo_s: Optional[float] = None
    checkpoint_interval_s: Optional[float] = None
    # Pallas autotuner (kernels/autotune.py): attach a modeled-timer tuner
    # to the broker so serve-lane kernels are pre-tuned at run start —
    # winners land as pinned datasets and kernel.tune events on the bus
    kernel_autotune: bool = False
    # invariant bounds
    max_makespan_inflation: float = 1.5
    timeout_s: float = 3600.0

    # -- (de)serialization ---------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        d = dict(d)
        d["providers"] = [ProviderDecl(**p) for p in d.get("providers", [])]
        d["elastic"] = [ElasticDecl(**e) for e in d.get("elastic", [])]
        d["tenants"] = [
            t if isinstance(t, TenantDecl) else TenantDecl(**t)
            for t in d.get("tenants", [])
        ]
        traffic = d.get("traffic", {})
        if isinstance(traffic, dict):
            traffic = dict(traffic)
            if "facts_durations" in traffic:
                traffic["facts_durations"] = tuple(traffic["facts_durations"])
            if "serve_kernels" in traffic:
                traffic["serve_kernels"] = tuple(traffic["serve_kernels"])
            d["traffic"] = TrafficSpec(**traffic)
        d["chaos"] = [
            c if isinstance(c, ChaosDecl) else ChaosDecl(**c)
            for c in d.get("chaos", [])
        ]
        return cls(**d)
