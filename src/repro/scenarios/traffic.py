"""Traffic builders: the paper's heterogeneous mix as Workflow DAGs.

Three shapes share one brokered fleet, mirroring the repo's real drivers
while staying cheap enough to run thousands of instances under VirtualClock:

  facts_ensemble   the FACTS sea-rise DAG (pre -> fit -> project -> post)
                   with the REAL data footprints from facts/workflow.py —
                   the 2 GB pinned forcing input and the per-stage output
                   sizes — but modeled (sleep) runtimes, so a ≥1k-member
                   ensemble executes in virtual seconds.
  train_traffic    launch/train.py's restart-safe loop: checkpoint-delimited
                   step blocks, each block consuming the previous block's
                   checkpoint dataset (ckpt/checkpoint.py semantics) and a
                   shared pinned corpus.
  serve_traffic    launch/serve.py's shape: waves of short independent
                   requests, each reading one pinned model snapshot.

Every dataset name is parameterized by the scenario name, so twin runs
(chaos vs no-chaos) inside one process never collide in a shared registry —
each run builds its own broker/registry anyway; the prefix keeps traces
legible."""
from __future__ import annotations

from repro.core.managers.workflow import Workflow
from repro.core.task import Resources, Task
from repro.facts.workflow import FORCING_DATASET, STAGE_MB, register_forcing

TRAIN_CORPUS_MB = 4096.0
TRAIN_CKPT_MB = 512.0
SERVE_SNAPSHOT_MB = 1024.0


def facts_ensemble(
    registry,
    n_members: int,
    durations: tuple = (2.0, 1.0, 3.0, 0.5),
    prefix: str = "searise",
) -> list[Workflow]:
    """``n_members`` FACTS instances with real footprints, modeled runtimes."""
    register_forcing(registry)
    pre_s, fit_s, proj_s, post_s = durations
    res = Resources(cpus=1, memory_mb=2048)
    # multi-tenant front door: the ensemble is throughput work — a "facts"
    # batch lane the serve tenant's interactive lane preempts in the queue
    lane = dict(tenant="facts", slo_class="batch")
    wfs = []
    for i in range(n_members):
        wf = Workflow(f"{prefix}.facts.{i:05d}")
        base = f"{prefix}/facts/{i:05d}"
        pre = wf.add(
            Task(
                "sleep",
                duration=pre_s,
                resources=res,
                inputs=[FORCING_DATASET],
                outputs={f"{base}/pre": STAGE_MB["pre"]},
                **lane,
            )
        )
        fit = wf.add(
            Task(
                "sleep",
                duration=fit_s,
                resources=res,
                inputs=[f"{base}/pre"],
                outputs={f"{base}/fit": STAGE_MB["fit"]},
                **lane,
            ),
            deps=[pre],
        )
        proj = wf.add(
            Task(
                "sleep",
                duration=proj_s,
                resources=res,
                inputs=[f"{base}/pre", f"{base}/fit"],
                outputs={f"{base}/proj": STAGE_MB["proj"]},
                **lane,
            ),
            deps=[fit],
        )
        wf.add(
            Task(
                "sleep",
                duration=post_s,
                resources=res,
                inputs=[f"{base}/proj"],
                outputs={f"{base}/result": STAGE_MB["result"]},
                **lane,
            ),
            deps=[proj],
        )
        wfs.append(wf)
    return wfs


def train_traffic(
    registry,
    n_jobs: int,
    n_blocks: int = 3,
    block_s: float = 6.0,
    prefix: str = "searise",
) -> list[Workflow]:
    """Checkpoint-delimited training jobs: block k reads ckpt k-1."""
    corpus = f"{prefix}/train/corpus"
    registry.add(corpus, TRAIN_CORPUS_MB, sites=["shared"], pinned=True)
    res = Resources(cpus=4, memory_mb=8192)
    lane = dict(tenant="train", slo_class="batch")
    wfs = []
    for j in range(n_jobs):
        wf = Workflow(f"{prefix}.train.{j:03d}")
        prev_task, prev_ckpt = None, None
        for k in range(n_blocks):
            inputs = [corpus] if prev_ckpt is None else [corpus, prev_ckpt]
            ckpt = f"{prefix}/train/{j:03d}/ckpt-{k + 1}"
            t = wf.add(
                Task(
                    "sleep",
                    duration=block_s,
                    resources=res,
                    inputs=inputs,
                    outputs={ckpt: TRAIN_CKPT_MB},
                    **lane,
                ),
                deps=[prev_task] if prev_task is not None else None,
            )
            prev_task, prev_ckpt = t, ckpt
        wfs.append(wf)
    return wfs


def serve_traffic(
    registry,
    n_waves: int,
    tasks_per_wave: int = 8,
    task_s: float = 0.5,
    prefix: str = "searise",
    kernels: tuple = (),
    kernel_reps: int = 1,
) -> list[Workflow]:
    """Waves of short independent requests against one pinned snapshot.

    With ``kernels`` non-empty the wave tasks carry REAL compute: each is a
    ``kind="kernel"`` payload (managers/compute.py KernelRuntime) cycling
    through the named kernels at their registry tiny shapes — the paper's
    heterogeneous-workload claim exercised with actual Pallas calls instead
    of modeled sleeps.  The snapshot input still gates placement, so kernel
    requests obey data gravity exactly like the sleep-shaped ones."""
    snapshot = f"{prefix}/serve/model-snapshot"
    registry.add(snapshot, SERVE_SNAPSHOT_MB, sites=["shared"], pinned=True)
    # the latency-sensitive tenant: interactive requests preempt queued
    # batch backfill in the dispatcher's lanes
    lane = dict(tenant="serve", slo_class="interactive")
    res = Resources(cpus=1, memory_mb=1024)
    wfs = []
    i = 0
    for w in range(n_waves):
        wf = Workflow(f"{prefix}.serve.{w:03d}")
        for _ in range(tasks_per_wave):
            if kernels:
                wf.add(
                    Task(
                        "kernel",
                        payload={
                            "kernel": kernels[i % len(kernels)],
                            "reps": kernel_reps,
                            "seed": i,
                        },
                        resources=res,
                        inputs=[snapshot],
                        **lane,
                    )
                )
                i += 1
            else:
                wf.add(
                    Task(
                        "sleep",
                        duration=task_s,
                        resources=res,
                        inputs=[snapshot],
                        **lane,
                    )
                )
        wfs.append(wf)
    return wfs


def build_traffic(registry, traffic, prefix: str = "searise") -> list[Workflow]:
    """One TrafficSpec -> the full workflow list (FACTS + train + serve)."""
    wfs: list[Workflow] = []
    if traffic.facts_members:
        wfs += facts_ensemble(
            registry,
            traffic.facts_members,
            durations=tuple(traffic.facts_durations),
            prefix=prefix,
        )
    if traffic.train_jobs:
        wfs += train_traffic(
            registry,
            traffic.train_jobs,
            n_blocks=traffic.train_blocks,
            block_s=traffic.train_block_s,
            prefix=prefix,
        )
    if traffic.serve_waves:
        wfs += serve_traffic(
            registry,
            traffic.serve_waves,
            tasks_per_wave=traffic.serve_tasks_per_wave,
            task_s=traffic.serve_task_s,
            kernels=tuple(traffic.serve_kernels),
            kernel_reps=traffic.serve_kernel_reps,
            prefix=prefix,
        )
    return wfs
