"""Canonical scenario specs.

``searise_at_scale`` is the acceptance scenario from the ISSUE: a ≥1k-member
FACTS sea-rise ensemble mixed with training and serving traffic on a
cloud+HPC fleet with an elastic burst pool, hit mid-run by four correlated
fault events — a whole-site outage, a provisioning quarantine storm, a
cloud<->HPC link partition, and a preempt-kill wave.  ``searise_smoke`` is
the same story at unit-test scale; ``searise_full`` is the nightly shape.

All runtimes are modeled (sleep tasks), all footprints are real (FACTS
stage sizes, checkpoint/corpus/snapshot bytes), so any scale runs in real
seconds under VirtualClock."""
from __future__ import annotations

from repro.scenarios.spec import (
    ChaosDecl,
    ElasticDecl,
    ProviderDecl,
    ScenarioSpec,
    TenantDecl,
    TrafficSpec,
)

# fair-share weights only (no rate limits: preset traffic is admitted in one
# up-front bulk call per run, which a rate limit would reject).  ``serve`` is
# the interactive tenant — its lane preempts queued batch work regardless of
# weight; the weights shape the batch-lane split between facts and train.
_TENANTS = [
    TenantDecl(name="serve", weight=2.0),
    TenantDecl(name="facts", weight=2.0),
    TenantDecl(name="train", weight=1.0),
]


def _fleet(concurrency: int, burst_max: int, burst_latency_s: float):
    providers = [
        ProviderDecl(name="jet2", platform="cloud", concurrency=concurrency),
        ProviderDecl(name="chi", platform="cloud", concurrency=concurrency),
        ProviderDecl(name="aws", platform="cloud", concurrency=concurrency),
        ProviderDecl(
            name="bridges2",
            platform="hpc",
            connector="pilot",
            concurrency=concurrency,
        ),
    ]
    elastic = [
        ElasticDecl(
            template="burst",
            platform="cloud",
            concurrency=concurrency,
            max_instances=burst_max,
            latency_s=burst_latency_s,
        )
    ]
    return providers, elastic


def searise_smoke(seed: int = 0) -> ScenarioSpec:
    """Unit-test / bench-smoke scale: same fleet + event shapes, ~200 task-s."""
    providers, elastic = _fleet(concurrency=4, burst_max=2, burst_latency_s=8.0)
    return ScenarioSpec(
        name="searise-smoke",
        seed=seed,
        providers=providers,
        elastic=elastic,
        tenants=list(_TENANTS),
        traffic=TrafficSpec(
            facts_members=24,
            train_jobs=2,
            train_blocks=3,
            train_block_s=6.0,
            serve_waves=2,
            serve_tasks_per_wave=8,
            serve_task_s=0.5,
        ),
        # events land AFTER the cold-staging ramp (~20 virtual s: every
        # member's first task waits on the 2 GB forcing pull) so they hit
        # running tasks and in-flight transfers, not an idle fleet
        chaos=[
            ChaosDecl(kind="site_outage", at_s=25.0, site="jet2"),
            ChaosDecl(kind="quarantine_storm", at_s=26.0, template="burst", duration_s=15.0),
            ChaosDecl(
                kind="link_window",
                at_s=28.0,
                duration_s=8.0,
                src_platform="cloud",
                dst_platform="hpc",
                factor=0.0,  # partition
            ),
            ChaosDecl(kind="preempt_kill", at_s=32.0, count=4),
        ],
        # a permanent 1-of-4 site loss is a 25% capacity cut at this tiny
        # scale; the ISSUE's 1.5x bound is defined on searise_at_scale,
        # where the staging-bound ensemble absorbs it
        max_makespan_inflation=2.0,
    )


def searise_kernels(seed: int = 0) -> ScenarioSpec:
    """searise_smoke with REAL compute on the wire: the serve lane carries
    ``kind="kernel"`` payloads cycling through all four Pallas kernels at
    their tiny shapes, the broker pre-tunes them with the modeled-timer
    autotuner, and task checkpoints are armed so a preempt-killed kernel
    task resumes from its completed-rep boundary.  Same correlated fault
    schedule as the smoke preset — the acceptance run for "a scenario with
    kernel-payload tasks completes with zero failed tasks under chaos"."""
    spec = searise_smoke(seed)
    spec.name = "searise-kernels"
    spec.traffic.serve_kernels = (
        "flash_attention",
        "selective_scan",
        "rglru_scan",
        "moe_gmm",
    )
    spec.traffic.serve_kernel_reps = 2
    spec.kernel_autotune = True
    spec.checkpoint_interval_s = 2.0
    return spec


def searise_at_scale(seed: int = 0) -> ScenarioSpec:
    """The ISSUE's acceptance scenario: 1024 FACTS members + train/serve
    traffic, four correlated fault events including a whole-site outage and
    a cloud<->HPC partition, zero failed tasks, inflation <= 1.5x.

    No warm elastic floor: tasks parked on stage-in now register as decayed
    deferred demand (Dispatcher.deferred_demand), so the autoscaler holds
    burst capacity through a link partition on the signal itself instead of
    the old ``min_instances=2`` workaround."""
    providers, elastic = _fleet(concurrency=8, burst_max=4, burst_latency_s=15.0)
    return ScenarioSpec(
        name="searise-at-scale",
        seed=seed,
        providers=providers,
        elastic=elastic,
        tenants=list(_TENANTS),
        traffic=TrafficSpec(
            facts_members=1024,
            train_jobs=6,
            train_blocks=3,
            train_block_s=6.0,
            serve_waves=4,
            serve_tasks_per_wave=16,
            serve_task_s=0.5,
        ),
        chaos=[
            ChaosDecl(kind="site_outage", at_s=40.0, site="jet2"),
            ChaosDecl(kind="quarantine_storm", at_s=45.0, template="burst", duration_s=60.0),
            ChaosDecl(
                kind="link_window",
                at_s=60.0,
                duration_s=30.0,
                src_platform="cloud",
                dst_platform="hpc",
                factor=0.0,  # partition
            ),
            ChaosDecl(kind="preempt_kill", at_s=80.0, count=12),
        ],
    )


def searise_full(seed: int = 0) -> ScenarioSpec:
    """Nightly scale: a 2k-member ensemble and a longer fault sequence."""
    spec = searise_at_scale(seed)
    spec.name = "searise-full"
    spec.traffic.facts_members = 2048
    spec.traffic.train_jobs = 8
    spec.traffic.serve_waves = 8
    spec.chaos = spec.chaos + [
        ChaosDecl(
            kind="link_window",
            at_s=120.0,
            duration_s=20.0,
            src_platform="cloud",
            dst_platform="cloud",
            factor=0.1,  # degradation, not partition
        ),
        ChaosDecl(kind="preempt_kill", at_s=140.0, count=16),
    ]
    return spec
