"""Scenario harness: declarative fleet + traffic + chaos specs, a runner
that executes them under the active Clock, and an invariant checker that
turns "the pieces each work" into "the *system* works under adversity".

    from repro.scenarios import presets, run_scenario, check_invariants

    spec = presets.searise_smoke()
    chaos_rep = run_scenario(spec, chaos=True)
    base_rep = run_scenario(spec, chaos=False)   # the no-chaos twin
    assert not check_invariants(chaos_rep, base_rep, spec)
"""
from repro.scenarios.runner import (
    ScenarioReport,
    check_invariants,
    run_scenario,
)
from repro.scenarios.spec import (
    ChaosDecl,
    ElasticDecl,
    ProviderDecl,
    ScenarioSpec,
    TrafficSpec,
)
from repro.scenarios import presets

__all__ = [
    "ChaosDecl",
    "ElasticDecl",
    "ProviderDecl",
    "ScenarioReport",
    "ScenarioSpec",
    "TrafficSpec",
    "check_invariants",
    "presets",
    "run_scenario",
]
